"""Losses (g/h vs autodiff) and quantile binning."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import LOSSES, get_loss
from repro.core.binning import Binner, bin_dataset


@pytest.mark.parametrize("name", list(LOSSES))
def test_grad_hess_match_autodiff(name):
    loss = get_loss(name)
    rng = np.random.default_rng(0)
    m = jnp.asarray(rng.normal(size=64), jnp.float32)
    y = jnp.asarray((rng.uniform(size=64) > .5).astype(np.float64)
                    if name == "binary:logistic"
                    else rng.normal(size=64), jnp.float32)
    g, h = loss.grad_hess(m, y)
    g_ad = jax.vmap(jax.grad(loss.value))(m, y)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ad),
                               rtol=1e-5, atol=1e-6)
    if name != "reg:huber":  # huber hessian is a smoothed surrogate
        h_ad = jax.vmap(jax.grad(jax.grad(loss.value)))(m, y)
        np.testing.assert_allclose(np.asarray(h), np.asarray(h_ad),
                                   rtol=1e-4, atol=1e-5)


def test_binning_roundtrip_order_preserved():
    rng = np.random.default_rng(1)
    X = rng.normal(size=(5000, 3))
    data = bin_dataset(X, max_bins=32)
    codes = np.asarray(data.codes)
    for f in range(3):
        order = np.argsort(X[:, f], kind="stable")
        assert (np.diff(codes[order, f].astype(int)) >= 0).all()


def test_binning_missing_and_categorical():
    X = np.array([[1.0, 2.0], [np.nan, 0.0], [3.0, 1.0], [2.0, np.nan]])
    data = bin_dataset(X, max_bins=16, categorical_fields=[1])
    codes = np.asarray(data.codes)
    assert codes[1, 0] == data.missing_bin
    assert codes[3, 1] == data.missing_bin
    assert codes[0, 1] == 2 and codes[1, 1] == 0 and codes[2, 1] == 1
    assert bool(data.is_categorical[1]) and not bool(data.is_categorical[0])


def test_binning_rejects_too_many_categories():
    X = np.arange(600, dtype=np.float64).reshape(-1, 1)
    with pytest.raises(ValueError):
        Binner(max_bins=16, categorical_fields=[0]).fit(X)


def test_column_major_copy_is_consistent():
    rng = np.random.default_rng(2)
    X = rng.normal(size=(100, 5))
    data = bin_dataset(X, max_bins=8)
    np.testing.assert_array_equal(np.asarray(data.codes).T,
                                  np.asarray(data.codes_cm))

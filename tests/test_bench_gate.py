"""The CI perf gate (benchmarks/check_regression.py) — pure-dict logic."""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import (check, machine_calibration,  # noqa: E402
                                         throughput_lanes)


def _report(rps, error=None):
    return {"benches": {
        "training": {
            "error": error,
            "rows": [{"name": name,
                      "us_per_call": 1.0,
                      "derived": f"rows_per_sec={v:.0f};n=1"}
                     for name, v in rps.items()],
        }}}


def test_lane_extraction_ignores_non_throughput_rows():
    rep = _report({"a": 100.0})
    rep["benches"]["training"]["rows"].append(
        {"name": "modeled", "us_per_call": 5.0, "derived": "x=3.10"})
    assert throughput_lanes(rep) == {("training", "a"): 100.0}


def test_within_tolerance_passes():
    base = _report({"a": 1000.0, "b": 500.0, "c": 2000.0})
    ci = _report({"a": 980.0, "b": 400.0, "c": 2100.0})   # worst: -20%
    assert check(ci, base, tolerance=0.30) == ([], [])


def test_per_lane_regression_fails():
    """Two lanes hold, one drops 45% — calibration (median ratio 1.0)
    does not mask a genuine single-lane regression."""
    base = _report({"a": 1000.0, "b": 500.0, "c": 2000.0})
    ci = _report({"a": 1000.0, "b": 500.0, "c": 1100.0})
    failures, warnings = check(ci, base, tolerance=0.30)
    assert len(failures) == 1 and "training/c" in failures[0]
    assert warnings == []


def test_uniform_machine_speed_difference_passes():
    """A slower runner class (every lane at ~0.5x) is calibrated away."""
    base = _report({"a": 1000.0, "b": 500.0, "c": 2000.0})
    ci = _report({"a": 520.0, "b": 240.0, "c": 1000.0})
    assert machine_calibration(throughput_lanes(base),
                               throughput_lanes(ci)) == 0.5
    assert check(ci, base, tolerance=0.30) == ([], [])


def test_calibration_clamped_for_collapse():
    """An across-the-board 5x collapse exceeds the 3x clamp and fails —
    it cannot all be explained away as hardware."""
    base = _report({"a": 1000.0, "b": 500.0, "c": 2000.0})
    ci = _report({"a": 200.0, "b": 100.0, "c": 400.0})
    assert check(ci, base, tolerance=0.30)[0] != []


def test_absolute_mode_skips_calibration():
    base = _report({"a": 1000.0})
    ci = _report({"a": 650.0})                   # -35%, single lane
    assert check(ci, base, tolerance=0.30) == ([], [])    # calibrated away
    failures, _ = check(ci, base, tolerance=0.30, absolute=True)
    assert len(failures) == 1 and "below" in failures[0]


def test_disappeared_lane_warns_but_passes():
    """A baseline lane absent from a successful CI bench (renamed or
    retired) must not fail the gate — it becomes a printed warning."""
    base = _report({"a": 1000.0, "b": 500.0})
    ci = _report({"a": 1000.0})
    failures, warnings = check(ci, base, tolerance=0.30)
    assert failures == []
    assert len(warnings) == 1 and "training/b" in warnings[0]
    assert "disappeared" in warnings[0]


def test_new_ci_lane_without_baseline_is_ignored():
    """A lane only the CI run reports (new bench, baseline not yet
    regenerated) must neither fail nor warn — and must not skew the
    machine calibration."""
    base = _report({"a": 1000.0})
    ci = _report({"a": 1000.0, "new_lane": 1.0})
    assert check(ci, base, tolerance=0.30) == ([], [])


def test_errored_bench_fails_once():
    base = _report({"a": 1000.0, "b": 500.0})
    ci = _report({}, error="RuntimeError('boom')")
    failures, warnings = check(ci, base, tolerance=0.30)
    assert len(failures) == 1 and "errored in CI" in failures[0]
    assert warnings == []   # errored lanes are failures, not warnings


def test_faster_ci_always_passes():
    base = _report({"a": 1000.0, "b": 500.0})
    ci = _report({"a": 5000.0, "b": 2600.0})
    assert check(ci, base, tolerance=0.30) == ([], [])

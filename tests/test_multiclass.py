"""Multi-class softmax GBDT: loss calculus, end-to-end training, the
class-batched kernels, and bundle/checkpoint round-trips."""
import json
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import BoosterClassifier, ExecutionPlan, load, save
from repro.core import GBDTConfig, bin_dataset, train
from repro.core.losses import get_loss, multi_softmax
from repro.data import make_tabular


# --------------------------------------------------------------------------
# softmax loss calculus vs autodiff
# --------------------------------------------------------------------------
def test_softmax_grad_hess_matches_autodiff():
    rng = np.random.default_rng(0)
    K, n = 5, 64
    loss = multi_softmax(K)
    m = jnp.asarray(rng.normal(size=(n, K)), jnp.float32)
    y = jnp.asarray(rng.integers(0, K, n), jnp.float32)

    g, h = loss.grad_hess(m, y)
    g_auto = jax.grad(lambda mm: jnp.sum(loss.value(mm, y)))(m)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_auto),
                               rtol=1e-5, atol=1e-6)

    # h is the exact DIAGONAL of the per-record Hessian: d^2 L_i / dm_ik^2
    def value_one(mm, yy):
        return loss.value(mm[None, :], yy[None])[0]

    hess = jax.vmap(jax.hessian(value_one))(m, y)            # (n, K, K)
    h_auto = jax.vmap(jnp.diag)(hess)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_auto),
                               rtol=1e-4, atol=1e-5)


def test_softmax_loss_registry_and_validation():
    loss = get_loss("multi:softmax", 4)
    assert loss.n_outputs == 4
    with pytest.raises(ValueError, match="requires n_classes"):
        get_loss("multi:softmax")
    with pytest.raises(ValueError, match="n_classes >= 2"):
        multi_softmax(1)
    # scalar losses are untouched by the n_classes plumbing
    assert get_loss("reg:squarederror").n_outputs is None


def test_softmax_base_margin_is_centered_log_prior():
    loss = multi_softmax(3)
    y = jnp.asarray([0, 0, 0, 1, 2, 2], jnp.float32)
    bm = np.asarray(loss.base_margin(y))
    assert bm.shape == (3,)
    np.testing.assert_allclose(bm.sum(), 0.0, atol=1e-6)
    p = np.asarray(jax.nn.softmax(jnp.asarray(bm)))
    np.testing.assert_allclose(p, [3 / 6, 1 / 6, 2 / 6], rtol=1e-4)


# --------------------------------------------------------------------------
# end-to-end training
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def mc_data():
    X, y, _ = make_tabular(2500, 8, 0, task="multiclass", n_classes=4,
                           seed=0)
    return X, y.astype(int)


@pytest.fixture(scope="module")
def mc_fitted(mc_data):
    X, y = mc_data
    est = BoosterClassifier(n_trees=20, max_depth=5, learning_rate=0.4,
                            max_bins=32, seed=1)
    est.fit(X, y)
    return est


def test_multiclass_learns_beats_majority(mc_data, mc_fitted):
    X, y = mc_data
    majority = np.bincount(y).max() / len(y)
    assert majority < 0.3                       # near-balanced 4 classes
    acc = float((mc_fitted.predict(X) == y).mean())
    assert acc > 0.8, acc


def test_multiclass_auto_detection_and_shapes(mc_data, mc_fitted):
    X, y = mc_data
    model = mc_fitted.model_
    assert model.objective == "multi:softmax"
    assert model.n_classes == 4
    assert model.n_trees == 20 * 4              # K trees per round
    assert model.n_rounds == 20
    proba = mc_fitted.predict_proba(X)
    assert proba.shape == (len(y), 4)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, rtol=1e-5)
    assert set(np.unique(mc_fitted.predict(X))) <= {0, 1, 2, 3}


def test_multiclass_staged_predict_prefixes(mc_data, mc_fitted):
    X, y = mc_data
    stages = list(mc_fitted.staged_predict(X[:200]))
    assert len(stages) == 20
    assert stages[0].shape == (200, 4)
    np.testing.assert_allclose(np.asarray(stages[-1]),
                               mc_fitted.predict_proba(X[:200]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_multiclass_strategies_grow_identical_trees(mc_data):
    """The K>1 parity acceptance: every histogram strategy grows the SAME
    K-class forest (same splits; leaf values to fp tolerance)."""
    X, y = mc_data
    data = bin_dataset(X[:1200], max_bins=16)
    results = {}
    for s in ("scatter", "scatter_private", "sort", "onehot",
              "pallas_grouped", "pallas_packed"):
        cfg = GBDTConfig(n_trees=2, max_depth=3, objective="multi:softmax",
                         n_classes=4, hist_strategy=s)
        results[s] = train(cfg, data, y[:1200])
    t0 = results["scatter"].model.trees
    for s, r in results.items():
        np.testing.assert_array_equal(np.asarray(r.model.trees.feature),
                                      np.asarray(t0.feature), err_msg=s)
        np.testing.assert_array_equal(np.asarray(r.model.trees.threshold),
                                      np.asarray(t0.threshold), err_msg=s)
        np.testing.assert_allclose(np.asarray(r.model.trees.leaf_value),
                                   np.asarray(t0.leaf_value),
                                   rtol=1e-4, atol=1e-5, err_msg=s)


def test_multiclass_pallas_traversal_matches_reference(mc_data, mc_fitted):
    X, _ = mc_data
    codes = mc_fitted.binner_.transform(X[:400])
    model = mc_fitted.model_
    a = model.predict_margin(
        codes, plan=ExecutionPlan.auto(traversal_strategy="reference"))
    b = model.predict_margin(
        codes, plan=ExecutionPlan.auto(traversal_strategy="pallas"))
    assert a.shape == (400, 4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-6)


def test_multiclass_label_validation(mc_data):
    X, y = mc_data
    data = bin_dataset(X[:200], max_bins=16)
    with pytest.raises(ValueError, match="labels must be integers"):
        train(GBDTConfig(n_trees=1, max_depth=2,
                         objective="multi:softmax", n_classes=3,
                         hist_strategy="scatter"), data, y[:200])
    # fractional labels are rejected, not silently truncated
    with pytest.raises(ValueError, match="labels must be integers"):
        train(GBDTConfig(n_trees=1, max_depth=2,
                         objective="multi:softmax", n_classes=4,
                         hist_strategy="scatter"), data,
              y[:200] + 0.5)
    with pytest.raises(ValueError, match="requires n_classes"):
        GBDTConfig(objective="multi:softmax")
    with pytest.raises(ValueError, match="depthwise"):
        GBDTConfig(objective="multi:softmax", n_classes=3,
                   grow_policy="lossguide")


def test_classifier_n_classes_two_stays_binary():
    """An explicit (redundant) n_classes=2 with binary labels must train
    the scalar logistic path, not crash in config validation."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(300, 4))
    y = (X[:, 0] > 0).astype(int)
    est = BoosterClassifier(n_trees=2, max_depth=2, max_bins=16,
                            n_classes=2)
    est.fit(X, y)
    assert est.model_.objective == "binary:logistic"
    assert est.model_.n_classes == 1
    assert est.predict_proba(X).shape == (300, 2)


def test_classifier_scalar_objective_rejects_wide_k():
    """An explicit scalar objective with n_classes > 2 must fail loudly,
    not silently train a binary model on multi-class labels."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(100, 3))
    y = rng.integers(0, 4, 100)
    est = BoosterClassifier(n_trees=1, max_depth=2, max_bins=16,
                            objective="binary:logistic", n_classes=4)
    with pytest.raises(ValueError, match="conflicts with"):
        est.fit(X, y)
    # ...and the same when K comes from the labels instead of the param
    est2 = BoosterClassifier(n_trees=1, max_depth=2, max_bins=16,
                             objective="binary:logistic")
    with pytest.raises(ValueError, match="labels span"):
        est2.fit(X, y)


def test_multiclass_eval_labels_validated(mc_data):
    """Out-of-range labels in eval_set raise instead of producing NaN
    eval loss (which silently breaks early stopping)."""
    X, y = mc_data
    data = bin_dataset(X[:200], max_bins=16)
    ev = bin_dataset(X[200:260], max_bins=16)
    bad = np.asarray(y[200:260]).copy()
    bad[0] = 9                       # class id beyond K=4
    with pytest.raises(ValueError, match="eval_set labels"):
        train(GBDTConfig(n_trees=1, max_depth=2,
                         objective="multi:softmax", n_classes=4,
                         hist_strategy="scatter"), data, y[:200],
              eval_set=(ev, bad))


def test_classifier_soft_labels_with_explicit_binary_objective():
    """Soft targets in [0, 1] (label smoothing / distillation) remain
    valid for an EXPLICIT binary:logistic objective; only auto-detection
    and softmax require integer class ids."""
    rng = np.random.default_rng(3)
    X = rng.normal(size=(300, 4))
    y_soft = 0.5 + 0.4 * np.tanh(X[:, 0])        # floats in (0.1, 0.9)
    est = BoosterClassifier(n_trees=2, max_depth=2, max_bins=16,
                            objective="binary:logistic")
    est.fit(X, y_soft)
    assert est.model_.objective == "binary:logistic"
    assert est.predict_proba(X).shape == (300, 2)
    with pytest.raises(ValueError, match="integers"):
        BoosterClassifier(n_trees=1).fit(X, y_soft)  # auto-detect needs ids


def test_classifier_forced_wider_k():
    """n_classes wider than the observed label set forces softmax."""
    rng = np.random.default_rng(1)
    X = rng.normal(size=(300, 4))
    y = (X[:, 0] > 0).astype(int)           # labels only {0, 1}
    est = BoosterClassifier(n_trees=2, max_depth=2, max_bins=16,
                            n_classes=5)
    est.fit(X, y)
    assert est.model_.objective == "multi:softmax"
    assert est.model_.n_classes == 5
    assert est.predict_proba(X).shape == (300, 5)


# --------------------------------------------------------------------------
# serialization: bundles, checkpoints, pre-multi-class compatibility
# --------------------------------------------------------------------------
def test_multiclass_bundle_roundtrip_bit_exact(mc_data, mc_fitted, tmp_path):
    X, _ = mc_data
    path = str(tmp_path / "bundle")
    mc_fitted.save(path)
    est2 = load(path)
    assert isinstance(est2, BoosterClassifier)
    assert est2.model_.n_classes == 4
    np.testing.assert_array_equal(est2.predict_proba(X),
                                  mc_fitted.predict_proba(X))
    np.testing.assert_array_equal(est2.predict(X), mc_fitted.predict(X))


def test_multiclass_checkpoint_resume_bit_exact(mc_data, tmp_path):
    X, y = mc_data
    d = str(tmp_path / "ckpt")
    kw = dict(max_depth=3, learning_rate=0.3, max_bins=16, seed=7)
    a = BoosterClassifier(n_trees=3, **kw)
    a.fit(X, y, checkpoint_dir=d)
    # checkpoint steps count ROUNDS (not rounds*K): the final save must
    # not outrank later resumes' per-round saves
    from repro.api import load_checkpoint
    _, step = load_checkpoint(d)
    assert step == 3
    # a completed-run restore grows 0 extra rounds: restored K-class
    # predictions must be bit-exact
    b = BoosterClassifier(n_trees=3, **kw)
    b.fit(X, y, checkpoint_dir=d)
    assert b.n_trees_ == 3 * 4
    np.testing.assert_array_equal(a.predict_proba(X), b.predict_proba(X))
    # a genuine mid-run resume (3 more rounds on top of the checkpoint)
    # matches the straight 6-round fit to fp accumulation tolerance
    c = BoosterClassifier(n_trees=6, **kw)
    c.fit(X, y, checkpoint_dir=d)
    assert c.n_trees_ == 6 * 4
    straight = BoosterClassifier(n_trees=6, **kw)
    straight.fit(X, y)
    np.testing.assert_allclose(c.predict_proba(X),
                               straight.predict_proba(X),
                               rtol=1e-4, atol=1e-5)


def test_multiclass_warm_start_with_partial_label_batch(mc_data, mc_fitted):
    """Continuing a K=4 model on a batch whose labels happen to lack the
    highest classes keeps the model's K (observed labels are only a lower
    bound), instead of erroring or flipping to binary."""
    X, y = mc_data
    sub = y < 2                       # labels only {0, 1} in this batch
    cont = BoosterClassifier(n_trees=2, max_depth=5, learning_rate=0.4,
                             max_bins=32, seed=1)
    cont.fit(X[sub], y[sub], xgb_model=mc_fitted)
    assert cont.model_.objective == "multi:softmax"
    assert cont.model_.n_classes == 4
    assert cont.model_.n_rounds == 20 + 2
    assert cont.predict_proba(X).shape == (len(y), 4)
    # a regressor warm-starting from a multiclass model is a real mismatch
    from repro.api import BoosterRegressor
    bad = BoosterRegressor(n_trees=1, max_depth=5, max_bins=32)
    with pytest.raises(ValueError, match="objective"):
        bad.fit(X, y.astype(float), xgb_model=mc_fitted)


def test_pre_multiclass_bundle_still_loads(tmp_path):
    """Bundles written before n_classes existed (meta lacks the key) must
    load as K=1 models with identical predictions."""
    X, y, _ = make_tabular(400, 5, 0, task="regression", seed=3)
    from repro.api import BoosterRegressor
    est = BoosterRegressor(n_trees=3, max_depth=3, max_bins=16)
    est.fit(X, y)
    path = str(tmp_path / "legacy")
    save(path, est.to_pipeline())
    # strip the new meta key in place — the sha256 covers arrays.npz only
    mpath = os.path.join(path, "manifest.json")
    with open(mpath) as f:
        manifest = json.load(f)
    assert manifest["meta"]["model"].pop("n_classes") == 1
    with open(mpath, "w") as f:
        json.dump(manifest, f)
    pipe = load(path)
    assert pipe.model.n_classes == 1
    np.testing.assert_array_equal(np.asarray(pipe.predict(X)),
                                  np.asarray(est.predict(X)))

"""Step-⑤ traversal + batch-inference kernels vs the gather-walk oracle."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan
from repro.kernels import ops, ref
from repro.kernels.ref import TreeArrays

_PALLAS = ExecutionPlan.auto(traversal_strategy="pallas")


def rand_tree(rng, depth, n_cols, n_bins, p_passthrough=0.2):
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth
    feat = rng.integers(0, n_cols, n_int).astype(np.int32)
    feat[rng.uniform(size=n_int) < p_passthrough] = -1
    return TreeArrays(
        feature=jnp.asarray(feat),
        threshold=jnp.asarray(rng.integers(0, n_bins - 1, n_int), jnp.int32),
        is_cat=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
        default_left=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
        leaf_value=jnp.asarray(rng.normal(size=n_leaf), jnp.float32))


@pytest.mark.parametrize("depth", [1, 3, 6])
@pytest.mark.parametrize("n,n_cols,n_bins", [
    (64, 4, 8), (513, 7, 16), (1025, 63, 32)])
def test_traverse_matches_oracle(depth, n, n_cols, n_bins):
    rng = np.random.default_rng(depth * 100 + n)
    codes = jnp.asarray(rng.integers(0, n_bins, (n, n_cols)), jnp.uint8)
    tree = rand_tree(rng, depth, n_cols, n_bins)
    want = ref.traverse_ref(tree, codes, n_bins - 1)
    got = ops.traverse_tree(tree, codes, missing_bin=n_bins - 1,
                            plan=_PALLAS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("T", [1, 5, 17])
def test_ensemble_matches_oracle(T):
    rng = np.random.default_rng(T)
    depth, n_cols, n_bins, n = 4, 9, 16, 300
    codes = jnp.asarray(rng.integers(0, n_bins, (n, n_cols)), jnp.uint8)
    trees = TreeArrays(*[jnp.stack(x) for x in zip(
        *[tuple(rand_tree(rng, depth, n_cols, n_bins)) for _ in range(T)])])
    want = ref.predict_ensemble_ref(trees, codes, n_bins - 1)
    got = ops.predict_ensemble(trees, codes, missing_bin=n_bins - 1,
                               depth=depth, plan=_PALLAS)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_missing_values_follow_default_direction():
    rng = np.random.default_rng(0)
    n_bins = 8
    tree = TreeArrays(
        feature=jnp.asarray([0], jnp.int32),
        threshold=jnp.asarray([3], jnp.int32),
        is_cat=jnp.asarray([0], jnp.int32),
        default_left=jnp.asarray([1], jnp.int32),
        leaf_value=jnp.asarray([10.0, 20.0], jnp.float32))
    codes = jnp.asarray([[n_bins - 1]], jnp.uint8)  # missing
    out = ops.traverse_tree(tree, codes, missing_bin=n_bins - 1,
                            plan=_PALLAS)
    assert float(out[0]) == 10.0  # default_left -> left leaf
    tree2 = tree._replace(default_left=jnp.asarray([0], jnp.int32))
    out2 = ops.traverse_tree(tree2, codes, missing_bin=n_bins - 1,
                             plan=_PALLAS)
    assert float(out2[0]) == 20.0


def test_categorical_one_vs_rest():
    n_bins = 8
    tree = TreeArrays(
        feature=jnp.asarray([0], jnp.int32),
        threshold=jnp.asarray([5], jnp.int32),   # category == 5 -> left
        is_cat=jnp.asarray([1], jnp.int32),
        default_left=jnp.asarray([0], jnp.int32),
        leaf_value=jnp.asarray([1.0, -1.0], jnp.float32))
    codes = jnp.asarray([[5], [2], [6]], jnp.uint8)
    out = ops.traverse_tree(tree, codes, missing_bin=n_bins - 1,
                            plan=_PALLAS)
    np.testing.assert_allclose(np.asarray(out), [1.0, -1.0, -1.0])

"""The tree-batched inference engine (PR 5).

* batched level walk vs the legacy per-tree scan: BIT parity (leaf
  decisions are discrete; integer-valued leaves make every accumulation
  order exact) across depths x K x missing values,
* tree-blocked Pallas kernel parity across ``trees_per_block`` tiles,
  including tree counts that do not divide the tile,
* predict-cache retrace accounting (power-of-two row/tree buckets),
* device-resident binned transform vs the host path,
* sharded multi-class inference vs single-device.
"""
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan
from repro.core.binning import Binner
from repro.core.gbdt import GBDTModel
from repro.core.inference import (GBDTPipeline, bucket_pow2, bucket_trees,
                                  pad_trees, predict_cache_clear,
                                  predict_cache_stats,
                                  predict_margin_cached, sharded_predict)
from repro.kernels import ops, ref
from repro.kernels.ref import TreeArrays

N_BINS = 16
MISSING = N_BINS - 1


def rand_forest(rng, T, depth, n_cols, int_leaves=True):
    """Stacked (T, ...) trees; integer leaf values keep float sums exact
    in ANY association, so scan-vs-batched parity can be asserted
    bit-for-bit (the walks themselves are discrete and identical)."""
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth

    def one():
        feat = rng.integers(0, n_cols, n_int).astype(np.int32)
        feat[rng.uniform(size=n_int) < 0.2] = -1            # pass-through
        leaves = (rng.integers(-8, 8, n_leaf).astype(np.float32)
                  if int_leaves else
                  rng.normal(size=n_leaf).astype(np.float32))
        return TreeArrays(
            feature=jnp.asarray(feat),
            threshold=jnp.asarray(rng.integers(0, N_BINS - 1, n_int),
                                  jnp.int32),
            is_cat=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
            default_left=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
            leaf_value=jnp.asarray(leaves))

    trees = [one() for _ in range(T)]
    return TreeArrays(*[jnp.stack([getattr(t, f) for t in trees])
                        for f in TreeArrays._fields])


def rand_codes(rng, n, n_cols, missing_rate=0.1):
    codes = rng.integers(0, N_BINS, (n, n_cols)).astype(np.uint8)
    codes[rng.uniform(size=codes.shape) < missing_rate] = MISSING
    return jnp.asarray(codes)


# --------------------------------------------------------------------------
# batched level walk vs legacy per-tree scan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [1, 3, 6])
@pytest.mark.parametrize("K", [1, 3])
def test_batched_walk_bit_equals_scan(depth, K):
    rng = np.random.default_rng(depth * 10 + K)
    T = 3 * K * (2 if depth < 6 else 1)
    trees = rand_forest(rng, T, depth, n_cols=9)
    codes = rand_codes(rng, 257, 9)
    want = ref.predict_ensemble_ref(trees, codes, MISSING, n_classes=K)
    got = ref.predict_ensemble_batched(trees, codes, MISSING, n_classes=K)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_batched_walk_float_leaves_close_to_scan():
    """Real (non-integer) leaves: only the fold's accumulation order can
    differ, so the paths agree to float tolerance."""
    rng = np.random.default_rng(7)
    trees = rand_forest(rng, 40, 5, n_cols=12, int_leaves=False)
    codes = rand_codes(rng, 400, 12)
    want = ref.predict_ensemble_ref(trees, codes, MISSING)
    got = ref.predict_ensemble_batched(trees, codes, MISSING)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_ops_reference_dispatches_batched_walk():
    rng = np.random.default_rng(3)
    trees = rand_forest(rng, 6, 4, n_cols=5)
    codes = rand_codes(rng, 100, 5)
    via_ops = ops.predict_ensemble(
        trees, codes, missing_bin=MISSING, depth=4,
        plan=ExecutionPlan.auto(traversal_strategy="reference"))
    direct = ref.predict_ensemble_batched(trees, codes, MISSING)
    np.testing.assert_array_equal(np.asarray(via_ops), np.asarray(direct))
    via_scan = ops.predict_ensemble(
        trees, codes, missing_bin=MISSING, depth=4,
        plan=ExecutionPlan.auto(traversal_strategy="scan"))
    np.testing.assert_array_equal(np.asarray(via_scan),
                                  np.asarray(ref.predict_ensemble_ref(
                                      trees, codes, MISSING)))


def test_batched_walk_survives_wide_field_ids():
    """Field ids >= 2**15 overflow the packed int32 table — the dispatch
    must fall back to the unpacked walk, not silently corrupt."""
    F = (1 << 15) + 100
    tree = TreeArrays(
        feature=jnp.asarray([[F - 100]], jnp.int32),      # id 32868
        threshold=jnp.asarray([[1]], jnp.int32),
        is_cat=jnp.asarray([[0]], jnp.int32),
        default_left=jnp.asarray([[0]], jnp.int32),
        leaf_value=jnp.asarray([[1.0, 2.0]], jnp.float32))
    codes = np.zeros((4, F), np.uint8)
    codes[2:, F - 100] = 3                                 # > threshold
    codes = jnp.asarray(codes)
    for strat in ("reference", "scan"):
        out = ops.predict_ensemble(
            tree, codes, missing_bin=MISSING, depth=1,
            plan=ExecutionPlan.auto(traversal_strategy=strat))
        np.testing.assert_array_equal(np.asarray(out),
                                      [1.0, 1.0, 2.0, 2.0])


# --------------------------------------------------------------------------
# tree-blocked Pallas kernel
# --------------------------------------------------------------------------
@pytest.mark.parametrize("K", [1, 3])
@pytest.mark.parametrize("T,tblk", [(8, 8), (12, 4), (10, 4), (5, 8),
                                    (7, 1)])
def test_pallas_tree_blocking_matches_batched(K, T, tblk):
    """Every tile size — including T % tblk != 0 and tblk > T — agrees
    with the batched reference walk."""
    rng = np.random.default_rng(T * 10 + tblk + K)
    depth = 4
    trees = rand_forest(rng, T * K, depth, n_cols=9)
    codes = rand_codes(rng, 300, 9)
    plan = ExecutionPlan.auto(traversal_strategy="pallas",
                              trees_per_block=tblk)
    got = ops.predict_ensemble(trees, codes, missing_bin=MISSING,
                               depth=depth, plan=plan, n_classes=K)
    want = ref.predict_ensemble_batched(trees, codes, MISSING, n_classes=K)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# the compile-once predict cache
# --------------------------------------------------------------------------
def _model(rng, T=6, depth=4, F=5, K=1):
    trees = rand_forest(rng, T, depth, F)
    base = (np.zeros((K,), np.float32) if K > 1 else 0.5)
    return GBDTModel(trees=trees, base_margin=base,
                     objective="multi:softmax" if K > 1
                     else "reg:squarederror",
                     missing_bin=MISSING, n_fields=F, max_depth=depth,
                     n_classes=K)


def test_bucket_pow2():
    assert bucket_pow2(0) == 1
    assert bucket_pow2(1) == 1
    assert bucket_pow2(3) == 4
    assert bucket_pow2(128) == 128
    assert bucket_pow2(129) == 256
    assert bucket_pow2(5, floor=128) == 128


def test_bucket_trees_caps_padding_overhead():
    # small ensembles: exact (granule 1), zero padded-walk tax
    assert bucket_trees(5) == 5
    assert bucket_trees(8) == 8
    # larger: next multiple of pow2(T)/16 — at most 12.5% padding
    assert bucket_trees(100) == 104          # granule 8
    assert bucket_trees(104) == 104
    assert bucket_trees(105) == 112
    assert bucket_trees(300) == 320          # granule 32, 6.7% pad
    assert bucket_trees(512) == 512
    for T in range(1, 600):
        b = bucket_trees(T)
        assert b >= T and (b - T) <= max(1, T // 8)


def test_predict_cache_zero_retrace_within_bucket():
    rng = np.random.default_rng(11)
    model = _model(rng)
    predict_cache_clear()
    plan = ExecutionPlan.auto()
    out = predict_margin_cached(model, rand_codes(rng, 100, 5), plan=plan)
    assert out.shape == (100,)
    t0 = predict_cache_stats()["traces"]
    assert t0 >= 1
    # same 128-row bucket: NO new compilation
    predict_margin_cached(model, rand_codes(rng, 128, 5), plan=plan)
    predict_margin_cached(model, rand_codes(rng, 65, 5), plan=plan)
    assert predict_cache_stats()["traces"] == t0
    # new bucket (256): exactly one more trace, then warm again
    predict_margin_cached(model, rand_codes(rng, 200, 5), plan=plan)
    assert predict_cache_stats()["traces"] == t0 + 1
    predict_margin_cached(model, rand_codes(rng, 256, 5), plan=plan)
    assert predict_cache_stats()["traces"] == t0 + 1


def test_predict_cache_tree_bucket_absorbs_growth():
    """Checkpoint-resume: 99 -> 100 -> 104 trees all land in the
    104-tree bucket and reuse one executable."""
    rng = np.random.default_rng(12)
    codes = rand_codes(rng, 64, 5)
    plan = ExecutionPlan.auto()
    predict_cache_clear()
    predict_margin_cached(_model(rng, T=99), codes, plan=plan)
    t0 = predict_cache_stats()["traces"]
    predict_margin_cached(_model(rng, T=100), codes, plan=plan)
    predict_margin_cached(_model(rng, T=104), codes, plan=plan)
    assert predict_cache_stats()["traces"] == t0
    predict_margin_cached(_model(rng, T=105), codes, plan=plan)  # 112
    assert predict_cache_stats()["traces"] == t0 + 1


def test_predict_cache_key_ignores_training_only_plan_fields():
    """Two plans differing only in training-side knobs (histogram
    strategy, offload, chunking) share one cached step AND one compiled
    executable."""
    rng = np.random.default_rng(14)
    model = _model(rng)
    codes = rand_codes(rng, 64, 5)
    predict_cache_clear()
    predict_margin_cached(model, codes, plan=ExecutionPlan.auto())
    t0, e0 = (predict_cache_stats()["traces"],
              predict_cache_stats()["entries"])
    predict_margin_cached(
        model, codes,
        plan=ExecutionPlan.auto(hist_strategy="sort",
                                host_offload_split=True,
                                chunk_bytes=1 << 20))
    assert predict_cache_stats()["traces"] == t0
    assert predict_cache_stats()["entries"] == e0


@pytest.mark.parametrize("K", [1, 3])
def test_predict_cached_matches_direct(K):
    """Row/tree pad buckets NEVER change results (the docs contract)."""
    rng = np.random.default_rng(13 + K)
    model = _model(rng, T=5 * K, K=K)
    codes = rand_codes(rng, 203, 5)
    cached = predict_margin_cached(model, codes,
                                   plan=ExecutionPlan.auto())
    direct = model.predict_margin(codes, plan=ExecutionPlan.auto())
    np.testing.assert_allclose(np.asarray(cached), np.asarray(direct),
                               rtol=1e-6, atol=1e-6)
    via_model = model.predict_margin(codes, plan=ExecutionPlan.auto(),
                                     cached=True)
    np.testing.assert_array_equal(np.asarray(cached),
                                  np.asarray(via_model))


# --------------------------------------------------------------------------
# device-resident binned transform
# --------------------------------------------------------------------------
def test_device_binning_matches_host():
    rng = np.random.default_rng(21)
    n, F = 500, 8
    X = rng.normal(size=(n, F)).astype(np.float32).astype(np.float64)
    X[:, 6] = rng.integers(0, 5, n)                  # categorical
    X[:, 7] = rng.integers(0, 3, n)
    X[rng.uniform(size=X.shape) < 0.05] = np.nan
    binner = Binner(max_bins=32, categorical_fields=[6, 7]).fit(X)
    host = binner.transform_codes(X)
    dev = np.asarray(binner.transform_codes_device(X))
    np.testing.assert_array_equal(dev, host)


def test_pipeline_predict_uses_engine_and_matches_direct():
    rng = np.random.default_rng(22)
    n, F = 300, 5
    X = rng.normal(size=(n, F)).astype(np.float32).astype(np.float64)
    binner = Binner(max_bins=N_BINS).fit(X)
    model = _model(rng, F=F)
    pipe = GBDTPipeline(binner=binner, model=model)
    direct = np.asarray(model.predict(binner.transform(X)))
    predict_cache_clear()
    got = np.asarray(pipe.predict(X))
    np.testing.assert_allclose(got, direct, rtol=1e-5, atol=1e-6)
    t0 = predict_cache_stats()["traces"]
    np.testing.assert_allclose(np.asarray(pipe.predict(X[:57])),
                               direct[:57], rtol=1e-5, atol=1e-6)
    # 57 rows pad into a bucket <= 300's: engine may reuse or add ONE
    assert predict_cache_stats()["traces"] <= t0 + 1


# --------------------------------------------------------------------------
# sharded inference (multi-class + plan support)
# --------------------------------------------------------------------------
def test_sharded_predict_multiclass_single_device_mesh():
    """The psum path on a 1-device mesh: exercises specs/combine without
    needing host-platform device emulation."""
    from repro.launch.mesh import make_mesh
    rng = np.random.default_rng(31)
    K = 3
    model = _model(rng, T=2 * K, K=K)
    codes = rand_codes(rng, 128, 5)
    mesh = make_mesh((1, 1), ("data", "model"))
    padded = pad_trees(model, mesh.shape["model"] * K)
    with mesh:
        out = sharded_predict(mesh, padded, codes,
                              plan=ExecutionPlan.auto(
                                  traversal_strategy="reference"))
    want = model.predict_margin(codes)
    assert out.shape == (128, K)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_sharded_predict_rejects_class_splitting_shards():
    """A per-shard tree count not divisible by K would silently scramble
    the round-major class routing — must raise instead."""
    from repro.launch.mesh import make_mesh
    rng = np.random.default_rng(32)
    model = _model(rng, T=3, K=3)
    mesh = make_mesh((1, 1), ("data", "model"))
    bad = dataclasses_replace_trees(model, 4)
    with pytest.raises(ValueError, match="multiple of n_classes"):
        sharded_predict(mesh, bad, rand_codes(rng, 16, 5))


def dataclasses_replace_trees(model, T_new):
    """Pad to a tree count that does NOT respect K-alignment."""
    import dataclasses
    t = model.trees
    pad = T_new - t.feature.shape[0]
    padded = TreeArrays(
        feature=jnp.concatenate(
            [t.feature, jnp.full((pad,) + t.feature.shape[1:], -1,
                                 t.feature.dtype)]),
        threshold=jnp.pad(t.threshold, ((0, pad), (0, 0))),
        is_cat=jnp.pad(t.is_cat, ((0, pad), (0, 0))),
        default_left=jnp.pad(t.default_left, ((0, pad), (0, 0))),
        leaf_value=jnp.pad(t.leaf_value, ((0, pad), (0, 0))))
    return dataclasses.replace(model, trees=padded)


@pytest.mark.slow
def test_sharded_predict_multiclass_matches_single_device():
    """Paper §III-D with a class axis: trees round-robin across 2 model
    shards x 4 data shards, per-class psum."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = r"""
import numpy as np, jax.numpy as jnp
from repro.core import GBDTConfig, bin_dataset, train
from repro.core.inference import pad_trees, sharded_predict
from repro.data import make_tabular
from repro.launch.mesh import make_mesh

X, y, _ = make_tabular(1024, 5, 0, task="multiclass", seed=2)
K = int(y.max()) + 1
data = bin_dataset(X, max_bins=16)
model = train(GBDTConfig(n_trees=3, max_depth=3, objective="multi:softmax",
                         n_classes=K, hist_strategy="scatter"),
              data, y).model
mesh = make_mesh((4, 2), ("data", "model"))
padded = pad_trees(model, 2 * K)
with mesh:
    out = sharded_predict(mesh, padded, data.codes)
ref = model.predict_margin(data.codes)
assert out.shape == (1024, K), out.shape
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("SHARDED_MULTICLASS_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED_MULTICLASS_OK" in out.stdout

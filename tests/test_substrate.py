"""Remaining substrate: LR schedules, prefetch pipeline, mesh helpers,
dataset specs."""
import numpy as np
import jax.numpy as jnp

from repro.data.pipeline import PrefetchIterator, record_shards, token_batches
from repro.data.synthetic import PAPER_DATASETS, paper_dataset
from repro.launch.mesh import data_axes, make_mesh, n_data_shards
from repro.models.optim import (adamw_init, adamw_update, cosine_schedule,
                                get_schedule, wsd_schedule)


def test_cosine_schedule_shape():
    lr0 = float(cosine_schedule(jnp.asarray(0.0), base_lr=1.0, warmup=10,
                                total=100))
    lr_w = float(cosine_schedule(jnp.asarray(10.0), base_lr=1.0, warmup=10,
                                 total=100))
    lr_end = float(cosine_schedule(jnp.asarray(100.0), base_lr=1.0,
                                   warmup=10, total=100))
    assert lr0 == 0.0 and abs(lr_w - 1.0) < 1e-6
    assert abs(lr_end - 0.1) < 1e-6  # min_ratio floor


def test_wsd_schedule_three_phases():
    """MiniCPM WSD: warmup ramp, long flat stage, sharp decay tail."""
    f = lambda s: float(wsd_schedule(jnp.asarray(float(s)), base_lr=1.0,
                                     warmup=10, total=1000))
    assert f(5) < 1.0                         # warming up
    assert abs(f(500) - 1.0) < 1e-6           # stable plateau
    assert abs(f(899) - 1.0) < 1e-6           # still stable at 90%
    assert f(950) < 0.5                       # decaying
    assert f(1000) < 0.02                     # near min at the end
    assert get_schedule("wsd") is wsd_schedule


def test_adamw_moves_toward_gradient():
    params = {"w": jnp.ones((4,))}
    grads = {"w": jnp.ones((4,))}
    state = adamw_init(params)
    p2, s2, gnorm = adamw_update(params, grads, state, lr=0.1,
                                 weight_decay=0.0)
    assert float(gnorm) == 2.0
    assert (np.asarray(p2["w"]) < 1.0).all()
    assert int(s2.step) == 1


def test_prefetch_iterator_preserves_order_and_errors():
    rng = np.random.default_rng(0)
    batches = list(token_batches(rng, vocab=100, batch=2, seq=8,
                                 n_batches=5))
    out = list(PrefetchIterator(iter(batches), depth=2))
    assert len(out) == 5
    for a, b in zip(batches, out):
        np.testing.assert_array_equal(a["tokens"], np.asarray(b["tokens"]))

    def boom():
        yield batches[0]
        raise RuntimeError("stream died")

    it = PrefetchIterator(boom(), depth=1)
    next(it)
    try:
        next(it)
        raise AssertionError("expected the stream error to surface")
    except RuntimeError as e:
        assert "stream died" in str(e)


def test_record_shards_cover_dataset():
    codes = np.arange(20).reshape(10, 2).astype(np.uint8)
    g = np.arange(10.0)
    h = np.ones(10)
    shards = list(record_shards(codes, g, h, shard_size=4))
    assert [s["codes"].shape[0] for s in shards] == [4, 4, 2]
    np.testing.assert_array_equal(
        np.concatenate([s["g"] for s in shards]), g)


def test_mesh_helpers():
    mesh = make_mesh((1, 1), ("data", "model"))
    assert data_axes(mesh) == ("data",)
    assert n_data_shards(mesh) == 1


def test_paper_dataset_specs_match_table_iii():
    assert set(PAPER_DATASETS) == {"iot", "higgs", "allstate", "mq2008",
                                   "flight"}
    assert PAPER_DATASETS["higgs"].n_numeric == 28
    assert PAPER_DATASETS["allstate"].n_categorical == 16
    assert PAPER_DATASETS["flight"].n_categorical == 7
    X, y, cats, spec = paper_dataset("allstate", n_override=100)
    assert X.shape == (100, 32) and len(cats) == 16
    assert np.isnan(X).any()  # missing values present

"""The `repro.api` facade: estimators, ExecutionPlan, unified bundles."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import (BoosterClassifier, BoosterRegressor, ExecutionPlan,
                       load, load_checkpoint, save)
from repro.api.estimator import NotFittedError
from repro.core import GBDTConfig, train
from repro.core.binning import Binner
from repro.core.gbdt import GBDTModel
from repro.core.inference import GBDTPipeline, feature_importance
from repro.data import make_tabular
from repro.kernels import ops, ref


@pytest.fixture(scope="module")
def data():
    X, y, cats = make_tabular(1500, 5, 2, n_cats=6, task="regression",
                              missing_rate=0.03, seed=11)
    return X, y, cats


@pytest.fixture(scope="module")
def fitted(data):
    X, y, cats = data
    est = BoosterRegressor(n_trees=6, max_depth=4, learning_rate=0.3,
                           max_bins=32, categorical_fields=cats, seed=3)
    est.fit(X, y)
    return est


# --------------------------------------------------------------------------
# ExecutionPlan
# --------------------------------------------------------------------------
def test_plan_auto_resolves_for_backend():
    plan = ExecutionPlan.auto()
    # tests pin JAX_PLATFORMS=cpu (conftest), so the software paths win
    assert plan.hist_strategy == "scatter"
    assert plan.partition_strategy == "reference"
    assert plan.traversal_strategy == "reference"
    assert plan.interpret is True
    # idempotent and already-concrete
    assert plan.resolved() == plan


def test_plan_from_config_lifts_legacy_strings():
    cfg = GBDTConfig(hist_strategy="sort", partition_strategy="pallas",
                     traversal_strategy="reference",
                     host_offload_split=True)
    plan = ExecutionPlan.from_config(cfg)
    assert plan.hist_strategy == "sort"
    assert plan.partition_strategy == "pallas"
    assert plan.traversal_strategy == "reference"
    assert plan.host_offload_split is True


def test_plan_rejects_unknown_strategy():
    with pytest.raises(ValueError):
        ExecutionPlan(hist_strategy="warp_speed")


def test_plan_is_hashable_static_arg():
    a = ExecutionPlan.auto()
    b = ExecutionPlan.auto()
    assert hash(a) == hash(b) and a == b


# --------------------------------------------------------------------------
# the PR-1 loose-kwarg shim is gone: ops entry points are plan-only
# --------------------------------------------------------------------------
def test_ops_reject_loose_strategy_kwarg():
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, 8, (300, 4)), jnp.uint8)
    g = jnp.asarray(rng.normal(size=300), jnp.float32)
    h = jnp.asarray(rng.uniform(0, 1, 300), jnp.float32)
    nid = jnp.asarray(rng.integers(0, 2, 300), jnp.int32)
    with pytest.raises(TypeError):
        ops.build_histogram(codes, g, h, nid, n_nodes=2, n_bins=8,
                            strategy="sort")
    with pytest.raises(TypeError):
        ops.build_histogram(codes, g, h, nid, n_nodes=2, n_bins=8,
                            interpret=False)


def test_ops_plan_dispatch_matches_reference():
    rng = np.random.default_rng(1)
    codes = jnp.asarray(rng.integers(0, 8, (200, 3)), jnp.uint8)
    g = jnp.asarray(rng.normal(size=200), jnp.float32)
    h = jnp.asarray(rng.uniform(0, 1, 200), jnp.float32)
    nid = jnp.asarray(rng.integers(0, 2, 200), jnp.int32)
    want = ref.histogram_ref(codes, g, h, nid, 2, 8)
    for s in ("scatter", "sort", "onehot"):
        got = ops.build_histogram(codes, g, h, nid, n_nodes=2, n_bins=8,
                                  plan=ExecutionPlan.auto(hist_strategy=s))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=3e-4, atol=3e-4)


# --------------------------------------------------------------------------
# estimator <-> functional-path parity
# --------------------------------------------------------------------------
def test_estimator_matches_functional_train(data, fitted):
    X, y, cats = data
    binned = Binner(max_bins=32, categorical_fields=cats).fit_transform(X)
    res = train(GBDTConfig(n_trees=6, max_depth=4, learning_rate=0.3,
                           seed=3), binned, y)
    np.testing.assert_allclose(np.asarray(fitted.predict(X)),
                               np.asarray(res.model.predict(binned)),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(fitted.history_["train_loss"],
                               res.history["train_loss"], rtol=1e-6)


def test_get_set_params_roundtrip(data):
    est = BoosterRegressor(n_trees=9, learning_rate=0.05)
    params = est.get_params()
    assert params["n_trees"] == 9 and params["learning_rate"] == 0.05
    est.set_params(n_trees=4, max_depth=3)
    assert est.n_trees == 4 and est.max_depth == 3
    with pytest.raises(ValueError):
        est.set_params(bogus_param=1)
    with pytest.raises(TypeError):
        BoosterRegressor(bogus_param=1)


def test_unfitted_raises(data):
    X, _, _ = data
    with pytest.raises(NotFittedError):
        BoosterRegressor().predict(X)


def test_classifier_labels_and_proba():
    X, y, cats = make_tabular(1200, 6, 2, task="binary", seed=5)
    est = BoosterClassifier(n_trees=8, max_depth=4, learning_rate=0.3,
                            max_bins=32, categorical_fields=cats)
    est.fit(X, y)
    labels = est.predict(X)
    proba = est.predict_proba(X)
    assert set(np.unique(labels)) <= {0, 1}
    assert proba.shape == (1200, 2)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, rtol=1e-6)
    assert (labels == y).mean() > 0.75


def test_warm_start_xgb_model(data, fitted):
    X, y, cats = data
    cont = BoosterRegressor(n_trees=3, max_depth=4, learning_rate=0.3,
                            max_bins=32, categorical_fields=cats, seed=3)
    cont.fit(X, y, xgb_model=fitted)
    assert cont.n_trees_ == fitted.n_trees_ + 3


def test_warm_start_mismatch_raises_early(data, fitted):
    X, y, cats = data
    bad = BoosterRegressor(n_trees=2, max_depth=5, max_bins=32,
                           categorical_fields=cats)
    with pytest.raises(ValueError, match="max_depth"):
        bad.fit(X, y, xgb_model=fitted)


def test_repr_with_array_params():
    est = BoosterRegressor(categorical_fields=np.array([3, 4]), n_trees=2)
    assert "categorical_fields=(3, 4)" in repr(est)
    assert est.get_params()["categorical_fields"] == (3, 4)


def test_xgb_model_wins_over_checkpoints(data, fitted, tmp_path):
    X, y, cats = data
    d = str(tmp_path / "ckpt_conflict")
    first = BoosterRegressor(n_trees=2, max_depth=4, max_bins=32,
                             categorical_fields=cats, seed=3)
    first.fit(X, y, checkpoint_dir=d)
    cont = BoosterRegressor(n_trees=2, max_depth=4, learning_rate=0.3,
                            max_bins=32, categorical_fields=cats, seed=3)
    with pytest.warns(UserWarning, match="xgb_model wins"):
        cont.fit(X, y, xgb_model=fitted, checkpoint_dir=d)
    assert cont.n_trees_ == fitted.n_trees_ + 2


# --------------------------------------------------------------------------
# staged_predict == the training-history prefix ensembles
# --------------------------------------------------------------------------
def test_staged_predict_consistent_with_history(data, fitted):
    X, y, _ = data
    stages = list(fitted.staged_predict(X))
    assert len(stages) == fitted.n_trees_
    np.testing.assert_allclose(np.asarray(stages[-1]),
                               np.asarray(fitted.predict(X)),
                               rtol=1e-5, atol=1e-6)
    # k-th stage's squared-error loss reproduces history["train_loss"][k]
    for k in (0, fitted.n_trees_ - 1):
        loss_k = float(np.mean(0.5 * (np.asarray(stages[k]) - y) ** 2))
        np.testing.assert_allclose(loss_k,
                                   fitted.history_["train_loss"][k],
                                   rtol=1e-5)


# --------------------------------------------------------------------------
# one serialization story
# --------------------------------------------------------------------------
def test_estimator_bundle_roundtrip(data, fitted, tmp_path):
    X, _, _ = data
    path = str(tmp_path / "bundle")
    fitted.save(path)
    est2 = load(path)
    assert isinstance(est2, BoosterRegressor)
    assert est2.get_params()["n_trees"] == fitted.get_params()["n_trees"]
    np.testing.assert_array_equal(np.asarray(est2.predict(X)),
                                  np.asarray(fitted.predict(X)))


def test_pipeline_and_model_share_bundle_format(data, fitted, tmp_path):
    X, _, _ = data
    pipe = fitted.to_pipeline()
    p_path, m_path = str(tmp_path / "pipe"), str(tmp_path / "model")
    save(p_path, pipe)
    save(m_path, fitted.model_)
    pipe2 = load(p_path)
    assert isinstance(pipe2, GBDTPipeline)
    np.testing.assert_array_equal(np.asarray(pipe2.predict(X)),
                                  np.asarray(fitted.predict(X)))
    model2 = load(m_path)
    assert isinstance(model2, GBDTModel)
    codes = fitted.binner_.transform(X)
    # like-for-like path: the estimator serves through the fused engine
    # (1-ulp reassociation vs a direct codes predict), so round-trip
    # exactness is asserted against the same direct call
    np.testing.assert_array_equal(np.asarray(model2.predict(codes)),
                                  np.asarray(fitted.model_.predict(codes)))
    # estimator loader promotes a pipeline bundle (same payload family)
    est_from_pipe = BoosterRegressor.load(p_path)
    np.testing.assert_array_equal(np.asarray(est_from_pipe.predict(X)),
                                  np.asarray(fitted.predict(X)))


def test_checkpoint_flow_and_resume(data, tmp_path):
    X, y, cats = data
    ckpt_dir = str(tmp_path / "ckpt")
    est = BoosterRegressor(n_trees=4, max_depth=3, learning_rate=0.3,
                           max_bins=32, categorical_fields=cats, seed=3)
    est.fit(X, y, checkpoint_dir=ckpt_dir, checkpoint_every=2)
    obj, step = load_checkpoint(ckpt_dir)
    assert step == 4
    np.testing.assert_array_equal(np.asarray(obj.predict(X)),
                                  np.asarray(est.predict(X)))
    # a fresh estimator resumes instead of retraining (0 additional trees)
    est2 = BoosterRegressor(n_trees=4, max_depth=3, learning_rate=0.3,
                            max_bins=32, categorical_fields=cats, seed=3)
    est2.fit(X, y, checkpoint_dir=ckpt_dir, checkpoint_every=2)
    assert est2.n_trees_ == 4
    np.testing.assert_array_equal(np.asarray(est2.predict(X)),
                                  np.asarray(est.predict(X)))


def test_legacy_checkpoint_dir_trains_fresh(data, tmp_path):
    """A checkpoint dir holding only legacy (positional-leaf) payloads
    must not abort fit — it falls back to training from scratch."""
    from repro.distributed import checkpoint as ckpt
    X, y, cats = data
    d = str(tmp_path / "legacy")
    ckpt.save(d, {"a": np.zeros(3)}, step=5)
    est = BoosterRegressor(n_trees=2, max_depth=3, max_bins=16,
                           categorical_fields=cats)
    est.fit(X, y, checkpoint_dir=d)
    assert est.n_trees_ == 2


def test_corrupt_bundle_rejected(fitted, tmp_path):
    import os
    path = str(tmp_path / "bundle")
    fitted.save(path)
    with open(os.path.join(path, "arrays.npz"), "r+b") as f:
        f.seek(30)
        f.write(b"\xde\xad")   # bit-rot: sha256 verification must catch it
    with pytest.raises(FileNotFoundError):
        load(path)


# --------------------------------------------------------------------------
# vectorized feature_importance == the reference double loop
# --------------------------------------------------------------------------
def _importance_reference(model, kind):
    feats = np.asarray(model.trees.feature)
    leaves = np.asarray(model.trees.leaf_value, np.float64)
    imp = np.zeros((model.n_fields,), np.float64)
    T, n_int = feats.shape
    depth = model.max_depth
    for t in range(T):
        for pos in range(n_int):
            f = feats[t, pos]
            if f < 0:
                continue
            if kind == "split":
                imp[f] += 1.0
            else:
                level = (pos + 1).bit_length() - 1
                reps = 2 ** (depth - level)
                base = (pos - (2 ** level - 1)) * reps
                w = reps if kind == "cover" else 1.0
                imp[f] += w * float(np.var(leaves[t, base:base + reps]))
    s = imp.sum()
    return imp / s if s > 0 else imp


@pytest.mark.parametrize("kind", ["split", "gain", "cover"])
def test_feature_importance_vectorized_matches_loop(fitted, kind):
    got = feature_importance(fitted.model_, kind)
    want = _importance_reference(fitted.model_, kind)
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-12)
    np.testing.assert_allclose(fitted.feature_importances_,
                               feature_importance(fitted.model_, "gain"))

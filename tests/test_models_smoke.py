"""Per-arch reduced smoke tests: forward shapes/NaNs, one train step,
prefill->decode parity vs the train-mode forward (assignment deliverable f).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, get_arch, get_smoke
from repro.models import lm, optim

B, S = 2, 16


def _batch(cfg, rng, s=S):
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, s)),
                                   jnp.int32)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(s)[None, None], (3, B, s)).astype(jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, 4, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_train_step(arch_id):
    cfg = get_smoke(arch_id)
    rng = np.random.default_rng(0)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, rng)
    logits = lm.forward_train(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_padded)
    assert not bool(jnp.isnan(logits).any())
    step = jax.jit(lm.make_train_step(cfg))
    p2, opt2, m = step(params, optim.adamw_init(params), batch)
    assert np.isfinite(float(m["loss"]))
    # params actually changed (exact compare — one AdamW step moves norm
    # weights by only ~lr*1 which can sit inside allclose tolerances)
    changed = any(
        not np.array_equal(np.asarray(a, np.float32),
                           np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert changed


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_decode_matches_forward(arch_id):
    cfg = get_smoke(arch_id)
    rng = np.random.default_rng(1)
    params = lm.init_params(cfg, jax.random.PRNGKey(1))
    batch = _batch(cfg, rng)
    _, cache = lm.prefill(cfg, params, batch, cache_dtype=jnp.float32,
                          max_len=S + 1)
    tok = jnp.asarray(rng.integers(0, cfg.vocab, (B, 1)), jnp.int32)
    dec, _ = lm.decode_step(cfg, params, cache, tok,
                            jnp.asarray(S, jnp.int32))
    full = dict(batch)
    full["tokens"] = jnp.concatenate([batch["tokens"], tok], 1)
    if cfg.mrope:
        full["positions"] = jnp.broadcast_to(
            jnp.arange(S + 1)[None, None], (3, B, S + 1)).astype(jnp.int32)
    ref = lm.forward_train(cfg, params, full)[:, S].astype(jnp.float32)
    err = float(jnp.max(jnp.abs(dec - ref)))
    assert err / (float(jnp.max(jnp.abs(ref))) + 1e-6) < 5e-3, err


def test_cell_matrix_covers_assignment():
    """40 cells total; long_500k runs exactly for the sub-quadratic archs."""
    from repro.configs import all_cells
    cells = all_cells()
    assert len(cells) == 40
    long_runs = {a for a, s, ok, _ in cells if s == "long_500k" and ok}
    assert long_runs == {"mamba2-370m", "mixtral-8x22b", "jamba-v0.1-52b"}
    # every non-long cell is runnable
    assert all(ok for a, s, ok, _ in cells if s != "long_500k")


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """The full (dry-run) configs carry the exact assigned dimensions."""
    expect = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "command-r-35b": (40, 8192, 64, 8, 22528, 256000),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "jamba-v0.1-52b": (32, 4096, 32, 8, 14336, 65536),
    }[arch_id]
    cfg = get_arch(arch_id)
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect
    # scan grouping must tile the layer stack exactly
    assert cfg.n_layers % cfg.scan_period() == 0


def test_arch_specials():
    assert get_arch("mamba2-370m").ssm_state == 128
    assert get_arch("mixtral-8x22b").n_experts == 8
    assert get_arch("mixtral-8x22b").sliding_window == 4096
    assert get_arch("llama4-maverick-400b-a17b").n_experts == 128
    assert get_arch("llama4-maverick-400b-a17b").top_k == 1
    assert get_arch("jamba-v0.1-52b").n_experts == 16
    kinds = get_arch("jamba-v0.1-52b").layer_kinds()
    assert sum(1 for m, _ in kinds if m == "attn") == 4   # 1:7 interleave
    assert sum(1 for _, f in kinds if f == "moe") == 16   # every other
    assert get_arch("qwen3-14b").qk_norm
    assert get_arch("whisper-large-v3").encoder_layers == 32
    assert get_arch("minicpm-2b").lr_schedule == "wsd"

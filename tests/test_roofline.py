"""Roofline machinery unit tests (HLO collective parser + term math)."""
import numpy as np

from repro.launch import roofline as rl

_HLO = """
ENTRY %main {
  %p0 = f32[16,128]{1,0} parameter(0)
  %ar = f32[16,128]{1,0} all-reduce(%p0), replica_groups={}
  %ag = bf16[4,256]{1,0} all-gather(%p0), dimensions={0}
  %a2a = (f32[8,8]{1,0}, f32[8,8]{1,0}) all-to-all(%p0, %p0)
  %cps = u8[1024]{0} collective-permute-start(%p0)
  %cpd = u8[1024]{0} collective-permute-done(%cps)
  %rs = f32[2,64]{1,0} reduce-scatter(%p0), dimensions={0}
  %ars = f32[32]{0} all-reduce-start(%p0)
  %ard = f32[32]{0} all-reduce-done(%ars)
}
"""


def test_parse_collectives_counts_and_bytes():
    out = rl.parse_collectives(_HLO)
    # all-reduce: 16*128*4 = 8192 B (x2 ring factor) + async 32*4=128 (x2)
    assert out["all-reduce"]["count"] == 2
    assert out["all-reduce"]["bytes"] == 2 * (16 * 128 * 4) + 2 * (32 * 4)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["bytes"] == 4 * 256 * 2
    # tuple result: both elements counted
    assert out["all-to-all"]["bytes"] == 2 * (8 * 8 * 4)
    # -start counted once, -done skipped
    assert out["collective-permute"]["count"] == 1
    assert out["collective-permute"]["bytes"] == 1024
    assert out["reduce-scatter"]["bytes"] == 2 * 64 * 4


def test_roofline_terms_and_dominance():
    t = rl.roofline_terms(flops_per_chip=197e12, bytes_per_chip=819e9 / 2,
                          coll_bytes_per_chip=0)
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 0.5)
    assert t["dominant"] == "compute"
    np.testing.assert_allclose(t["roofline_fraction"], 1.0)
    t2 = rl.roofline_terms(1e12, 1e12, 1e12)
    assert t2["dominant"] == "collective"  # 20s > 1.2s > 5ms


def test_model_flops_convention():
    assert rl.model_flops("train", 10, 7) == 6 * 10 * 7
    assert rl.model_flops("prefill", 10, 7) == 2 * 10 * 7
    assert rl.model_flops("decode", 10, 7) == 2 * 10 * 7


def test_shape_bytes_dtypes():
    assert rl._shape_bytes("bf16[2,3]{1,0}") == 12
    assert rl._shape_bytes("u8[10]{0}") == 10
    assert rl._shape_bytes("(f32[4]{0}, s32[2]{0})") == 24

"""Step-② split finding: gain correctness vs brute force, missing
direction, categorical one-vs-rest, regularization gates."""
import numpy as np
import jax.numpy as jnp

from repro.core.splits import find_best_splits, find_best_splits_host


def _brute_force(hist, is_cat, lam, gamma, mcw):
    """O(everything) reference over one node."""
    F, NB, _ = hist.shape
    Gp, Hp = hist[0, :, 0].sum(), hist[0, :, 1].sum()
    parent = Gp ** 2 / (Hp + lam)
    best = (-np.inf, -1, -1, 0)
    for f in range(F):
        Gm, Hm = hist[f, NB - 1, 0], hist[f, NB - 1, 1]
        for t in range(NB - 1):
            if is_cat[f]:
                GL0, HL0 = hist[f, t, 0], hist[f, t, 1]
            else:
                GL0 = hist[f, : t + 1, 0].sum()
                HL0 = hist[f, : t + 1, 1].sum()
            for dl in (0, 1):
                GL = GL0 + (Gm if dl else 0.0)
                HL = HL0 + (Hm if dl else 0.0)
                GR, HR = Gp - GL, Hp - HL
                if HL < mcw or HR < mcw:
                    continue
                gain = 0.5 * (GL ** 2 / (HL + lam) + GR ** 2 / (HR + lam)
                              - parent) - gamma
                if gain > best[0] + 1e-12:
                    best = (gain, f, t, dl)
    return best


def test_matches_brute_force():
    rng = np.random.default_rng(0)
    NN, F, NB = 3, 5, 9
    hist = rng.normal(size=(NN, F, NB, 2)).astype(np.float32)
    hist[..., 1] = np.abs(hist[..., 1]) + 0.1
    # per-field totals must agree (density property)
    hist[..., :] = hist[:, :1, :, :]
    is_cat = np.array([False, True, False, True, False])
    got = find_best_splits(jnp.asarray(hist), jnp.asarray(is_cat),
                           jnp.ones((F,), bool), 1.0, 0.0, 0.05)
    for i in range(NN):
        gain, f, t, dl = _brute_force(hist[i], is_cat, 1.0, 0.0, 0.05)
        assert abs(float(got.gain[i]) - gain) < 1e-4
        assert int(got.feature[i]) == f
        assert int(got.threshold[i]) == t
        assert int(got.default_left[i]) == dl


def test_host_offload_matches_device():
    rng = np.random.default_rng(1)
    hist = np.abs(rng.normal(size=(4, 6, 8, 2))).astype(np.float32)
    hist[..., :] = hist[:, :1]
    is_cat = jnp.zeros((6,), bool)
    mask = jnp.ones((6,), bool)
    a = find_best_splits(jnp.asarray(hist), is_cat, mask, 1.0, 0.1, 1.0)
    b = find_best_splits_host(jnp.asarray(hist), is_cat, mask, 1.0, 0.1, 1.0)
    np.testing.assert_allclose(np.asarray(a.gain), np.asarray(b.gain),
                               rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(a.feature),
                                  np.asarray(b.feature))


def test_gamma_suppresses_weak_splits():
    rng = np.random.default_rng(2)
    hist = np.abs(rng.normal(size=(1, 3, 6, 2))).astype(np.float32) * 1e-3
    hist[..., :] = hist[:, :1]
    is_cat = jnp.zeros((3,), bool)
    mask = jnp.ones((3,), bool)
    d = find_best_splits(jnp.asarray(hist), is_cat, mask, 1.0, 1e6, 0.0)
    assert float(d.gain[0]) <= 0.0


def test_field_mask_excludes_fields():
    rng = np.random.default_rng(3)
    hist = np.abs(rng.normal(size=(2, 4, 6, 2))).astype(np.float32)
    hist[..., :] = hist[:, :1]
    is_cat = jnp.zeros((4,), bool)
    mask = jnp.asarray([True, False, False, True])
    d = find_best_splits(jnp.asarray(hist), is_cat, mask, 1.0, 0.0, 0.0)
    assert all(int(f) in (0, 3) for f in np.asarray(d.feature))


def test_missing_bin_tried_both_sides():
    """A node where all signal is in the missing bin: direction matters."""
    NB = 6
    hist = np.zeros((1, 1, NB, 2), np.float32)
    hist[0, 0, 0] = [5.0, 5.0]      # value bin 0
    hist[0, 0, 1] = [-5.0, 5.0]     # value bin 1
    hist[0, 0, NB - 1] = [-8.0, 4.0]  # missing bin, strongly negative
    d = find_best_splits(jnp.asarray(hist), jnp.zeros((1,), bool),
                         jnp.ones((1,), bool), 1.0, 0.0, 0.0)
    # best split: bin<=0 left with missing joining the negative side (right)
    assert float(d.gain[0]) > 0
    assert int(d.default_left[0]) == 0

"""The packed field-group layout (paper §II/§III-B): 4-bit nibble packing
round-trips losslessly, halves the resident binned matrix, and every
training/inference consumer — all six histogram strategies, K in {1, 3},
monolithic, chunked and distributed growers — stays bit-equal to the
plain uint8 path."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api.plan import ExecutionPlan, HIST_STRATEGIES
from repro.core.binning import (PACK_MAX_BINS, Binner, PackedCodes,
                                bin_dataset, pack_nibbles, pack_nibbles_np,
                                unpack_nibbles)
from repro.core.gbdt import GBDTConfig, train, train_streaming
from repro.data.pipeline import (ArraySource, BinnedShardSource,
                                 write_binned_shards)
from repro.kernels import ops


# --------------------------------------------------------------------------
# pack/unpack round-trip properties
# --------------------------------------------------------------------------
@pytest.mark.parametrize("shape", [(1, 1), (7, 3), (16, 8), (33, 15),
                                   (5, 1), (2, 17), (128, 28)])
def test_pack_roundtrip_all_widths(shape):
    """Every field width (even and ragged-odd) round-trips exactly,
    including the missing code (the top bin, 15)."""
    rng = np.random.default_rng(hash(shape) % 2**32)
    codes = rng.integers(0, 16, size=shape, dtype=np.uint8)
    codes.flat[0] = 15                                # the missing bin
    n = shape[-1]
    packed = pack_nibbles(jnp.asarray(codes))
    assert packed.shape[-1] == (n + 1) // 2
    np.testing.assert_array_equal(
        np.asarray(unpack_nibbles(packed, n)), codes)
    # numpy twin agrees with the jnp primitive bit for bit
    np.testing.assert_array_equal(pack_nibbles_np(codes),
                                  np.asarray(packed))


def test_packed_codes_container():
    rng = np.random.default_rng(0)
    codes = rng.integers(0, 16, size=(41, 9), dtype=np.uint8)
    pc = PackedCodes.pack_np(codes)
    assert pc.shape == (41, 9)
    assert pc.nbytes == 41 * 5                        # ceil(9 / 2) bytes/row
    np.testing.assert_array_equal(np.asarray(pc.unpack()), codes)
    # leading-axis gather preserves the packed form
    idx = np.array([3, 3, 40, 0])
    np.testing.assert_array_equal(np.asarray(pc[idx].unpack()), codes[idx])
    # pytree: flows through jit with the logical width as static aux
    out = jax.jit(lambda p: p.unpack())(pc)
    np.testing.assert_array_equal(np.asarray(out), codes)


def test_pack_rejects_wide_bins():
    with pytest.raises(ValueError):
        bin_dataset(np.random.default_rng(1).normal(size=(32, 3)),
                    max_bins=64, packed=True)


# --------------------------------------------------------------------------
# resident-layout accounting
# --------------------------------------------------------------------------
def test_resident_bytes_halve():
    """n_bins <= 16 auto-packs BOTH layouts: combined residency ~n*F
    bytes instead of 2*n*F (paper Table II's compressed representation)."""
    rng = np.random.default_rng(2)
    X = rng.normal(size=(512, 10)).astype(np.float32)
    dp = bin_dataset(X, max_bins=PACK_MAX_BINS)
    du = bin_dataset(X, max_bins=PACK_MAX_BINS, packed=False)
    assert isinstance(dp.codes, PackedCodes)
    assert isinstance(dp.codes_cm, PackedCodes)
    packed_bytes = dp.codes.nbytes + dp.codes_cm.nbytes
    plain_bytes = du.codes.nbytes + du.codes_cm.nbytes
    assert plain_bytes == 2 * 512 * 10
    assert packed_bytes <= plain_bytes // 2 + 512 + 10   # ceil slack only
    # wider binnings never pack implicitly
    d64 = bin_dataset(X, max_bins=64)
    assert not isinstance(d64.codes, PackedCodes)


def test_chunk_rows_reflects_packing():
    """The out-of-core budget model charges 1 byte/field when packed,
    2 bytes (codes + chunk-local transpose) when not."""
    F, K = 20, 3
    packed = ExecutionPlan(packed_codes=True).chunk_rows(F, K)
    plain = ExecutionPlan(packed_codes=False).chunk_rows(F, K)
    budget = ExecutionPlan.DEFAULT_CHUNK_BYTES
    assert plain == max(256, budget // (2 * F + 12 * K))
    assert packed == max(256, budget // (F + 12 * K))
    assert packed > plain


# --------------------------------------------------------------------------
# bit-equality: histograms across every strategy x K
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", HIST_STRATEGIES)
@pytest.mark.parametrize("K", [1, 3])
def test_histogram_bit_equal_packed(strategy, K):
    rng = np.random.default_rng(7)
    n, F, n_bins, nn = 257, 9, 16, 4
    codes = rng.integers(0, n_bins, size=(n, F), dtype=np.uint8)
    g = rng.normal(size=(K, n)).astype(np.float32)
    h = rng.uniform(0.5, 2.0, size=(K, n)).astype(np.float32)
    node = rng.integers(0, nn, size=(K, n)).astype(np.int32)
    if K == 1:
        g, h, node = g[0], h[0], node[0]
    plan = ExecutionPlan(hist_strategy=strategy).resolved()
    ref = ops.build_histogram(jnp.asarray(codes), jnp.asarray(g),
                              jnp.asarray(h), jnp.asarray(node),
                              n_nodes=nn, n_bins=n_bins, plan=plan)
    got = ops.build_histogram(PackedCodes.pack_np(codes), jnp.asarray(g),
                              jnp.asarray(h), jnp.asarray(node),
                              n_nodes=nn, n_bins=n_bins, plan=plan)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# --------------------------------------------------------------------------
# bit-equality: end-to-end training, monolithic + chunked
# --------------------------------------------------------------------------
@pytest.mark.parametrize("objective,K", [("binary:logistic", None),
                                         ("multi:softmax", 3)])
def test_train_bit_equal_packed(objective, K):
    rng = np.random.default_rng(11)
    X = rng.normal(size=(500, 8)).astype(np.float32)
    X[rng.random(X.shape) < 0.04] = np.nan
    if K is None:
        y = (X[:, 0] > 0).astype(np.float32)
    else:
        y = rng.integers(0, K, size=500).astype(np.float32)
    dp = bin_dataset(X, max_bins=16)
    du = bin_dataset(X, max_bins=16, packed=False)
    cfg = GBDTConfig(n_trees=4, max_depth=4, objective=objective,
                     n_classes=K)
    rp, ru = train(cfg, dp, y), train(cfg, du, y)
    assert rp.history["train_loss"] == ru.history["train_loss"]
    for f in rp.model.trees._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rp.model.trees, f)),
            np.asarray(getattr(ru.model.trees, f)))
    # predictions agree regardless of which layout feeds inference
    np.testing.assert_array_equal(
        np.asarray(rp.model.predict_margin(dp.codes)),
        np.asarray(ru.model.predict_margin(du.codes)))


def test_train_streaming_bit_equal_packed():
    """The chunked grower consumes PackedCodes chunks (half the host ->
    device bytes) and reproduces the uint8 stream bit for bit — and both
    match the monolithic grower."""
    rng = np.random.default_rng(13)
    X = rng.normal(size=(600, 7)).astype(np.float32)
    y = (X[:, 0] - X[:, 3] > 0).astype(np.float32)
    src = ArraySource(X, y)
    binner = Binner(max_bins=16).fit(X)
    cfg = GBDTConfig(n_trees=3, max_depth=3, objective="binary:logistic")
    rp = train_streaming(cfg, src, binner, y, chunk_rows=144)
    ru = train_streaming(cfg, src, binner, y, chunk_rows=144,
                         plan=ExecutionPlan(packed_codes=False))
    rm = train(cfg, binner.transform(X), y)
    assert rp.history["train_loss"] == ru.history["train_loss"]
    assert rp.history["train_loss"] == rm.history["train_loss"]


def test_train_streaming_rejects_packed_wide_bins():
    rng = np.random.default_rng(17)
    X = rng.normal(size=(300, 5)).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.float32)
    binner = Binner(max_bins=64).fit(X)
    cfg = GBDTConfig(n_trees=1, max_depth=2, objective="binary:logistic")
    with pytest.raises(ValueError, match="packed"):
        train_streaming(cfg, ArraySource(X, y), binner, y,
                        plan=ExecutionPlan(packed_codes=True))


# --------------------------------------------------------------------------
# bit-equality: distributed grower
# --------------------------------------------------------------------------
@pytest.mark.parametrize("n", [403, 408])   # odd/even per-shard parity
def test_train_distributed_bit_equal_packed(n):
    from repro.distributed.trainer import (data_parallel_mesh,
                                           train_distributed)
    rng = np.random.default_rng(19)
    X = rng.normal(size=(n, 6)).astype(np.float32)
    y = (X[:, 0] + X[:, 1] > 0).astype(np.float32)
    dp = bin_dataset(X, max_bins=16)
    du = bin_dataset(X, max_bins=16, packed=False)
    cfg = GBDTConfig(n_trees=3, max_depth=3, objective="binary:logistic")
    mesh = data_parallel_mesh(jax.devices())
    rp = train_distributed(cfg, dp, y, mesh=mesh)
    ru = train_distributed(cfg, du, y, mesh=mesh)
    assert rp.history["train_loss"] == ru.history["train_loss"]
    for f in rp.model.trees._fields:
        np.testing.assert_array_equal(
            np.asarray(getattr(rp.model.trees, f)),
            np.asarray(getattr(ru.model.trees, f)))


def test_distributed_histogram_accepts_packed():
    from repro.distributed.sharding import distributed_histogram
    from repro.launch.mesh import make_mesh
    n_dev = len(jax.devices())
    if n_dev < 2:
        pytest.skip("needs >= 2 devices")
    mesh = make_mesh((n_dev, 1), ("data", "model"))
    rng = np.random.default_rng(23)
    n, F, n_bins, nn = 8 * n_dev, 4, 16, 2
    codes = rng.integers(0, n_bins, size=(n, F), dtype=np.uint8)
    g = rng.normal(size=(n,)).astype(np.float32)
    h = np.ones((n,), np.float32)
    node = rng.integers(0, nn, size=(n,)).astype(np.int32)
    ref = distributed_histogram(mesh, jnp.asarray(codes), jnp.asarray(g),
                                jnp.asarray(h), jnp.asarray(node),
                                n_nodes=nn, n_bins=n_bins)
    got = distributed_histogram(mesh, PackedCodes.pack_np(codes),
                                jnp.asarray(g), jnp.asarray(h),
                                jnp.asarray(node), n_nodes=nn,
                                n_bins=n_bins)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(got))


# --------------------------------------------------------------------------
# packed binned npz shards
# --------------------------------------------------------------------------
def test_binned_shards_roundtrip_packed(tmp_path):
    rng = np.random.default_rng(29)
    X = rng.normal(size=(330, 6)).astype(np.float32)
    y = (X[:, 0] > 0).astype(np.float32)
    binner = Binner(max_bins=16).fit(X)
    paths = write_binned_shards(str(tmp_path), ArraySource(X, y), binner,
                                rows_per_shard=128)
    assert len(paths) == 3
    src = BinnedShardSource(str(tmp_path))
    assert src.packed and src.n_fields == 6
    expect = binner.transform_codes(X)
    got, got_y = [], []
    for chunk, yc in src.chunks(100):
        assert isinstance(chunk, PackedCodes)
        got.append(np.asarray(chunk.unpack()))
        got_y.append(yc)
    np.testing.assert_array_equal(np.concatenate(got), expect)
    np.testing.assert_array_equal(np.concatenate(got_y), y)
    # shard files hold half the code bytes of the uint8 encoding
    code_bytes = sum(np.load(p)["codes"].nbytes for p in paths)
    assert code_bytes == 330 * 3                      # ceil(6/2) per row


def test_binned_shards_plain_when_wide(tmp_path):
    rng = np.random.default_rng(31)
    X = rng.normal(size=(100, 4)).astype(np.float32)
    binner = Binner(max_bins=64).fit(X)
    write_binned_shards(str(tmp_path), ArraySource(X), binner,
                        rows_per_shard=64)
    src = BinnedShardSource(str(tmp_path))
    assert not src.packed
    chunks = [c for c, _ in src.chunks(64)]
    np.testing.assert_array_equal(np.concatenate(chunks),
                                  binner.transform_codes(X))

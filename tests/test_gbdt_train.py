"""End-to-end GBDT training: accuracy, invariances, paper-claimed
numerical neutrality of the software optimizations."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import GBDTConfig, bin_dataset, train
from repro.core.binning import BinnedDataset
from repro.data import make_tabular


def _split(data: BinnedDataset, y, n_tr):
    def sub(sl):
        return BinnedDataset(
            data.codes[sl],
            jnp.asarray(np.asarray(data.codes[sl]).T.copy()),
            data.is_categorical, data.n_bins, data.bin_edges,
            data.n_value_bins)
    return sub(slice(0, n_tr)), y[:n_tr], sub(slice(n_tr, None)), y[n_tr:]


@pytest.fixture(scope="module")
def reg_data():
    X, y, cats = make_tabular(2000, 8, 4, n_cats=10, task="regression",
                              missing_rate=0.05, seed=3)
    data = bin_dataset(X, max_bins=64, categorical_fields=cats)
    return _split(data, y, 1600)


@pytest.fixture(scope="module")
def cls_data():
    X, y, cats = make_tabular(1500, 10, 2, task="binary", seed=7)
    data = bin_dataset(X, max_bins=32, categorical_fields=cats)
    return _split(data, y, 1200)


def test_regression_learns(reg_data):
    tr, ytr, te, yte = reg_data
    res = train(GBDTConfig(n_trees=30, max_depth=5, learning_rate=0.3,
                           hist_strategy="scatter"), tr, ytr,
                eval_set=(te, jnp.asarray(yte)))
    pred = np.asarray(res.model.predict(te))
    r2 = 1 - np.mean((pred - yte) ** 2) / np.var(yte)
    assert r2 > 0.7, r2
    assert res.history["train_loss"][-1] < res.history["train_loss"][0] / 5


def test_classification_learns(cls_data):
    tr, ytr, te, yte = cls_data
    res = train(GBDTConfig(n_trees=20, max_depth=4, learning_rate=0.3,
                           objective="binary:logistic",
                           hist_strategy="scatter"), tr, ytr)
    acc = float(((np.asarray(res.model.predict(te)) > .5) == yte).mean())
    assert acc > 0.75, acc


def test_lossguide_learns(reg_data):
    tr, ytr, te, yte = reg_data
    res = train(GBDTConfig(n_trees=10, max_depth=5, learning_rate=0.3,
                           grow_policy="lossguide", max_leaves=16,
                           hist_strategy="scatter"), tr, ytr)
    pred = np.asarray(res.model.predict(te))
    r2 = 1 - np.mean((pred - yte) ** 2) / np.var(yte)
    assert r2 > 0.5, r2


@pytest.mark.slow
def test_strategies_grow_identical_trees(reg_data):
    """Paper §IV: 'software changes ... do not affect the numerical
    results'.  scatter / sort / one-hot MXU / packed produce the same
    ensemble (same splits; leaf values to fp tolerance)."""
    tr, ytr, _, _ = reg_data
    cfgs = [GBDTConfig(n_trees=5, max_depth=4, hist_strategy=s)
            for s in ("scatter", "sort", "onehot", "pallas_grouped")]
    results = [train(c, tr, ytr) for c in cfgs]
    t0 = results[0].model.trees
    for r in results[1:]:
        np.testing.assert_array_equal(np.asarray(r.model.trees.feature),
                                      np.asarray(t0.feature))
        np.testing.assert_array_equal(np.asarray(r.model.trees.threshold),
                                      np.asarray(t0.threshold))
        np.testing.assert_allclose(np.asarray(r.model.trees.leaf_value),
                                   np.asarray(t0.leaf_value),
                                   rtol=1e-4, atol=1e-5)


def test_pallas_partition_and_traversal_match_reference(reg_data):
    tr, ytr, _, _ = reg_data
    a = train(GBDTConfig(n_trees=4, max_depth=4,
                         hist_strategy="scatter",
                         partition_strategy="reference",
                         traversal_strategy="reference"), tr, ytr)
    b = train(GBDTConfig(n_trees=4, max_depth=4,
                         hist_strategy="scatter",
                         partition_strategy="pallas",
                         traversal_strategy="pallas"), tr, ytr)
    np.testing.assert_allclose(a.history["train_loss"],
                               b.history["train_loss"], rtol=1e-5)


def test_subsample_colsample_run(reg_data):
    tr, ytr, _, _ = reg_data
    res = train(GBDTConfig(n_trees=6, max_depth=4, subsample=0.7,
                           colsample_bytree=0.7, hist_strategy="scatter"),
                tr, ytr)
    assert res.history["train_loss"][-1] < res.history["train_loss"][0]


def test_early_stopping(reg_data):
    tr, ytr, te, yte = reg_data
    res = train(GBDTConfig(n_trees=60, max_depth=6, learning_rate=0.8,
                           early_stopping_rounds=3,
                           hist_strategy="scatter"),
                tr, ytr, eval_set=(te, jnp.asarray(yte)))
    assert res.model.n_trees < 60  # aggressive LR overfits -> stops early


def test_deterministic_replay(reg_data):
    """Same seed -> bit-identical ensembles (fault-tolerant replay)."""
    tr, ytr, _, _ = reg_data
    cfg = GBDTConfig(n_trees=5, max_depth=4, subsample=0.8, seed=13,
                     hist_strategy="scatter")
    a, b = train(cfg, tr, ytr), train(cfg, tr, ytr)
    for fa, fb in zip(a.model.trees, b.model.trees):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_warm_start_continues(reg_data):
    tr, ytr, _, _ = reg_data
    cfg = GBDTConfig(n_trees=4, max_depth=4, hist_strategy="scatter")
    first = train(cfg, tr, ytr)
    cont = train(cfg, tr, ytr, init_model=first.model)
    assert cont.model.n_trees == 8
    assert cont.history["train_loss"][-1] <= first.history["train_loss"][-1]

"""Histogram-subtraction level growers + fused boosting rounds.

Parity contract (documented float tolerance): the derived sibling
``parent − smaller`` reassociates the parent's float32 sum, so subtraction
histograms match the direct pass to ~ulp(parent) per bucket — NOT bitwise.
Split decisions argmax over well-separated gains, so trees come out
*structurally identical* on generic data; leaf values (segment sums over
the same final partition) match to float tolerance.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api.plan import HIST_STRATEGIES, ExecutionPlan
from repro.core import GBDTConfig, bin_dataset, train
from repro.core import splits as splits_mod
from repro.core import tree as tree_mod
from repro.core.binning import BinnedDataset
from repro.data import make_tabular
from repro.kernels import ops


def _dataset(n=900, seed=5, max_bins=32):
    X, y, cats = make_tabular(n, 6, 2, n_cats=8, task="regression",
                              missing_rate=0.05, seed=seed)
    return bin_dataset(X, max_bins=max_bins, categorical_fields=cats), y


def _stats(n, K, seed=0):
    rng = np.random.default_rng(seed)
    g = np.asarray(rng.normal(size=(K, n)), np.float32)
    h = np.abs(np.asarray(rng.normal(size=(K, n)), np.float32)) + 0.1
    return g, h


def _grow_kwargs(data, depth=4):
    F = data.codes.shape[1]
    return dict(depth=depth, n_bins=data.n_bins,
                missing_bin=data.missing_bin,
                is_cat_field=data.is_categorical,
                field_mask=jnp.ones((F,), bool), lambda_=1.0, gamma=0.0,
                min_child_weight=1.0)


def _chunks(codes_np, rows):
    n = codes_np.shape[0]

    def it():
        for lo in range(0, n, rows):
            hi = min(lo + rows, n)
            c = codes_np[lo:hi]
            if c.shape[0] < rows:
                c = np.pad(c, ((0, rows - c.shape[0]), (0, 0)))
            yield lo, hi, c
    return it


def _assert_tree_parity(a, b, rtol=1e-4, atol=1e-5):
    np.testing.assert_array_equal(np.asarray(a.feature), np.asarray(b.feature))
    np.testing.assert_array_equal(np.asarray(a.threshold),
                                  np.asarray(b.threshold))
    np.testing.assert_array_equal(np.asarray(a.is_cat), np.asarray(b.is_cat))
    # default_left is NOT asserted bitwise: when a node sees no missing
    # records in its chosen feature, both missing directions have exactly
    # equal gain and the ~ulp residual in a derived sibling histogram
    # breaks the tie arbitrarily — a don't-care bit (no record routes
    # through it during training).  Routing of records that DO exist is
    # covered by the leaf-value check (same final partition).
    np.testing.assert_allclose(np.asarray(a.leaf_value),
                               np.asarray(b.leaf_value), rtol=rtol, atol=atol)


# --------------------------------------------------------------------------
# histogram-level parity: derived siblings match the direct pass
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", HIST_STRATEGIES)
@pytest.mark.parametrize("K", [1, 3])
def test_subtraction_level_hist_matches_direct(strategy, K):
    data, _ = _dataset()
    n, F = data.codes.shape
    g, h = _stats(n, K)
    gd, hd = jnp.asarray(g), jnp.asarray(h)
    plan = ExecutionPlan(hist_strategy=strategy).resolved()
    rng = np.random.default_rng(3)
    # a realistic level-1 partition: children 2p/2p+1 of 2 parents
    node_ids = jnp.asarray(rng.integers(0, 4, size=(K, n)), jnp.int32)
    parent = ops.build_histogram(data.codes, gd, hd, node_ids // 2,
                                 n_nodes=2, n_bins=data.n_bins, plan=plan)
    direct = ops.build_histogram(data.codes, gd, hd, node_ids,
                                 n_nodes=4, n_bins=data.n_bins, plan=plan)
    sub = tree_mod._subtract_level_hist(data.codes, gd, hd, node_ids,
                                        parent, n_nodes=4,
                                        n_bins=data.n_bins, plan=plan)
    scale = float(jnp.max(jnp.abs(parent)))
    np.testing.assert_allclose(np.asarray(sub), np.asarray(direct),
                               rtol=1e-4, atol=1e-5 * max(scale, 1.0))


# --------------------------------------------------------------------------
# grower-level parity: subtraction-vs-direct, monolithic and chunked,
# all 6 strategies x K in {1, 3}
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", HIST_STRATEGIES)
@pytest.mark.parametrize("K", [1, 3])
def test_monolithic_grower_parity(strategy, K):
    data, _ = _dataset()
    n, F = data.codes.shape
    g, h = _stats(n, K)
    kw = _grow_kwargs(data)
    direct = tree_mod.fit_forest(
        data.codes, data.codes_cm, jnp.asarray(g), jnp.asarray(h),
        plan=ExecutionPlan(hist_strategy=strategy).resolved(), **kw)
    sub = tree_mod.fit_forest(
        data.codes, data.codes_cm, jnp.asarray(g), jnp.asarray(h),
        plan=ExecutionPlan(hist_strategy=strategy,
                           hist_subtraction=True).resolved(), **kw)
    _assert_tree_parity(direct, sub)


@pytest.mark.parametrize("strategy", HIST_STRATEGIES)
@pytest.mark.parametrize("K", [1, 3])
def test_chunked_grower_parity(strategy, K):
    data, _ = _dataset()
    n, F = data.codes.shape
    g, h = _stats(n, K)
    codes_np = np.asarray(data.codes)
    kw = _grow_kwargs(data)
    direct, nid_d = tree_mod.fit_forest_chunked(
        _chunks(codes_np, 256), g, h,
        plan=ExecutionPlan(hist_strategy=strategy).resolved(), **kw)
    sub, nid_s = tree_mod.fit_forest_chunked(
        _chunks(codes_np, 256), g, h,
        plan=ExecutionPlan(hist_strategy=strategy,
                           hist_subtraction=True).resolved(), **kw)
    _assert_tree_parity(direct, sub)
    np.testing.assert_array_equal(nid_d, nid_s)


def test_chunked_matches_monolithic_under_subtraction():
    """Same trees from the in-memory and out-of-core subtraction growers
    (their smaller-child selections may differ — count- vs hessian-based —
    but the derived histograms agree to tolerance, so the argmaxes do)."""
    data, _ = _dataset()
    n, F = data.codes.shape
    g, h = _stats(n, 1)
    kw = _grow_kwargs(data)
    plan = ExecutionPlan(hist_strategy="scatter",
                         hist_subtraction=True).resolved()
    mono = tree_mod.fit_forest(data.codes, data.codes_cm, jnp.asarray(g),
                               jnp.asarray(h), plan=plan, **kw)
    chunked, _ = tree_mod.fit_forest_chunked(
        _chunks(np.asarray(data.codes), 200), g, h, plan=plan, **kw)
    _assert_tree_parity(mono, chunked)


# --------------------------------------------------------------------------
# counts channel: SplitDecision.left_h equals the left child's hessian mass
# --------------------------------------------------------------------------
def test_split_decision_left_h_matches_partition():
    data, _ = _dataset()
    n, F = data.codes.shape
    g, h = _stats(n, 1)
    gd, hd = jnp.asarray(g[0]), jnp.asarray(h[0])
    nid = jnp.zeros((n,), jnp.int32)
    hist = ops.build_histogram(data.codes, gd, hd, nid, n_nodes=1,
                               n_bins=data.n_bins,
                               plan=ExecutionPlan().resolved())
    best = splits_mod.find_best_splits(hist, data.is_categorical,
                                       jnp.ones((F,), bool), 1.0, 0.0, 1.0)
    assert float(best.gain[0]) > 0
    # route the records with the chosen split and sum hessians on the left
    child = ops.partition_level(
        nid, data.codes_cm[best.feature].T, jnp.zeros((1,), jnp.int32),
        best.threshold, best.is_cat, best.default_left,
        missing_bin=data.missing_bin, plan=ExecutionPlan().resolved())
    hl = float(jnp.sum(jnp.where(child == 0, hd, 0.0)))
    np.testing.assert_allclose(float(best.left_h[0]), hl, rtol=1e-5)
    # host-offloaded twin carries the same channel
    best_host = splits_mod.find_best_splits_host(
        hist, data.is_categorical, jnp.ones((F,), bool), 1.0, 0.0, 1.0)
    np.testing.assert_allclose(float(best_host.left_h[0]),
                               float(best.left_h[0]), rtol=1e-6)


# --------------------------------------------------------------------------
# donated chunked accumulator stays correct when rebound in a loop
# --------------------------------------------------------------------------
def test_accumulate_histogram_rebinding():
    """The jitted (accumulator-donating) accumulate stays bit-equal to the
    monolithic pass when rebound chunk-by-chunk in a loop.  Integer-valued
    stats keep float accumulation order-independent (the same trick as
    test_streaming's bit-equality matrix), so the assert is bit-strict."""
    data, _ = _dataset(n=400)
    n, F = data.codes.shape
    rng = np.random.default_rng(7)
    gd = jnp.asarray(rng.integers(-8, 9, (1, n)), jnp.float32)
    hd = jnp.asarray(rng.integers(0, 5, (1, n)), jnp.float32)
    nid = jnp.zeros((1, n), jnp.int32)
    plan = ExecutionPlan().resolved()
    full = ops.build_histogram(data.codes, gd, hd, nid, n_nodes=1,
                               n_bins=data.n_bins, plan=plan)
    acc = jnp.zeros_like(full)
    for lo in range(0, n, 128):
        hi = min(lo + 128, n)
        acc = ops.accumulate_histogram(
            acc, data.codes[lo:hi], gd[:, lo:hi], hd[:, lo:hi],
            nid[:, lo:hi], n_nodes=1, n_bins=data.n_bins, plan=plan)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(acc))


# --------------------------------------------------------------------------
# fused boosting rounds: trajectory parity vs the host-driven loop
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def boost_data():
    X, y, cats = make_tabular(1800, 8, 4, n_cats=10, task="regression",
                              missing_rate=0.05, seed=3)
    data = bin_dataset(X, max_bins=64, categorical_fields=cats)

    def sub(sl):
        return BinnedDataset(
            data.codes[sl],
            jnp.asarray(np.asarray(data.codes[sl]).T.copy()),
            data.is_categorical, data.n_bins, data.bin_edges,
            data.n_value_bins)
    return sub(slice(0, 1400)), y[:1400], sub(slice(1400, None)), y[1400:]


@pytest.mark.parametrize("kw", [
    dict(),
    dict(subsample=0.7, colsample_bytree=0.7),
    dict(objective="binary:logistic"),
])
def test_fused_rounds_trajectory_parity(boost_data, kw):
    """Fusing a round into one XLA program lets the compiler reassociate
    float chains (e.g. ``-G/(H+λ) * lr``), so margins drift by ulps and a
    near-tied split in a later round may flip — round 0 is bit-identical
    (identical inputs), and the loss trajectory and predictions agree to
    float tolerance throughout."""
    tr, ytr, te, _ = boost_data
    if kw.get("objective") == "binary:logistic":
        ytr = (np.asarray(ytr) > np.median(ytr)).astype(np.float32)
    a = train(GBDTConfig(n_trees=6, max_depth=4, hist_strategy="scatter",
                         **kw), tr, ytr)
    b = train(GBDTConfig(n_trees=6, max_depth=4, hist_strategy="scatter",
                         fused_rounds=True, **kw), tr, ytr)
    for fa, fb in zip(a.model.trees[:4], b.model.trees[:4]):
        np.testing.assert_array_equal(np.asarray(fa)[0], np.asarray(fb)[0])
    np.testing.assert_allclose(a.history["train_loss"],
                               b.history["train_loss"], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a.model.predict(te)),
                               np.asarray(b.model.predict(te)),
                               rtol=1e-3, atol=1e-4)


def test_fused_rounds_goss_losses_match(boost_data):
    """GOSS ranks records by |g|; ulp-level margin differences between the
    fused and host loops can flip near-ties in that ranking, so structural
    equality is not guaranteed — the loss trajectories still agree."""
    tr, ytr, _, _ = boost_data
    kw = dict(n_trees=6, max_depth=4, hist_strategy="scatter",
              goss_top_rate=0.2, goss_other_rate=0.2)
    a = train(GBDTConfig(**kw), tr, ytr)
    b = train(GBDTConfig(fused_rounds=True, **kw), tr, ytr)
    np.testing.assert_allclose(a.history["train_loss"],
                               b.history["train_loss"], rtol=1e-4)


def test_fused_rounds_multiclass_parity(boost_data):
    tr, ytr, _, _ = boost_data
    y3 = np.digitize(np.asarray(ytr),
                     np.quantile(np.asarray(ytr), [0.33, 0.66]))
    kw = dict(n_trees=4, max_depth=3, objective="multi:softmax",
              n_classes=3, hist_strategy="scatter")
    a = train(GBDTConfig(**kw), tr, y3.astype(np.float32))
    b = train(GBDTConfig(fused_rounds=True, **kw), tr,
              y3.astype(np.float32))
    for fa, fb in zip(a.model.trees[:4], b.model.trees[:4]):
        # round 0 (the first K class trees) sees bit-identical inputs
        np.testing.assert_array_equal(np.asarray(fa)[:3], np.asarray(fb)[:3])
    np.testing.assert_allclose(a.history["train_loss"],
                               b.history["train_loss"], rtol=1e-4)


def test_fused_rounds_early_stopping_matches(boost_data):
    tr, ytr, te, yte = boost_data
    kw = dict(n_trees=40, max_depth=5, learning_rate=0.5,
              early_stopping_rounds=3, hist_strategy="scatter")
    a = train(GBDTConfig(**kw), tr, ytr, eval_set=(te, jnp.asarray(yte)))
    b = train(GBDTConfig(fused_rounds=True, **kw), tr, ytr,
              eval_set=(te, jnp.asarray(yte)))
    assert a.model.n_trees == b.model.n_trees
    assert len(b.history["eval_loss"]) == b.model.n_trees
    assert "fused_rounds" in b.step_times


def test_fused_plus_subtraction_end_to_end(boost_data):
    """The acceptance path: fused rounds + hist_subtraction together
    reproduce the baseline trainer's trajectory (same float-tolerance
    contract as each optimization alone)."""
    tr, ytr, te, _ = boost_data
    plan = ExecutionPlan(hist_strategy="scatter",
                         hist_subtraction=True).resolved()
    a = train(GBDTConfig(n_trees=6, max_depth=5, hist_strategy="scatter"),
              tr, ytr)
    b = train(GBDTConfig(n_trees=6, max_depth=5, fused_rounds=True),
              tr, ytr, plan=plan)
    np.testing.assert_allclose(a.history["train_loss"],
                               b.history["train_loss"], rtol=1e-4)
    np.testing.assert_allclose(np.asarray(a.model.predict(te)),
                               np.asarray(b.model.predict(te)),
                               rtol=1e-3, atol=1e-4)


def test_fused_rounds_rejects_lossguide():
    with pytest.raises(ValueError, match="fused_rounds"):
        GBDTConfig(fused_rounds=True, grow_policy="lossguide")


def test_streaming_subtraction_trajectory_parity():
    from repro.api import BoosterRegressor
    from repro.data.synthetic import SyntheticSource

    src = SyntheticSource(3000, 10, seed=0)
    kw = dict(n_trees=4, max_depth=4, learning_rate=0.3, max_bins=32)
    base = BoosterRegressor(**kw)
    base.fit(data=src, plan=ExecutionPlan(chunk_bytes=40_000))
    sub = BoosterRegressor(**kw)
    sub.fit(data=src, plan=ExecutionPlan(chunk_bytes=40_000,
                                         hist_subtraction=True))
    for fa, fb in zip(base.model_.trees[:4], sub.model_.trees[:4]):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))
    np.testing.assert_allclose(base.history_["train_loss"],
                               sub.history_["train_loss"], rtol=1e-5)

"""Step-① histogram kernel: every strategy vs the scatter oracle, across a
shape/dtype sweep, plus the paper's structural invariants."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan
from repro.kernels import ops, ref

STRATEGIES = ["scatter", "scatter_private", "sort", "onehot",
              "pallas_grouped", "pallas_packed"]


def _plan(strategy, **kw):
    return ExecutionPlan.auto(hist_strategy=strategy, **kw)


def _data(n, F, NB, NN, seed=0, gdtype=jnp.float32):
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, NB, (n, F)), jnp.uint8)
    g = jnp.asarray(rng.normal(size=n), gdtype)
    h = jnp.asarray(rng.uniform(0.1, 1.0, n), gdtype)
    nid = jnp.asarray(rng.integers(0, NN, n), jnp.int32)
    return codes, g, h, nid


@pytest.mark.parametrize("strategy", STRATEGIES)
@pytest.mark.parametrize("n,F,NB,NN", [
    (64, 3, 8, 1),        # tiny
    (777, 13, 16, 4),     # ragged record count (padding path)
    (1024, 8, 32, 8),     # block-aligned
    (300, 1, 4, 2),       # single field
    (515, 33, 16, 1),     # ragged field count (field padding path)
])
def test_strategies_match_oracle(strategy, n, F, NB, NN):
    codes, g, h, nid = _data(n, F, NB, NN)
    want = ref.histogram_ref(codes, g, h, nid, NN, NB)
    got = ops.build_histogram(codes, g, h, nid, n_nodes=NN, n_bins=NB,
                              plan=_plan(strategy))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("strategy", ["pallas_grouped", "pallas_packed"])
@pytest.mark.parametrize("gdtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(strategy, gdtype):
    codes, g, h, nid = _data(513, 5, 16, 4, seed=3, gdtype=gdtype)
    want = ref.histogram_ref(codes, g.astype(jnp.float32),
                             h.astype(jnp.float32), nid, 4, 16)
    got = ops.build_histogram(codes, g, h, nid, n_nodes=4, n_bins=16,
                              plan=_plan(strategy))
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("rblk,fblk", [(64, 2), (128, 4), (256, 8)])
def test_kernel_block_shape_sweep(rblk, fblk):
    codes, g, h, nid = _data(1000, 9, 8, 2, seed=5)
    want = ref.histogram_ref(codes, g, h, nid, 2, 8)
    got = ops.build_histogram(codes, g, h, nid, n_nodes=2, n_bins=8,
                              plan=_plan("pallas_grouped",
                                         records_per_block=rblk,
                                         fields_per_block=fblk))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("K", [1, 3])
@pytest.mark.parametrize("n,F,NB,NN,all_missing_col", [
    (500, 4, 8, 2, False),     # non-multiple-of-block record count
    (513, 9, 16, 4, False),    # ragged records AND fields
    (256, 3, 8, 1, True),      # one column entirely missing-bin codes
    (67, 11, 8, 2, True),      # ragged everything + all-missing column
])
def test_strategy_parity_matrix(K, n, F, NB, NN, all_missing_col):
    """scatter ≡ scatter_private ≡ sort ≡ onehot ≡ pallas_grouped ≡
    pallas_packed on identical inputs — including the class-batched (K, n)
    statistics shapes, non-multiple-of-block sizes, and columns where every
    record carries the missing bin."""
    rng = np.random.default_rng(n * 31 + K)
    codes = rng.integers(0, NB, (n, F))
    if all_missing_col:
        codes[:, F // 2] = NB - 1          # the missing bin is the last code
    codes = jnp.asarray(codes, jnp.uint8)
    shape = (K, n) if K > 1 else (n,)
    g = jnp.asarray(rng.normal(size=shape), jnp.float32)
    h = jnp.asarray(rng.uniform(0.1, 1.0, shape), jnp.float32)
    nid = jnp.asarray(rng.integers(0, NN, shape), jnp.int32)

    outs = {s: np.asarray(ops.build_histogram(
        codes, g, h, nid, n_nodes=NN, n_bins=NB, plan=_plan(s)))
        for s in STRATEGIES}
    want_shape = (K, NN, F, NB, 2) if K > 1 else (NN, F, NB, 2)
    for s, got in outs.items():
        assert got.shape == want_shape, (s, got.shape)
        np.testing.assert_allclose(got, outs["scatter"],
                                   rtol=2e-5, atol=2e-5, err_msg=s)
    # the all-missing column concentrates ALL mass in its last bin
    if all_missing_col:
        col = outs["scatter"][..., F // 2, :, :]
        np.testing.assert_allclose(col[..., : NB - 1, :], 0.0, atol=1e-7)


def test_mass_conservation():
    """sum over bins of any field's histogram == sum of (g, h) — the
    'every record hits exactly one bin per field' density property."""
    codes, g, h, nid = _data(999, 7, 16, 4, seed=7)
    hist = ops.build_histogram(codes, g, h, nid, n_nodes=4, n_bins=16,
                               plan=_plan("pallas_grouped"))
    per_field = np.asarray(hist.sum(axis=(0, 2)))           # (F, 2)
    np.testing.assert_allclose(per_field[:, 0], float(g.sum()), rtol=1e-4)
    np.testing.assert_allclose(per_field[:, 1], float(h.sum()), rtol=1e-4)


def test_shard_merge_equals_global():
    """Histograms over record shards sum to the global histogram — the
    paper's end-of-step-① cluster reduction."""
    codes, g, h, nid = _data(800, 5, 8, 2, seed=9)
    full = ops.build_histogram(codes, g, h, nid, n_nodes=2, n_bins=8,
                               plan=_plan("scatter"))
    parts = sum(
        ops.build_histogram(codes[i::4], g[i::4], h[i::4], nid[i::4],
                            n_nodes=2, n_bins=8, plan=_plan("scatter"))
        for i in range(4))
    np.testing.assert_allclose(np.asarray(parts), np.asarray(full),
                               rtol=1e-5, atol=1e-5)


def test_grouped_equals_packed():
    """Group-by-field vs naive packing must be numerically identical —
    the Fig 9 ablation is a performance statement, not a semantic one."""
    codes, g, h, nid = _data(511, 6, 16, 4, seed=11)
    a = ops.build_histogram(codes, g, h, nid, n_nodes=4, n_bins=16,
                            plan=_plan("pallas_grouped"))
    b = ops.build_histogram(codes, g, h, nid, n_nodes=4, n_bins=16,
                            plan=_plan("pallas_packed"))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


def test_onehot_matmul_primitive():
    rng = np.random.default_rng(1)
    idx = jnp.asarray(rng.integers(0, 10, 200), jnp.int32)
    vals = jnp.asarray(rng.normal(size=(200, 3)), jnp.float32)
    got = ops.onehot_matmul(idx, vals, 10)
    want = jnp.zeros((10, 3)).at[idx].add(vals)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)

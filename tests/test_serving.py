"""The serving daemon (PR 8): deadline batching, hot-swap, multi-tenancy.

* coalescing: k requests queued under one deadline are served in a
  single flush, bit-equal to individual predicts (row padding never
  changes results),
* zero-slack requests dispatch immediately (one flush each),
* hot-swap under load: a republished tenant loses zero in-flight
  requests and triggers zero retraces when the shape buckets match;
  post-swap results come from the new version,
* multi-model isolation: tenants (and separate registries) keep
  disjoint predict caches; unpublish evicts exactly one tenant,
* ``stats()`` counters are consistent with the submitted request mix,
* oversize requests chop into segments and reassemble in order.
"""
import threading
import time

import numpy as np
import pytest

from repro.api import ExecutionPlan, ModelRegistry, Server, warmup_buckets
from repro.core.binning import Binner
from repro.core.gbdt import GBDTModel
from repro.core.inference import (GBDTPipeline, PredictCache,
                                  ROW_BUCKET_FLOOR, bucket_pow2,
                                  bucket_trees)
from repro.kernels.ref import TreeArrays

N_BINS = 16
MISSING = N_BINS - 1
N_FIELDS = 7
PLAN = ExecutionPlan(traversal_strategy="reference")


def rand_forest(rng, T, depth):
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth

    def one():
        feat = rng.integers(0, N_FIELDS, n_int).astype(np.int32)
        feat[rng.uniform(size=n_int) < 0.2] = -1
        return TreeArrays(
            feature=feat,
            threshold=rng.integers(0, N_BINS - 1, n_int).astype(np.int32),
            is_cat=rng.integers(0, 2, n_int).astype(np.int32),
            default_left=rng.integers(0, 2, n_int).astype(np.int32),
            leaf_value=rng.normal(size=n_leaf).astype(np.float32))

    trees = [one() for _ in range(T)]
    return TreeArrays(*[np.stack([getattr(t, f) for t in trees])
                        for f in TreeArrays._fields])


def make_pipeline(seed: int, T: int = 12, depth: int = 3) -> GBDTPipeline:
    """A synthetic binner+model bundle — no training, deterministic."""
    rng = np.random.default_rng(seed)
    X_fit = rng.normal(size=(512, N_FIELDS)).astype(np.float32)
    binner = Binner(N_BINS).fit(X_fit)
    model = GBDTModel(trees=rand_forest(rng, T, depth), base_margin=0.5,
                      objective="reg:squarederror", missing_bin=MISSING,
                      n_fields=N_FIELDS, max_depth=depth)
    return GBDTPipeline(binner=binner, model=model)


def make_X(seed: int, n: int) -> np.ndarray:
    rng = np.random.default_rng(1000 + seed)
    X = rng.normal(size=(n, N_FIELDS)).astype(np.float32)
    X[rng.random(X.shape) < 0.05] = np.nan
    return X


@pytest.fixture
def registry():
    reg = ModelRegistry(PLAN)
    reg.publish("a", make_pipeline(0))
    return reg


# --------------------------------------------------------------------------
# deadline batching
# --------------------------------------------------------------------------
def test_coalesced_flush_bit_equal_to_individual_predicts(registry):
    pipe = registry.pipeline("a")
    batches = [make_X(i, n) for i, n in enumerate((100, 37, 160, 201))]
    with Server(registry, max_batch=1024, default_slack_ms=500.0) as srv:
        srv.warmup("a")
        flushes0 = srv.stats()["a"]["flushes"]
        reqs = [srv.submit("a", X) for X in batches]
        outs = [r.result(timeout=60) for r in reqs]
        stats = srv.stats()["a"]
    # all four queued within the 500 ms slack of the first -> ONE flush
    assert stats["flushes"] - flushes0 == 1
    for X, out in zip(batches, outs):
        np.testing.assert_array_equal(
            out, np.asarray(pipe.predict(X, plan=PLAN)))


def test_zero_slack_serves_immediately(registry):
    with Server(registry, max_batch=1024, default_slack_ms=0.0) as srv:
        srv.warmup("a")
        for i in range(3):
            srv.submit("a", make_X(i, 50)).result(timeout=60)
        stats = srv.stats()["a"]
    assert stats["requests"] == 3
    # nothing to coalesce with: each request flushed on its own
    assert stats["flushes"] == 3


def test_full_batch_flushes_before_deadline(registry):
    with Server(registry, max_batch=256, default_slack_ms=3600e3) as srv:
        srv.warmup("a")
        reqs = [srv.submit("a", make_X(i, 128)) for i in range(2)]
        # an hour of slack, but 2 x 128 rows fill max_batch -> flush now
        outs = [r.result(timeout=60) for r in reqs]
    assert all(o.shape == (128,) for o in outs)


def test_oversize_request_chops_and_reassembles(registry):
    pipe = registry.pipeline("a")
    X = make_X(7, 700)
    with Server(registry, max_batch=256, default_slack_ms=5.0) as srv:
        srv.warmup("a")
        out = srv.submit("a", X).result(timeout=60)
        stats = srv.stats()["a"]
    assert stats["requests"] == 1 and stats["flushes"] == 3
    np.testing.assert_array_equal(out,
                                  np.asarray(pipe.predict(X, plan=PLAN)))


def test_warmup_covers_every_reachable_flush_bucket(registry):
    with Server(registry, max_batch=1000, default_slack_ms=200.0) as srv:
        traces = srv.warmup("a")
        buckets = warmup_buckets(1000)
        assert buckets == [128, 256, 512, 1024]
        assert traces == len(buckets)
        # any flush is <= max_batch rows; its pad bucket is in the set
        for rows in (1, 128, 129, 700, 1000):
            assert bucket_pow2(rows, ROW_BUCKET_FLOOR) in buckets
        t0 = srv.stats()["a"]["traces"]
        reqs = [srv.submit("a", make_X(i, n))
                for i, n in enumerate((3, 130, 513, 999, 1000))]
        for r in reqs:
            r.result(timeout=60)
        assert srv.stats()["a"]["traces"] == t0   # zero retraces, any mix


# --------------------------------------------------------------------------
# hot-swap
# --------------------------------------------------------------------------
def test_hotswap_under_load_drops_nothing_and_never_retraces(registry):
    v2 = make_pipeline(99)        # same T/depth -> same shape buckets
    assert bucket_trees(v2.model.n_trees) == bucket_trees(
        registry.pipeline("a").model.n_trees)
    with Server(registry, max_batch=512, default_slack_ms=2.0) as srv:
        srv.warmup("a")
        warm = srv.stats()["a"]["traces"]
        reqs, swapped = [], threading.Event()

        def pound():
            for i in range(40):
                reqs.append(srv.submit("a", make_X(i, 64 + i)))
                if i == 20:
                    swapped.set()
                time.sleep(0.001)

        t = threading.Thread(target=pound)
        t.start()
        swapped.wait(timeout=30)
        version = registry.publish("a", v2)     # hot-swap mid-load
        t.join()
        outs = [r.result(timeout=60) for r in reqs]
        # a request submitted strictly after publish() returned must be
        # served by the NEW version's numbers
        post = srv.submit("a", make_X(999, 77)).result(timeout=60)
        stats = srv.stats()["a"]
    assert version == 2
    assert len(outs) == 40 and stats["dropped"] == 0
    assert stats["requests"] == 41
    assert stats["traces"] == warm              # zero retraces across swap
    np.testing.assert_array_equal(
        post, np.asarray(v2.predict(make_X(999, 77), plan=PLAN)))


def test_publish_warms_new_buckets_off_hot_path():
    reg = ModelRegistry(PLAN)
    reg.publish("a", make_pipeline(0))
    reg.warm("a", [128, 256])
    # v2 lands in DIFFERENT tree bucket -> publish() pre-compiles the
    # previously-served row buckets before the swap becomes visible
    v2 = make_pipeline(5, T=40)
    assert bucket_trees(40) != bucket_trees(12)
    traces_before = reg.entry("a").cache.stats()["traces"]
    reg.publish("a", v2)
    traces_after = reg.entry("a").cache.stats()["traces"]
    assert traces_after - traces_before == 2    # both buckets, pre-swap
    # serving those buckets now costs nothing new
    out = v2.predict(make_X(1, 100), plan=PLAN,
                     cache=reg.entry("a").cache)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(v2.predict(make_X(1, 100),
                                                        plan=PLAN)))
    assert reg.entry("a").cache.stats()["traces"] == traces_after


# --------------------------------------------------------------------------
# multi-model tenancy
# --------------------------------------------------------------------------
def test_multi_model_isolation_and_eviction():
    reg = ModelRegistry(PLAN)
    reg.publish("a", make_pipeline(0))
    reg.publish("b", make_pipeline(1, T=20, depth=4))
    ca, cb = reg.entry("a").cache, reg.entry("b").cache
    assert ca is not cb
    reg.warm("a", [128])
    assert ca.stats()["traces"] == 1
    assert cb.stats()["traces"] == 0            # tenant b untouched
    reg.warm("b", [128])
    assert cb.stats()["traces"] == 1
    reg.unpublish("a")
    assert "a" not in reg and "b" in reg
    assert ca.stats() == {"entries": 0, "hits": 0, "misses": 0, "traces": 0}
    assert cb.stats()["traces"] == 1            # eviction is per-tenant
    with pytest.raises(KeyError):
        reg.unpublish("a")


def test_two_registries_do_not_collide():
    r1, r2 = ModelRegistry(PLAN), ModelRegistry(PLAN)
    r1.publish("m", make_pipeline(0))
    r2.publish("m", make_pipeline(1))
    r1.warm("m", [128, 256])
    assert r1.entry("m").cache.stats()["traces"] == 2
    assert r2.entry("m").cache.stats()["traces"] == 0
    X = make_X(0, 64)
    out1 = r1.pipeline("m").predict(X, plan=PLAN,
                                    cache=r1.entry("m").cache)
    out2 = r2.pipeline("m").predict(X, plan=PLAN,
                                    cache=r2.entry("m").cache)
    assert not np.array_equal(np.asarray(out1), np.asarray(out2))


def test_submit_unknown_model_raises(registry):
    with Server(registry, max_batch=256) as srv:
        with pytest.raises(KeyError):
            srv.submit("nope", make_X(0, 8))


# --------------------------------------------------------------------------
# stats consistency
# --------------------------------------------------------------------------
def test_stats_counters_match_request_mix(registry):
    registry.publish("b", make_pipeline(1, T=20, depth=4))
    sizes_a, sizes_b = (64, 130, 7), (100, 200)
    with Server(registry, max_batch=512, default_slack_ms=5.0) as srv:
        srv.warmup("a")
        srv.warmup("b")
        reqs = ([srv.submit("a", make_X(i, n))
                 for i, n in enumerate(sizes_a)]
                + [srv.submit("b", make_X(i, n))
                   for i, n in enumerate(sizes_b)])
        for r in reqs:
            r.result(timeout=60)
        stats = srv.stats()
    a, b = stats["a"], stats["b"]
    assert a["requests"] == len(sizes_a) and a["rows"] == sum(sizes_a)
    assert b["requests"] == len(sizes_b) and b["rows"] == sum(sizes_b)
    for s in (a, b):
        assert s["dropped"] == 0
        assert s["queue_depth"] == 0            # drained
        assert 0.0 < s["batch_fill"] <= 1.0
        assert s["p50_ms"] <= s["p99_ms"]
        assert s["qps"] > 0.0
        assert s["flushes"] <= s["requests"]
    assert a["version"] == 1 and b["version"] == 1


def test_stop_drains_pending_requests(registry):
    srv = Server(registry, max_batch=256, default_slack_ms=10_000.0)
    srv.warmup("a")
    reqs = [srv.submit("a", make_X(i, 20)) for i in range(4)]
    srv.stop()                    # long slack, but stop() must drain
    assert all(r.done() for r in reqs)
    with pytest.raises(RuntimeError):
        srv.submit("a", make_X(9, 20))


# --------------------------------------------------------------------------
# overload & failure posture (PR 9)
# --------------------------------------------------------------------------
def test_bounded_queue_sheds_typed_and_never_enqueues(registry):
    from repro.api import QueueFullError
    with Server(registry, max_batch=128, default_slack_ms=10_000.0,
                max_queue_rows=128) as srv:
        srv.warmup("a")
        keep = srv.submit("a", make_X(0, 60))       # queued: 60 < max_batch
        shed = srv.submit("a", make_X(1, 100))      # 160 > 128 -> shed
        assert shed.done()                          # failed at admission
        with pytest.raises(QueueFullError):
            shed.result(timeout=1)
        late = srv.submit("a", make_X(2, 30))       # 90 <= 128 -> admitted
        stats = srv.stats()["a"]
        assert stats["shed"] == 1
        # the shed request never entered the queue
        assert stats["queue_depth"] == 90
    # stop() drained the admitted work; nothing silently dropped
    assert keep.result(timeout=60).shape == (60,)
    assert late.result(timeout=60).shape == (30,)


def test_queue_deadline_fails_typed(registry):
    from repro.api import DeadlineExceededError
    with Server(registry, max_batch=256, default_slack_ms=10_000.0,
                timeout_ms=50.0) as srv:
        srv.warmup("a")
        # slack says "wait 10 s for company", the hard deadline says 50 ms:
        # the segment must expire typed, not flush
        req = srv.submit("a", make_X(0, 20))
        with pytest.raises(DeadlineExceededError):
            req.result(timeout=60)
        deadline = time.monotonic() + 30
        while (srv.stats()["a"]["deadline_failures"] == 0
               and time.monotonic() < deadline):
            time.sleep(0.01)
        stats = srv.stats()["a"]
    assert stats["deadline_failures"] == 1
    assert stats["queue_depth"] == 0                # popped, not leaked


def test_dispatcher_crash_restarts_and_keeps_serving(registry):
    from repro.api import DispatcherCrashError, FaultSchedule
    sched = FaultSchedule()
    sched.add("dispatch", 0, kind="error",
              exc=RuntimeError, message="chaos: flush 0 dies")
    with Server(registry, max_batch=256, default_slack_ms=0.0,
                fault_injector=sched) as srv:
        srv.warmup("a")
        doomed = srv.submit("a", make_X(0, 30))
        with pytest.raises(DispatcherCrashError) as ei:
            doomed.result(timeout=60)
        assert isinstance(ei.value.__cause__, RuntimeError)
        # the supervisor restarted the dispatcher: serving continues
        out = srv.submit("a", make_X(1, 30)).result(timeout=60)
        health = srv.health()
        stats = srv.stats()["a"]
    assert out.shape == (30,)
    assert health.alive and health.ready
    assert health.dispatcher_restarts == 1
    assert stats["dropped"] == 1                    # the crashed flush
    assert sched.fired == [("dispatch", 0, "error")]


def test_restart_budget_exhaustion_fails_everything_typed(registry):
    from repro.api import DispatcherCrashError, FaultSchedule
    sched = FaultSchedule()
    sched.add("dispatch", 0, kind="error",
              exc=RuntimeError, message="chaos: fatal flush")
    with Server(registry, max_batch=256, default_slack_ms=0.0,
                max_dispatcher_restarts=0, fault_injector=sched) as srv:
        srv.warmup("a")
        doomed = srv.submit("a", make_X(0, 30))
        with pytest.raises(DispatcherCrashError):
            doomed.result(timeout=60)
        deadline = time.monotonic() + 30
        while srv.health().alive and time.monotonic() < deadline:
            time.sleep(0.01)
        health = srv.health()
        # dead server: submissions fail fast, typed — zero silent drops
        fast = srv.submit("a", make_X(1, 10))
        assert fast.done()
        with pytest.raises(DispatcherCrashError):
            fast.result(timeout=1)
    assert not health.alive and not health.ready
    assert srv.health().failed_requests == 2        # crash + fast-fail


def test_health_reports_clean_server(registry):
    with Server(registry, max_batch=256, default_slack_ms=0.0) as srv:
        srv.warmup("a")
        srv.submit("a", make_X(0, 16)).result(timeout=60)
        h = srv.health()
    assert h.alive and h.ready
    assert h.dispatcher_restarts == 0 and h.failed_requests == 0
    assert h.models == 1
    assert h.as_dict()["alive"] is True

"""Step-③ partition kernel vs oracle + structural properties."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan
from repro.kernels import ops, ref

_PALLAS = ExecutionPlan.auto(partition_strategy="pallas")


def _case(rng, n, nn, n_cols, n_bins):
    node_ids = jnp.asarray(rng.integers(0, nn, n), jnp.int32)
    codes = jnp.asarray(rng.integers(0, n_bins, (n, n_cols)), jnp.uint8)
    sf = jnp.asarray(rng.integers(-1, n_cols, nn), jnp.int32)
    st = jnp.asarray(rng.integers(0, n_bins - 1, nn), jnp.int32)
    sc = jnp.asarray(rng.integers(0, 2, nn), jnp.int32)
    sd = jnp.asarray(rng.integers(0, 2, nn), jnp.int32)
    return node_ids, codes, sf, st, sc, sd


@pytest.mark.parametrize("n,nn,n_cols,n_bins", [
    (64, 1, 1, 4), (511, 4, 4, 16), (1025, 16, 16, 32)])
def test_partition_matches_oracle(n, nn, n_cols, n_bins):
    rng = np.random.default_rng(n + nn)
    args = _case(rng, n, nn, n_cols, n_bins)
    want = ref.partition_ref(*args, n_bins - 1)
    got = ops.partition_level(*args, missing_bin=n_bins - 1,
                              plan=_PALLAS)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_children_are_consistent():
    """Child ids land in [2*node, 2*node+1] — left ⊎ right partitions the
    node's records (the paper's predicate-true/false streams)."""
    rng = np.random.default_rng(7)
    node_ids, codes, sf, st, sc, sd = _case(rng, 2048, 8, 8, 16)
    child = ops.partition_level(node_ids, codes, sf, st, sc, sd,
                                missing_bin=15, plan=_PALLAS)
    child = np.asarray(child)
    parent = np.asarray(node_ids)
    assert ((child == 2 * parent) | (child == 2 * parent + 1)).all()
    # record counts conserved per parent
    for j in range(8):
        assert (parent == j).sum() == ((child == 2 * j).sum()
                                       + (child == 2 * j + 1).sum())


def test_passthrough_goes_left():
    node_ids = jnp.zeros((16,), jnp.int32)
    codes = jnp.asarray(np.random.default_rng(0).integers(0, 4, (16, 2)),
                        jnp.uint8)
    child = ops.partition_level(
        node_ids, codes, jnp.asarray([-1], jnp.int32),
        jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
        jnp.zeros((1,), jnp.int32), missing_bin=3, plan=_PALLAS)
    assert (np.asarray(child) == 0).all()

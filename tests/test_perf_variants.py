"""§Perf optimization variants must be semantics-preserving (tested)."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke
from repro.models import lm
from repro.models.layers import sdpa, sdpa_chunked


@pytest.mark.parametrize("case", [
    dict(b=2, sq=16, sk=16, h=4, kv=2, d=8, causal=True, win=None, chunk=8),
    dict(b=1, sq=32, sk=32, h=4, kv=4, d=16, causal=True, win=12, chunk=8),
    dict(b=2, sq=8, sk=24, h=2, kv=1, d=8, causal=False, win=None, chunk=7),
])
def test_flash_attention_matches_dense(case):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(case["b"], case["sq"], case["h"],
                                     case["d"])), jnp.float32)
    k = jnp.asarray(rng.normal(size=(case["b"], case["sk"], case["kv"],
                                     case["d"])), jnp.float32)
    v = jnp.asarray(rng.normal(size=(case["b"], case["sk"], case["kv"],
                                     case["d"])), jnp.float32)
    a = sdpa(q, k, v, causal=case["causal"], sliding_window=case["win"])
    c = sdpa_chunked(q, k, v, causal=case["causal"],
                     sliding_window=case["win"], kv_chunk=case["chunk"])
    np.testing.assert_allclose(np.asarray(c), np.asarray(a),
                               rtol=2e-5, atol=2e-5)


def test_blocked_xent_matches_dense():
    cfg = get_smoke("command-r-35b")
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                   jnp.int32)}
    a = float(lm.loss_fn(cfg, params, batch))
    b = float(lm.loss_fn_blocked(cfg, params, batch, n_blocks=8))
    assert abs(a - b) < 1e-4
    ga = jax.grad(lambda p: lm.loss_fn(cfg, p, batch))(params)
    gb = jax.grad(lambda p: lm.loss_fn_blocked(cfg, p, batch,
                                               n_blocks=8))(params)
    for la, lb in zip(jax.tree.leaves(ga), jax.tree.leaves(gb)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-3, atol=1e-5)


def test_flash_attn_config_preserves_forward():
    for aid in ("qwen3-14b", "mixtral-8x22b"):
        cfg = get_smoke(aid)
        cfg_f = dataclasses.replace(cfg, attn_chunk=8)
        params = lm.init_params(cfg, jax.random.PRNGKey(1))
        rng = np.random.default_rng(1)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (2, 16)),
                                       jnp.int32)}
        a = lm.forward_train(cfg, params, batch)
        b = lm.forward_train(cfg_f, params, batch)
        np.testing.assert_allclose(np.asarray(b), np.asarray(a),
                                   rtol=2e-4, atol=2e-4)


@pytest.mark.slow
def test_explicit_distributed_tree_variants_match_reference():
    """Explicit shard_map schedule, bf16 histogram psum and owner-evaluates
    partition all grow the reference tree."""
    import os
    import subprocess
    import sys
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import pathlib
    root = pathlib.Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(root / "src")
    code = r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import fit_tree
from repro.distributed.sharding import distributed_fit_tree
from repro.launch.mesh import make_mesh
mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
codes = jnp.asarray(rng.integers(0, 16, (2048, 8)), jnp.uint8)
codes_cm = jnp.asarray(np.asarray(codes).T.copy())
g = jnp.asarray(rng.normal(size=2048), jnp.float32)
h = jnp.asarray(rng.uniform(.1, 1, 2048), jnp.float32)
kw = dict(depth=3, n_bins=16, missing_bin=15,
          is_cat_field=jnp.zeros((8,), bool),
          field_mask=jnp.ones((8,), bool), lambda_=1.0, gamma=0.0,
          min_child_weight=1.0)
ref = fit_tree(codes, codes_cm, g, h, hist_strategy="scatter",
               partition_strategy="reference", **kw)
# each feature alone, then both together (the redundant single-feature
# cross cell is dropped to keep the multi-device compile budget down)
for bits, hd in ((False, None), (True, None), (True, jnp.bfloat16)):
    with mesh:
        t = distributed_fit_tree(mesh, codes, codes_cm, g, h,
                                 hist_strategy="scatter",
                                 hist_dtype=hd, partition_bits=bits,
                                 **kw)
    for a, b in zip(t, ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-3, atol=1e-4)
print("VARIANTS_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "VARIANTS_OK" in out.stdout

"""Fault tolerance: checkpoint/restart with deterministic replay, journal
recovery, corrupt-checkpoint fallback, injected failures."""
import os

import numpy as np
import pytest

from repro.core import GBDTConfig, GBDTModel, bin_dataset, train
from repro.data import make_tabular
from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import FaultInjector, StepJournal, run_with_restarts


@pytest.fixture(scope="module")
def small_data():
    X, y, cats = make_tabular(800, 6, 2, task="regression", seed=5)
    return bin_dataset(X, max_bins=32, categorical_fields=cats), y


def test_checkpoint_roundtrip_bitexact(small_data, tmp_path):
    data, y = small_data
    res = train(GBDTConfig(n_trees=4, max_depth=4, hist_strategy="scatter"),
                data, y)
    ckpt.save(str(tmp_path), res.model.to_state(), step=4)
    state, step, _ = ckpt.restore(str(tmp_path),
                                  like=res.model.to_state())
    model2 = GBDTModel.from_state(state)
    np.testing.assert_array_equal(np.asarray(res.model.predict(data)),
                                  np.asarray(model2.predict(data)))


def test_corrupt_checkpoint_falls_back(small_data, tmp_path):
    data, y = small_data
    res = train(GBDTConfig(n_trees=2, max_depth=3, hist_strategy="scatter"),
                data, y)
    st = res.model.to_state()
    ckpt.save(str(tmp_path), st, step=1)
    ckpt.save(str(tmp_path), st, step=2)
    with open(os.path.join(str(tmp_path), "step_2", "arrays.npz"),
              "wb") as f:
        f.write(b"corrupted")
    _, step, _ = ckpt.restore(str(tmp_path), like=st)
    assert step == 1


def test_restart_replay_is_exact(small_data, tmp_path):
    """Kill training at tree 5 of 8; restart from the tree-3 checkpoint;
    the final ensemble must equal an uninterrupted run (deterministic
    per-tree RNG streams)."""
    data, y = small_data
    cfg = GBDTConfig(n_trees=8, max_depth=4, subsample=0.8, seed=11,
                     hist_strategy="scatter")
    golden = train(cfg, data, y)

    ckdir = str(tmp_path / "ck")
    journal = StepJournal(str(tmp_path / "journal.jsonl"))
    injector = FaultInjector(fail_at_steps=[5])
    restarts = []

    def make_trainer(start_step):
        def gen():
            if start_step == 0:
                init = None
            else:
                state, step, _ = ckpt.restore(
                    ckdir, like=golden.model.to_state())
                init = GBDTModel.from_state(state)
                assert init.n_trees == step

            done = init.n_trees if init else 0

            def cb(t_idx, model):
                injector.check(t_idx)  # may raise mid-training
                ckpt.save(ckdir, model.to_state(), step=t_idx + 1)
                journal.append(t_idx, {"loss": 0.0})

            import dataclasses
            c = dataclasses.replace(cfg, n_trees=cfg.n_trees - done)
            train(c, data, y, init_model=init, callback=cb)
            yield cfg.n_trees - 1
        return gen()

    last = run_with_restarts(make_trainer, max_restarts=2,
                             on_restart=lambda n, e: restarts.append(str(e)))
    assert last == cfg.n_trees - 1
    assert len(restarts) == 1 and "injected fault" in restarts[0]

    state, step, _ = ckpt.restore(ckdir, like=golden.model.to_state())
    assert step == cfg.n_trees
    recovered = GBDTModel.from_state(state)
    for fa, fb in zip(recovered.trees, golden.model.trees):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


def test_journal_survives_torn_writes(tmp_path):
    j = StepJournal(str(tmp_path / "j.jsonl"))
    j.append(0, {"loss": 1.0})
    j.append(1, {"loss": 0.5})
    with open(j.path, "a") as f:
        f.write('{"step": 2, "loss":')  # torn tail
    assert j.last_step() == 1

"""Fault tolerance: checkpoint/restart with deterministic replay, journal
recovery, corrupt-checkpoint fallback, injected failures."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import GBDTConfig, GBDTModel, bin_dataset, train
from repro.data import make_tabular
from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import StepJournal, run_with_restarts
from repro.resilience.faults import FaultInjector


@pytest.fixture(scope="module")
def small_data():
    X, y, cats = make_tabular(800, 6, 2, task="regression", seed=5)
    return bin_dataset(X, max_bins=32, categorical_fields=cats), y


def test_checkpoint_roundtrip_bitexact(small_data, tmp_path):
    data, y = small_data
    res = train(GBDTConfig(n_trees=4, max_depth=4, hist_strategy="scatter"),
                data, y)
    ckpt.save(str(tmp_path), res.model.to_state(), step=4)
    state, step, _ = ckpt.restore(str(tmp_path),
                                  like=res.model.to_state())
    model2 = GBDTModel.from_state(state)
    np.testing.assert_array_equal(np.asarray(res.model.predict(data)),
                                  np.asarray(model2.predict(data)))


def test_corrupt_checkpoint_falls_back(small_data, tmp_path):
    data, y = small_data
    res = train(GBDTConfig(n_trees=2, max_depth=3, hist_strategy="scatter"),
                data, y)
    st = res.model.to_state()
    ckpt.save(str(tmp_path), st, step=1)
    ckpt.save(str(tmp_path), st, step=2)
    with open(os.path.join(str(tmp_path), "step_2", "arrays.npz"),
              "wb") as f:
        f.write(b"corrupted")
    _, step, _ = ckpt.restore(str(tmp_path), like=st)
    assert step == 1


def test_restart_replay_is_exact(small_data, tmp_path):
    """Kill training at tree 5 of 8; restart from the tree-3 checkpoint;
    the final ensemble must equal an uninterrupted run (deterministic
    per-tree RNG streams)."""
    data, y = small_data
    cfg = GBDTConfig(n_trees=8, max_depth=4, subsample=0.8, seed=11,
                     hist_strategy="scatter")
    golden = train(cfg, data, y)

    ckdir = str(tmp_path / "ck")
    journal = StepJournal(str(tmp_path / "journal.jsonl"))
    injector = FaultInjector(fail_at_steps=[5])
    restarts = []

    def make_trainer(start_step):
        def gen():
            if start_step == 0:
                init = None
            else:
                state, step, _ = ckpt.restore(
                    ckdir, like=golden.model.to_state())
                init = GBDTModel.from_state(state)
                assert init.n_trees == step

            done = init.n_trees if init else 0

            def cb(t_idx, model):
                injector.check(t_idx)  # may raise mid-training
                ckpt.save(ckdir, model.to_state(), step=t_idx + 1)
                journal.append(t_idx, {"loss": 0.0})

            import dataclasses
            c = dataclasses.replace(cfg, n_trees=cfg.n_trees - done)
            train(c, data, y, init_model=init, callback=cb)
            yield cfg.n_trees - 1
        return gen()

    last = run_with_restarts(make_trainer, max_restarts=2,
                             on_restart=lambda n, e: restarts.append(str(e)))
    assert last == cfg.n_trees - 1
    assert len(restarts) == 1 and "injected fault" in restarts[0]

    state, step, _ = ckpt.restore(ckdir, like=golden.model.to_state())
    assert step == cfg.n_trees
    recovered = GBDTModel.from_state(state)
    for fa, fb in zip(recovered.trees, golden.model.trees):
        np.testing.assert_array_equal(np.asarray(fa), np.asarray(fb))


_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=_ROOT,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_distributed_fault_shrink_restore_replay(tmp_path):
    """A FaultInjector-killed worker mid-round on an 8-shard fit must
    recover WITHOUT restarting the fit: re-mesh onto 6 survivors, restore
    the newest checkpoint.save_named step, and deterministically replay
    the in-flight tree — landing on the same ensemble as an uninterrupted
    run (identical structure; leaf floats within the documented
    tolerance).  A grow event afterwards re-meshes back up to 8 shards
    between rounds."""
    out = _run_with_devices(r"""
import numpy as np, jax, tempfile
from repro.core import GBDTConfig, bin_dataset
from repro.resilience.faults import FaultInjector
from repro.distributed.trainer import (DistributedConfig,
                                       data_parallel_mesh,
                                       train_distributed)

rng = np.random.default_rng(0)
n, F = 4096, 6
X = rng.normal(size=(n, F))
y = (rng.integers(-8, 9, n) * 0.25).astype(np.float32)
data = bin_dataset(X, max_bins=32)
cfg = GBDTConfig(n_trees=8, max_depth=3, subsample=0.8, seed=11,
                 hist_strategy="scatter")
mesh8 = data_parallel_mesh(jax.devices())
golden = train_distributed(cfg, data, y, mesh=mesh8)
pg = np.asarray(golden.model.predict(data))

with tempfile.TemporaryDirectory() as d:
    dist = DistributedConfig(
        checkpoint_dir=d, checkpoint_every=2,
        fault_injector=FaultInjector(fail_at_steps=(5,)),
        survivors=lambda devs: devs[:-2])       # lose two workers
    res = train_distributed(cfg, data, y, mesh=mesh8, dist=dist)
assert res.stats["restarts"] == 1, res.stats
assert res.stats["remesh_events"] == [("shrink", 5, 6)], res.stats
assert res.stats["n_shards"] == 6
assert res.model.n_trees == cfg.n_trees            # the fit never restarted
for nm in ("feature", "threshold", "is_cat", "default_left"):
    np.testing.assert_array_equal(np.asarray(getattr(res.model.trees, nm)),
                                  np.asarray(getattr(golden.model.trees,
                                                     nm)), err_msg=nm)
np.testing.assert_allclose(np.asarray(res.model.predict(data)), pg,
                           rtol=1e-5, atol=1e-6)

# grow event: 4 shards for rounds 0-3, back up to 8 from round 4
grew = train_distributed(
    cfg, data, y, mesh=data_parallel_mesh(jax.devices()[:4]),
    dist=DistributedConfig(available_devices=lambda t:
                           jax.devices()[:4] if t < 4 else jax.devices()))
assert grew.stats["remesh_events"] == [("grow", 4, 8)], grew.stats
assert grew.stats["n_shards"] == 8
np.testing.assert_allclose(np.asarray(grew.model.predict(data)), pg,
                           rtol=1e-5, atol=1e-6)
print("FAULT_DIST_OK")
""")
    assert "FAULT_DIST_OK" in out


def test_journal_survives_torn_writes(tmp_path):
    j = StepJournal(str(tmp_path / "j.jsonl"))
    j.append(0, {"loss": 1.0})
    j.append(1, {"loss": 0.5})
    with open(j.path, "a") as f:
        f.write('{"step": 2, "loss":')  # torn tail
    assert j.last_step() == 1

"""§III-D extensions: multi-chip sharded inference, importances, pipeline."""
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core import GBDTConfig, train
from repro.core.binning import Binner
from repro.core.inference import (GBDTPipeline, feature_importance,
                                  pad_trees)
from repro.data import make_tabular


@pytest.fixture(scope="module")
def trained():
    X, y, cats = make_tabular(2000, 6, 2, n_cats=6, task="regression",
                              missing_rate=0.02, seed=4)
    binner = Binner(max_bins=32, categorical_fields=cats)
    data = binner.fit_transform(X)
    res = train(GBDTConfig(n_trees=6, max_depth=4, learning_rate=0.3,
                           hist_strategy="scatter"), data, y)
    return X, y, binner, data, res.model


def test_pad_trees_preserves_predictions(trained):
    X, y, binner, data, model = trained
    padded = pad_trees(model, 4)          # 6 -> 8 trees
    assert padded.n_trees == 8
    np.testing.assert_allclose(
        np.asarray(padded.predict_margin(data.codes)),
        np.asarray(model.predict_margin(data.codes)), rtol=1e-5, atol=1e-6)


def test_feature_importance_shapes_and_mass(trained):
    _, _, _, _, model = trained
    for kind in ("split", "gain", "cover"):
        imp = feature_importance(model, kind)
        assert imp.shape == (model.n_fields,)
        assert abs(imp.sum() - 1.0) < 1e-6
        assert (imp >= 0).all()
    # the planted signal uses a handful of fields; importance concentrates
    assert feature_importance(model, "split").max() > 1.0 / model.n_fields


def test_pipeline_raw_predict_and_roundtrip(trained, tmp_path):
    X, y, binner, data, model = trained
    pipe = GBDTPipeline(binner=binner, model=model)
    direct = np.asarray(model.predict(data))
    via_raw = np.asarray(pipe.predict(X))
    # the pipeline serves through the fused compile-once engine: the one
    # XLA program may reassociate the tree fold, so margins near zero
    # need an absolute floor on top of the relative tolerance
    np.testing.assert_allclose(via_raw, direct, rtol=1e-5, atol=1e-6)

    from repro.distributed import checkpoint as ckpt
    ckpt.save(str(tmp_path), pipe.to_state(), step=1)
    state, _, _ = ckpt.restore(str(tmp_path), like=pipe.to_state())
    pipe2 = GBDTPipeline.from_state(state)
    np.testing.assert_allclose(np.asarray(pipe2.predict(X)), direct,
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow
def test_sharded_predict_matches_single_device():
    """Paper §III-D: trees round-robin across chips, outputs combined."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    root = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    env["PYTHONPATH"] = os.path.join(root, "src")
    code = r"""
import numpy as np, jax.numpy as jnp
from repro.core import GBDTConfig, bin_dataset, train
from repro.core.inference import pad_trees, sharded_predict
from repro.data import make_tabular
from repro.launch.mesh import make_mesh

X, y, cats = make_tabular(1024, 5, 0, task="regression", seed=2)
data = bin_dataset(X, max_bins=16)
model = train(GBDTConfig(n_trees=4, max_depth=3,
                         hist_strategy="scatter"), data, y).model
mesh = make_mesh((4, 2), ("data", "model"))
padded = pad_trees(model, 2)
with mesh:
    out = sharded_predict(mesh, padded, data.codes)
ref = model.predict_margin(data.codes)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
print("SHARDED_PREDICT_OK")
"""
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "SHARDED_PREDICT_OK" in out.stdout

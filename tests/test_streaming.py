"""Out-of-core streaming vertical: sketch binning, chunked histograms,
chunked training parity, GOSS, and the DataSource implementations."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import BoosterClassifier, BoosterRegressor, ExecutionPlan
from repro.core.binning import Binner, StreamingBinner
from repro.core.gbdt import GBDTConfig, goss_weights, train, train_streaming
from repro.data.pipeline import (ArraySource, DataSource, NpzShardSource,
                                 as_source, write_npz_shards)
from repro.data.synthetic import SyntheticSource, make_tabular
from repro.kernels import ops

import jax


# --------------------------------------------------------------------------
# StreamingBinner: sketch-vs-exact quantile parity
# --------------------------------------------------------------------------
def test_sketch_edges_exact_below_capacity():
    """Streams shorter than sketch_size never compress: finalize must
    reproduce Binner.fit bit-for-bit, chunking notwithstanding."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(1500, 7))
    X[rng.uniform(size=X.shape) < 0.05] = np.nan
    X[:, 5] = rng.integers(0, 9, size=1500)          # categorical field
    exact = Binner(max_bins=32, categorical_fields=[5]).fit(X)
    sk = StreamingBinner(max_bins=32, categorical_fields=[5],
                         sketch_size=2000)
    for lo in range(0, 1500, 311):                   # ragged chunking
        sk.partial_fit(X[lo:lo + 311])
    sk.finalize()
    np.testing.assert_array_equal(exact._edges, sk._edges)
    np.testing.assert_array_equal(exact._is_cat, sk._is_cat)
    np.testing.assert_array_equal(exact._n_value_bins, sk._n_value_bins)
    np.testing.assert_array_equal(np.asarray(exact.transform(X).codes),
                                  np.asarray(sk.transform(X).codes))


def test_sketch_edges_approximate_beyond_capacity():
    """Compressed sketches stay close to the exact quantiles (and codes
    must agree on almost every record)."""
    rng = np.random.default_rng(1)
    X = np.concatenate([rng.normal(size=(4000, 3)),
                        rng.exponential(size=(4000, 3))])  # mixed shapes
    exact = Binner(max_bins=64).fit(X)
    sk = StreamingBinner(max_bins=64, sketch_size=512)
    for lo in range(0, 8000, 1000):
        sk.partial_fit(X[lo:lo + 1000])
    sk.finalize()
    agree = np.mean(np.asarray(exact.transform(X).codes)
                    == np.asarray(sk.transform(X).codes))
    assert agree > 0.95, f"only {agree:.3f} of codes agree"


def test_sketch_rejects_mismatched_fields():
    sk = StreamingBinner(max_bins=16).partial_fit(np.zeros((4, 3)))
    with pytest.raises(ValueError, match="fields"):
        sk.partial_fit(np.zeros((4, 5)))


# --------------------------------------------------------------------------
# chunked histogram accumulation: bit-equality across every strategy
# --------------------------------------------------------------------------
@pytest.mark.parametrize("strategy", ["scatter", "scatter_private", "sort",
                                      "onehot", "pallas_grouped",
                                      "pallas_packed"])
def test_chunked_histogram_bit_equality(strategy):
    """hist(all records) == sum of per-chunk hists, bitwise, for every
    strategy.  Integer-valued stats make float accumulation exact, so the
    comparison is order-independent and genuinely bit-strict."""
    rng = np.random.default_rng(2)
    n, F, n_bins, n_nodes = 700, 5, 16, 4
    codes = jnp.asarray(rng.integers(0, n_bins, (n, F)), jnp.uint8)
    g = jnp.asarray(rng.integers(-8, 9, n), jnp.float32)
    h = jnp.asarray(rng.integers(0, 5, n), jnp.float32)
    nid = jnp.asarray(rng.integers(0, n_nodes, n), jnp.int32)
    plan = ExecutionPlan.auto(hist_strategy=strategy)

    full = ops.build_histogram(codes, g, h, nid, n_nodes=n_nodes,
                               n_bins=n_bins, plan=plan)
    acc = jnp.zeros_like(full)
    for lo in range(0, n, 256):                      # ragged final chunk
        hi = min(lo + 256, n)
        acc = ops.accumulate_histogram(acc, codes[lo:hi], g[lo:hi],
                                       h[lo:hi], nid[lo:hi],
                                       n_nodes=n_nodes, n_bins=n_bins,
                                       plan=plan)
    np.testing.assert_array_equal(np.asarray(full), np.asarray(acc))


def test_chunked_histogram_padding_is_neutral():
    """Zero-stat padded records contribute exactly +0.0 (the invariant the
    streaming trainer's uniform chunk shapes rely on)."""
    rng = np.random.default_rng(3)
    n, F, n_bins = 100, 3, 8
    codes = jnp.asarray(rng.integers(0, n_bins, (n, F)), jnp.uint8)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.ones((n,), jnp.float32)
    nid = jnp.zeros((n,), jnp.int32)
    plan = ExecutionPlan.auto()
    base = ops.build_histogram(codes, g, h, nid, n_nodes=2, n_bins=n_bins,
                               plan=plan)
    padded = ops.build_histogram(
        jnp.pad(codes, ((0, 28), (0, 0))), jnp.pad(g, (0, 28)),
        jnp.pad(h, (0, 28)), jnp.pad(nid, (0, 28)), n_nodes=2,
        n_bins=n_bins, plan=plan)
    np.testing.assert_array_equal(np.asarray(base), np.asarray(padded))


# --------------------------------------------------------------------------
# DataSource implementations
# --------------------------------------------------------------------------
def test_synthetic_source_chunk_invariant():
    """The same rows come back regardless of chunk size (block-based
    counter RNG) — the property that makes streamed passes repeatable."""
    src = SyntheticSource(5000, 6, seed=11)
    big = np.concatenate([x for x, _ in src.chunks(5000)])
    small = np.concatenate([x for x, _ in src.chunks(613)])
    np.testing.assert_array_equal(big, small)
    ys = np.concatenate([y for _, y in src.chunks(613)])
    yb = np.concatenate([y for _, y in src.chunks(5000)])
    np.testing.assert_array_equal(yb, ys)


def test_npz_shard_roundtrip(tmp_path):
    src = SyntheticSource(3000, 4, seed=13)
    paths = write_npz_shards(str(tmp_path), src, rows_per_shard=700)
    assert len(paths) == 5
    back = NpzShardSource(str(tmp_path))
    assert back.n_fields == 4
    X0 = np.concatenate([x for x, _ in src.chunks(997)])
    X1 = np.concatenate([x for x, _ in back.chunks(997)])  # shard-crossing
    np.testing.assert_array_equal(X0, X1)


def test_write_npz_shards_clears_stale(tmp_path):
    """A shorter re-export must not leave old shards mixed into the
    directory (NpzShardSource globs everything)."""
    write_npz_shards(str(tmp_path), SyntheticSource(2000, 3, seed=1),
                     rows_per_shard=400)
    write_npz_shards(str(tmp_path), SyntheticSource(500, 3, seed=2),
                     rows_per_shard=400)
    total = sum(x.shape[0]
                for x, _ in NpzShardSource(str(tmp_path)).chunks(1000))
    assert total == 500


def test_streaming_binner_refit_resets():
    """fit() recomputes from scratch (Binner semantics), it does not
    accumulate onto the previous stream."""
    rng = np.random.default_rng(4)
    X1 = rng.normal(size=(300, 2))
    X2 = rng.normal(size=(300, 2)) + 5.0
    b = StreamingBinner(max_bins=16)
    b.fit(X1)
    b.fit(X2)
    fresh = StreamingBinner(max_bins=16).fit(X2)
    np.testing.assert_array_equal(b._edges, fresh._edges)
    assert b.n_rows_seen == 300


def test_as_source_coercions(tmp_path):
    X, y = np.zeros((10, 2)), np.zeros(10)
    assert isinstance(as_source((X, y)), ArraySource)
    src = ArraySource(X, y)
    assert as_source(src) is src
    assert isinstance(src, DataSource)
    write_npz_shards(str(tmp_path), src, rows_per_shard=5)
    assert isinstance(as_source(str(tmp_path)), NpzShardSource)
    with pytest.raises(TypeError):
        as_source(42)


# --------------------------------------------------------------------------
# GOSS
# --------------------------------------------------------------------------
def test_goss_weights_structure():
    g = jnp.asarray(np.linspace(-2, 2, 100), jnp.float32)
    w = np.asarray(goss_weights(g, jax.random.PRNGKey(0), 0.2, 0.3))
    amp = (1 - 0.2) / 0.3
    # top 20 |g| records kept at weight 1
    top = np.argsort(-np.abs(np.asarray(g)))[:20]
    np.testing.assert_array_equal(w[top], 1.0)
    assert np.sum(w == amp) == 30                    # ceil(0.3 * 100) of rest
    assert np.sum(w == 0.0) == 100 - 20 - 30


def test_goss_config_validation():
    with pytest.raises(ValueError, match="GOSS"):
        GBDTConfig(goss_top_rate=0.5, goss_other_rate=0.7)
    with pytest.raises(ValueError, match="GOSS"):
        GBDTConfig(goss_top_rate=0.2, goss_other_rate=0.0)
    GBDTConfig(goss_top_rate=0.2, goss_other_rate=0.1)   # valid


def test_goss_training_still_learns():
    X, y, _ = make_tabular(2000, 8, 0, task="regression", seed=5)
    est = BoosterRegressor(n_trees=15, max_depth=4, learning_rate=0.3,
                           max_bins=64, goss_top_rate=0.2,
                           goss_other_rate=0.2)
    est.fit(X, y)
    base = np.sqrt(np.mean((y - y.mean()) ** 2))
    rmse = np.sqrt(np.mean((np.asarray(est.predict(X)) - y) ** 2))
    assert rmse < 0.5 * base


# --------------------------------------------------------------------------
# end-to-end streaming parity
# --------------------------------------------------------------------------
def _rmse(a, b):
    return float(np.sqrt(np.mean((np.asarray(a) - np.asarray(b)) ** 2)))


def test_streaming_matches_in_memory_fit():
    """Acceptance core (scaled down): a chunk-capped streamed fit over an
    ArraySource matches the in-memory fit's eval metric within 2% with
    GOSS disabled.  sketch_size >= n keeps bin edges exact, so the only
    possible divergence is the chunked accumulation itself."""
    src = SyntheticSource(4000, 10, seed=21)
    (X, y), = list(src.chunks(4000))
    X_val, y_val = next(iter(SyntheticSource(1000, 10, seed=22).chunks(1000)))

    kw = dict(n_trees=12, max_depth=4, learning_rate=0.3, max_bins=64,
              sketch_size=4096)
    mem = BoosterRegressor(**kw).fit(X, y)
    stream = BoosterRegressor(**kw)
    stream.fit(data=src, plan=ExecutionPlan(chunk_bytes=12_800))

    stats = stream.stats_
    assert stats["chunk_rows"] * 8 <= stats["n_rows"], \
        "resident chunk must be <= 1/8 of the dataset"
    assert stats["n_chunks"] >= 8

    r_mem = _rmse(mem.predict(X_val), y_val)
    r_stream = _rmse(stream.predict(X_val), y_val)
    assert r_stream <= r_mem * 1.02 + 1e-9, (r_mem, r_stream)
    # same seed + exact sketch => identical training loss trajectory
    np.testing.assert_allclose(mem.history_["train_loss"],
                               stream.history_["train_loss"], rtol=1e-5)


def test_streaming_classifier_multiclass():
    X, y, _ = make_tabular(2400, 8, 0, task="multiclass", n_classes=3,
                           seed=31)
    clf = BoosterClassifier(n_trees=6, max_depth=4, learning_rate=0.5,
                            max_bins=64)
    clf.fit(data=(X, y.astype(int)), plan=ExecutionPlan(chunk_bytes=16_000))
    assert clf.model_.n_classes == 3
    acc = np.mean(np.asarray(clf.predict(X)) == y)
    assert acc > 0.6
    proba = clf.predict_proba(X)
    np.testing.assert_allclose(proba.sum(axis=1), 1.0, atol=1e-5)


def test_streaming_goss_on_npz_shards(tmp_path):
    """GOSS over a true on-disk shard source still reaches in-memory-class
    accuracy; eval history and early stopping machinery stay wired."""
    src = SyntheticSource(4000, 8, seed=41)
    write_npz_shards(str(tmp_path), src, rows_per_shard=900)
    (X, y), = list(src.chunks(4000))
    est = BoosterRegressor(n_trees=10, max_depth=4, learning_rate=0.3,
                           max_bins=64, goss_top_rate=0.3,
                           goss_other_rate=0.3)
    est.fit(data=str(tmp_path), plan=ExecutionPlan(chunk_bytes=25_000),
            eval_set=(X[:500], y[:500]))
    assert len(est.history_["eval_loss"]) == 10
    base = np.sqrt(np.mean((y - y.mean()) ** 2))
    assert _rmse(est.predict(X), y) < 0.5 * base


def test_streaming_warm_start_and_checkpoint(tmp_path):
    src = SyntheticSource(2000, 6, seed=51)
    plan = ExecutionPlan(chunk_bytes=15_000)
    ck = str(tmp_path / "ck")
    first = BoosterRegressor(n_trees=4, max_depth=3, max_bins=32)
    first.fit(data=src, plan=plan, checkpoint_dir=ck, checkpoint_every=2)
    assert first.n_trees_ == 4
    resumed = BoosterRegressor(n_trees=6, max_depth=3, max_bins=32)
    resumed.fit(data=src, plan=plan, checkpoint_dir=ck)
    assert resumed.n_trees_ == 6

    warm = BoosterRegressor(n_trees=2, max_depth=3, max_bins=32)
    warm.fit(data=src, plan=plan, xgb_model=first)
    assert warm.n_trees_ == 6                        # 4 warm + 2 new


def test_streaming_rejects_mixed_inputs():
    src = SyntheticSource(100, 3, seed=0)
    X = np.zeros((10, 3))
    with pytest.raises(ValueError, match="not both"):
        BoosterRegressor(n_trees=1).fit(X, np.zeros(10), data=src)
    with pytest.raises(TypeError, match="fit needs"):
        BoosterRegressor(n_trees=1).fit()


def test_train_streaming_direct_api():
    """The core-layer entry point stands alone (no estimator)."""
    src = SyntheticSource(1500, 5, seed=61)
    (X, y), = list(src.chunks(1500))
    binner = StreamingBinner(max_bins=32, sketch_size=2048).fit(X)
    cfg = GBDTConfig(n_trees=5, max_depth=3, objective="reg:squarederror")
    res = train_streaming(cfg, src, binner, y, chunk_rows=400)
    assert res.model.n_trees == 5
    assert res.stats["n_chunks"] == 4
    assert res.stats["passes_per_round"] == 4        # depth 3 + 1
    data = binner.transform(X)
    in_mem = train(cfg, data, y)
    np.testing.assert_allclose(res.history["train_loss"],
                               in_mem.history["train_loss"], rtol=1e-5)


def test_npz_shard_source_rejects_mixed_widths(tmp_path):
    """chunks() validates every shard's X width and names the offender
    (a silent width change would bin garbage mid-pass)."""
    np.savez(tmp_path / "a.npz", X=np.zeros((4, 3), np.float32))
    np.savez(tmp_path / "b.npz", X=np.zeros((4, 5), np.float32))
    src = NpzShardSource(str(tmp_path))
    with pytest.raises(ValueError, match="b.npz"):
        list(src.chunks(10))


def test_npz_shard_source_rejects_misaligned_labels(tmp_path):
    np.savez(tmp_path / "a.npz", X=np.zeros((4, 3), np.float32),
             y=np.zeros((3,), np.float32))
    with pytest.raises(ValueError, match="a.npz"):
        list(NpzShardSource(str(tmp_path)).chunks(10))


def test_prefetch_iterator_close_releases_worker():
    """Abandoning the stream early (break/exception) must not leave the
    put-blocked worker thread parked holding batches."""
    from repro.data.pipeline import PrefetchIterator
    cleaned = []

    def gen():
        try:
            for i in range(1000):
                yield {"i": np.int32(i)}
        finally:
            cleaned.append(True)

    with PrefetchIterator(gen(), depth=2) as it:
        next(it)
    assert cleaned == [True]                 # generator finally ran
    assert not it._thread.is_alive()
    it.close()                               # idempotent

"""End-to-end behaviour tests for the paper's system."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan
from repro.core import GBDTConfig, GBDTModel, bin_dataset, train
from repro.data import make_tabular, paper_dataset
from repro.kernels import ops


def test_full_pipeline_regression():
    """raw floats -> binning -> boosting -> batch inference, end to end."""
    X, y, cats = make_tabular(1500, 6, 3, n_cats=8, task="regression",
                              missing_rate=0.03, seed=0)
    data = bin_dataset(X, max_bins=32, categorical_fields=cats)
    res = train(GBDTConfig(n_trees=15, max_depth=5, learning_rate=0.3,
                           hist_strategy="scatter"), data, y)
    pred = np.asarray(res.model.predict(data))
    r2 = 1 - np.mean((pred - y) ** 2) / np.var(y)
    assert r2 > 0.7, r2


def test_predict_equals_sum_of_trees():
    """Batch inference (§III-D) == margin accumulation during training."""
    X, y, cats = make_tabular(1000, 5, 0, task="regression", seed=1)
    data = bin_dataset(X, max_bins=16)
    res = train(GBDTConfig(n_trees=6, max_depth=4, learning_rate=0.5,
                           hist_strategy="scatter"), data, y)
    model = res.model
    total = model.predict_margin(data.codes)
    acc = jnp.full((1000,), model.base_margin)
    for i in range(model.n_trees):
        one = ops.traverse_tree(
            type(model.trees)(*[a[i] for a in model.trees]), data.codes,
            missing_bin=data.missing_bin,
            plan=ExecutionPlan.auto(traversal_strategy="reference"))
        acc = acc + one
    np.testing.assert_allclose(np.asarray(total), np.asarray(acc),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_paper_dataset_analogs_train():
    """Each Table-III analog trains to better-than-baseline loss."""
    for name in ("higgs", "allstate"):
        X, y, cats, spec = paper_dataset(name, n_override=1200)
        data = bin_dataset(X, max_bins=64, categorical_fields=cats)
        obj = ("binary:logistic" if spec.task == "binary"
               else "reg:squarederror")
        res = train(GBDTConfig(n_trees=6, max_depth=4, learning_rate=0.3,
                               objective=obj, hist_strategy="scatter"),
                    data, y)
        assert res.history["train_loss"][-1] < res.history["train_loss"][0]


def test_model_state_roundtrip():
    X, y, _ = make_tabular(400, 4, 0, task="regression", seed=2)
    data = bin_dataset(X, max_bins=16)
    res = train(GBDTConfig(n_trees=3, max_depth=3, hist_strategy="scatter"),
                data, y)
    m2 = GBDTModel.from_state(res.model.to_state())
    np.testing.assert_array_equal(np.asarray(res.model.predict(data)),
                                  np.asarray(m2.predict(data)))

import os
import sys

# smoke tests and benches must see the default single CPU device; only the
# dry-run launcher (a separate process) forces 512 placeholder devices.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

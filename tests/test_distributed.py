"""Distributed-path tests.  Multi-device cases run in a subprocess with 8
forced host devices (the main pytest process must keep the default single
device for everything else)."""
import os
import subprocess
import sys

import pytest

_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_with_devices(code: str, n_devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=_ROOT,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_distributed_histogram_and_tree_match_single_device():
    out = _run_with_devices(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import fit_tree
from repro.core.splits import find_best_splits
from repro.distributed.sharding import (distributed_histogram,
                                        distributed_split_combine,
                                        pjit_fit_tree)
from repro.launch.mesh import make_mesh
from repro.kernels import ops

mesh = make_mesh((4, 2), ("data", "model"))
rng = np.random.default_rng(0)
n, F, NB, NN = 4096, 8, 16, 4
codes = jnp.asarray(rng.integers(0, NB, (n, F)), jnp.uint8)
g = jnp.asarray(rng.normal(size=n), jnp.float32)
h = jnp.asarray(rng.uniform(.1, 1, n), jnp.float32)
nid = jnp.asarray(rng.integers(0, NN, n), jnp.int32)
from repro.api import ExecutionPlan
ref = ops.build_histogram(codes, g, h, nid, n_nodes=NN, n_bins=NB,
                          plan=ExecutionPlan.auto(hist_strategy="scatter"))
dist = distributed_histogram(mesh, codes, g, h, nid, n_nodes=NN,
                             n_bins=NB, strategy="scatter")
np.testing.assert_allclose(np.asarray(dist), np.asarray(ref),
                           rtol=1e-5, atol=1e-5)
iscat = jnp.zeros((F,), bool); fmask = jnp.ones((F,), bool)
ds = distributed_split_combine(mesh, dist, iscat, fmask, 1.0, 0.0, 1.0, F)
ss = find_best_splits(ref, iscat, fmask, 1.0, 0.0, 1.0)
np.testing.assert_allclose(np.asarray(ds.gain), np.asarray(ss.gain),
                           rtol=1e-5)
np.testing.assert_array_equal(np.asarray(ds.feature),
                              np.asarray(ss.feature))
codes_cm = jnp.asarray(np.asarray(codes).T.copy())
fj = pjit_fit_tree(mesh, depth=4, n_bins=NB, missing_bin=NB-1,
                   lambda_=1.0, gamma=0.0, min_child_weight=1.0)
t_dist = fj(codes, codes_cm, g, h, iscat, fmask)
t_ref = fit_tree(codes, codes_cm, g, h, depth=4, n_bins=NB,
                 missing_bin=NB-1, is_cat_field=iscat, field_mask=fmask,
                 lambda_=1.0, gamma=0.0, min_child_weight=1.0,
                 hist_strategy="scatter", partition_strategy="reference")
for a, b, nm in zip(t_dist, t_ref, t_ref._fields):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5, err_msg=nm)
print("DIST_OK")
""")
    assert "DIST_OK" in out


@pytest.mark.slow
def test_elastic_shrink_restore_preserves_predictions():
    out = _run_with_devices(r"""
import numpy as np, jax, jax.numpy as jnp, tempfile
from repro.core import GBDTConfig, GBDTModel, bin_dataset, train
from repro.data import make_tabular
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import ElasticContext
from repro.distributed.sharding import shard_dataset

X, y, cats = make_tabular(2000, 6, 0, task="regression", seed=1)
data = bin_dataset(X, max_bins=32)
res = train(GBDTConfig(n_trees=3, max_depth=3, hist_strategy="scatter"),
            data, y)
pred0 = np.asarray(res.model.predict(data))
ctx = ElasticContext(model_parallel=2)
assert ctx.mesh.shape == {"data": 4, "model": 2}
with tempfile.TemporaryDirectory() as d:
    ckpt.save(d, res.model.to_state(), step=3)
    # shrink: lose 2 devices -> (3, 2) mesh; restore onto survivor mesh
    mesh2 = ctx.resize(jax.devices()[:6])
    assert mesh2.shape == {"data": 3, "model": 2}
    sharded = shard_dataset(data, mesh2)   # pads 2000 -> 2001 (3 shards)
    state, step, _ = ckpt.restore(d, like=res.model.to_state())
    model2 = GBDTModel.from_state(state)
    pred1 = np.asarray(model2.predict(sharded))[:2000]
np.testing.assert_allclose(pred1, pred0, rtol=1e-5, atol=1e-6)
print("ELASTIC_OK")
""")
    assert "ELASTIC_OK" in out


@pytest.mark.slow
def test_train_distributed_matches_single_device_regression():
    """K=1 parity across shard counts {1, 2, 8}: dyadic targets (multiples
    of 0.25, n a power of two, squared-error h=1) make every round-0
    histogram cell exactly representable, so the first tree must be
    BIT-equal for every shard count; D=1 must be bit-equal to the fused
    single-device trainer for the WHOLE trajectory (trees and losses);
    every D must match the per-op trainer within the documented
    float-tolerance contract (identical structure, leaf values ~1e-6)."""
    out = _run_with_devices(r"""
import numpy as np, jax, jax.numpy as jnp
from repro.core import GBDTConfig, bin_dataset, train
from repro.distributed.trainer import train_distributed, data_parallel_mesh

rng = np.random.default_rng(0)
n, F = 4096, 6
X = rng.normal(size=(n, F))
y = (rng.integers(-8, 9, n) * 0.25).astype(np.float32)   # dyadic targets
data = bin_dataset(X, max_bins=32)
cfg = GBDTConfig(n_trees=4, max_depth=4, hist_strategy="scatter")
ref = train(cfg, data, y)
fused = train(GBDTConfig(n_trees=4, max_depth=4, hist_strategy="scatter",
                         fused_rounds=True), data, y)
pref = np.asarray(ref.model.predict(data))
cfg1 = GBDTConfig(n_trees=1, max_depth=4, hist_strategy="scatter")
tree0 = train(cfg1, data, y).model.trees
for D in (1, 2, 8):
    mesh = data_parallel_mesh(jax.devices()[:D])
    res = train_distributed(cfg, data, y, mesh=mesh)
    assert res.stats["n_shards"] == D
    # round 0: bit-equal to the single-device tree for EVERY shard count
    t0 = train_distributed(cfg1, data, y, mesh=mesh).model.trees
    for a, b, nm in zip(t0, tree0, tree0._fields):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"round0 D={D} {nm}")
    if D == 1:
        # one shard reassociates nothing: the full trajectory is
        # bit-equal to the fused trainer (same one-jit round program)
        for a, b, nm in zip(res.model.trees, fused.model.trees,
                            tree0._fields):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"D=1 fused {nm}")
        assert res.history["train_loss"] == fused.history["train_loss"]
    # full trajectory vs the per-op trainer: same structure, leaf values
    # within the float contract (FMA/psum reassociation)
    for nm in ("feature", "threshold", "is_cat", "default_left"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res.model.trees, nm)),
            np.asarray(getattr(ref.model.trees, nm)),
            err_msg=f"D={D} {nm}")
    p = np.asarray(res.model.predict(data))
    np.testing.assert_allclose(p, pref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(res.history["train_loss"],
                               ref.history["train_loss"],
                               rtol=1e-5, atol=1e-6)
print("PARITY_K1_OK")
""")
    assert "PARITY_K1_OK" in out


@pytest.mark.slow
def test_train_distributed_matches_single_device_multiclass():
    """K=3 softmax parity across shard counts {1, 2, 8}: softmax gradients
    are not dyadic, so D>1 psum reassociation forbids bit-equality — the
    contract is identical tree STRUCTURE (integer fields) plus allclose
    leaf values/losses for the whole fit, and D=1 stays bit-equal."""
    out = _run_with_devices(r"""
import numpy as np, jax
from repro.core import GBDTConfig, bin_dataset, train
from repro.distributed.trainer import train_distributed, data_parallel_mesh

rng = np.random.default_rng(1)
n, F = 4096, 6
X = rng.normal(size=(n, F))
y = rng.integers(0, 3, n)
data = bin_dataset(X, max_bins=32)
cfg = GBDTConfig(n_trees=3, max_depth=3, objective="multi:softmax",
                 n_classes=3, hist_strategy="scatter")
ref = train(cfg, data, y, eval_set=(data, y))
fused = train(GBDTConfig(n_trees=3, max_depth=3, objective="multi:softmax",
                         n_classes=3, hist_strategy="scatter",
                         fused_rounds=True), data, y, eval_set=(data, y))
pfused = np.asarray(fused.model.predict_margin(data.codes))
pref = np.asarray(ref.model.predict_margin(data.codes))
for D in (1, 2, 8):
    mesh = data_parallel_mesh(jax.devices()[:D])
    res = train_distributed(cfg, data, y, mesh=mesh, eval_set=(data, y))
    for nm in ("feature", "threshold", "is_cat", "default_left"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res.model.trees, nm)),
            np.asarray(getattr(ref.model.trees, nm)),
            err_msg=f"D={D} {nm}")
    p = np.asarray(res.model.predict_margin(data.codes))
    if D == 1:   # one shard: bit-equal to the fused one-jit round program
        for a, b in zip(res.model.trees, fused.model.trees):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        np.testing.assert_array_equal(p, pfused)
    np.testing.assert_allclose(p, pref, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(res.history["eval_loss"],
                               ref.history["eval_loss"],
                               rtol=1e-5, atol=1e-6)
print("PARITY_K3_OK")
""")
    assert "PARITY_K3_OK" in out


@pytest.mark.slow
def test_train_distributed_hist_subtraction_and_estimator_mesh():
    """The §II-A smaller-child masking path keeps shard parity (psum'd
    integer counts pick the same child everywhere), and the estimator's
    ``fit(mesh=...)`` surface routes through the distributed engine."""
    out = _run_with_devices(r"""
import numpy as np, jax
from repro.api import BoosterRegressor, ExecutionPlan
from repro.core import GBDTConfig, bin_dataset, train
from repro.data import make_tabular
from repro.distributed.trainer import train_distributed, data_parallel_mesh

X, y, _ = make_tabular(2048, 6, 0, task="regression", seed=3)
data = bin_dataset(X, max_bins=32)
plan = ExecutionPlan.auto(hist_subtraction=True)
cfg = GBDTConfig(n_trees=3, max_depth=4, hist_strategy="scatter")
ref = train(cfg, data, y, plan=plan)
res = train_distributed(cfg, data, y, plan=plan,
                        mesh=data_parallel_mesh(jax.devices()))
for nm in ("feature", "threshold", "is_cat", "default_left"):
    np.testing.assert_array_equal(np.asarray(getattr(res.model.trees, nm)),
                                  np.asarray(getattr(ref.model.trees, nm)),
                                  err_msg=nm)
np.testing.assert_allclose(np.asarray(res.model.predict(data)),
                           np.asarray(ref.model.predict(data)),
                           rtol=1e-5, atol=1e-6)

est = BoosterRegressor(n_trees=3, max_depth=4, max_bins=32)
est.fit(X, y, mesh=data_parallel_mesh(jax.devices()))
assert est.stats_["distributed"] and est.stats_["n_shards"] == 8
np.testing.assert_allclose(np.asarray(est.predict(X)),
                           np.asarray(ref.model.predict(data)),
                           rtol=1e-5, atol=1e-5)
print("SUBTRACT_ESTIMATOR_OK")
""")
    assert "SUBTRACT_ESTIMATOR_OK" in out


@pytest.mark.slow
def test_smoke_arch_lowers_on_tiny_production_mesh():
    """A reduced config lowers+compiles with the full sharding rules on an
    8-device (4 data x 2 model) mesh — the dry-run path end to end."""
    out = _run_with_devices(r"""
import numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke
from repro.launch.mesh import make_mesh
from repro.models import lm, optim

mesh = make_mesh((4, 2), ("data", "model"))
for aid in ("qwen3-14b", "mixtral-8x22b", "jamba-v0.1-52b"):
    cfg = get_smoke(aid)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    pshard = lm.param_shardings(cfg, mesh)
    params = jax.tree.map(jax.device_put, params, pshard)
    opt = optim.adamw_init(params)
    B, S = 8, 16
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    bshard = {k: NamedSharding(mesh, P("data", None)) for k in batch}
    batch = jax.tree.map(jax.device_put, batch, bshard)
    step = jax.jit(lm.make_train_step(cfg))
    with mesh:
        p2, o2, m = step(params, opt, batch)
    assert np.isfinite(float(m["loss"])), aid
    print("LOWER_OK", aid, float(m["loss"]))
""")
    assert out.count("LOWER_OK") == 3

"""Property tests on the system's core invariants.

The randomized-search versions need ``hypothesis``; when it is missing
(e.g. a minimal container) collection must not fail, so the import is
guarded and a deterministic fixed-seed fallback of every invariant runs
instead — same checks, fixed sample of the input space.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.api import ExecutionPlan
from repro.core.splits import find_best_splits
from repro.kernels import ops, ref
from repro.kernels.ref import TreeArrays

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - depends on the container
    HAVE_HYPOTHESIS = False


# --------------------------------------------------------------------------
# the invariants, parameterized over concrete draws (shared by both modes)
# --------------------------------------------------------------------------
def check_histogram_equivalence(shape, seed, strategy):
    n, F, NB, NN = shape
    rng = np.random.default_rng(seed)
    codes = jnp.asarray(rng.integers(0, NB, (n, F)), jnp.uint8)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.asarray(rng.uniform(0, 1, n), jnp.float32)
    nid = jnp.asarray(rng.integers(0, NN, n), jnp.int32)
    want = ref.histogram_ref(codes, g, h, nid, NN, NB)
    got = ops.build_histogram(codes, g, h, nid, n_nodes=NN, n_bins=NB,
                              plan=ExecutionPlan.auto(hist_strategy=strategy))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=3e-4, atol=3e-4)


def check_histogram_permutation_invariance(n, seed):
    """Histogram is a sum — any record permutation yields the same result."""
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 8, (n, 3)).astype(np.uint8)
    g = rng.normal(size=n).astype(np.float32)
    h = rng.uniform(0, 1, n).astype(np.float32)
    nid = rng.integers(0, 2, n).astype(np.int32)
    perm = rng.permutation(n)
    plan = ExecutionPlan.auto(hist_strategy="scatter")
    a = ops.build_histogram(jnp.asarray(codes), jnp.asarray(g),
                            jnp.asarray(h), jnp.asarray(nid),
                            n_nodes=2, n_bins=8, plan=plan)
    b = ops.build_histogram(jnp.asarray(codes[perm]), jnp.asarray(g[perm]),
                            jnp.asarray(h[perm]), jnp.asarray(nid[perm]),
                            n_nodes=2, n_bins=8, plan=plan)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-4, atol=1e-5)


def check_split_gain_nonneg_additivity(n_bins, seed):
    """Children gradient sums reconstruct the parent (hist subtraction
    trick soundness): GL + GR == Gp for the chosen split."""
    rng = np.random.default_rng(seed)
    hist = np.abs(rng.normal(size=(1, 2, n_bins, 2))).astype(np.float32)
    hist[..., :] = hist[:, :1]
    d = find_best_splits(jnp.asarray(hist), jnp.zeros((2,), bool),
                         jnp.ones((2,), bool), 1.0, 0.0, 0.0)
    f, t = int(d.feature[0]), int(d.threshold[0])
    Gp = hist[0, f, :, 0].sum()
    GL = hist[0, f, : t + 1, 0].sum() + (hist[0, f, -1, 0]
                                         if int(d.default_left[0]) else 0.0)
    GR = Gp - GL
    np.testing.assert_allclose(GL + GR, Gp, rtol=1e-5)


def check_traversal_reaches_valid_leaf(depth, n, seed):
    rng = np.random.default_rng(seed)
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth
    n_cols, n_bins = 4, 8
    feat = rng.integers(-1, n_cols, n_int).astype(np.int32)
    tree = TreeArrays(
        feature=jnp.asarray(feat),
        threshold=jnp.asarray(rng.integers(0, n_bins - 1, n_int), jnp.int32),
        is_cat=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
        default_left=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
        leaf_value=jnp.asarray(np.arange(n_leaf, dtype=np.float32)))
    codes = jnp.asarray(rng.integers(0, n_bins, (n, n_cols)), jnp.uint8)
    out = np.asarray(ref.traverse_ref(tree, codes, n_bins - 1))
    assert ((out >= 0) & (out <= n_leaf - 1)).all()
    got = np.asarray(ops.traverse_tree(
        tree, codes, missing_bin=n_bins - 1,
        plan=ExecutionPlan.auto(traversal_strategy="pallas")))
    np.testing.assert_allclose(got, out, rtol=1e-6)


def check_partition_conserves_records(n, nn, seed):
    rng = np.random.default_rng(seed)
    node_ids = jnp.asarray(rng.integers(0, nn, n), jnp.int32)
    codes = jnp.asarray(rng.integers(0, 8, (n, nn)), jnp.uint8)
    sf = jnp.asarray(rng.integers(-1, nn, nn), jnp.int32)
    st_ = jnp.asarray(rng.integers(0, 7, nn), jnp.int32)
    sc = jnp.asarray(rng.integers(0, 2, nn), jnp.int32)
    sd = jnp.asarray(rng.integers(0, 2, nn), jnp.int32)
    child = np.asarray(ref.partition_ref(node_ids, codes, sf, st_, sc, sd, 7))
    parent = np.asarray(node_ids)
    assert (child // 2 == parent).all()


# --------------------------------------------------------------------------
# hypothesis-driven search (when available)
# --------------------------------------------------------------------------
if HAVE_HYPOTHESIS:
    _shapes = st.tuples(
        st.integers(min_value=1, max_value=400),   # n records
        st.integers(min_value=1, max_value=9),     # fields
        st.integers(min_value=2, max_value=16),    # bins
        st.integers(min_value=1, max_value=4),     # nodes
    )

    @settings(max_examples=25, deadline=None)
    @given(_shapes, st.integers(0, 2 ** 31 - 1),
           st.sampled_from(["scatter", "sort", "onehot", "pallas_grouped"]))
    def test_histogram_equivalence_property(shape, seed, strategy):
        check_histogram_equivalence(shape, seed, strategy)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(1, 500), st.integers(0, 2 ** 31 - 1))
    def test_histogram_permutation_invariance(n, seed):
        check_histogram_permutation_invariance(n, seed)

    @settings(max_examples=25, deadline=None)
    @given(st.integers(2, 64), st.integers(0, 2 ** 31 - 1))
    def test_split_gain_nonneg_additivity(n_bins, seed):
        check_split_gain_nonneg_additivity(n_bins, seed)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 5), st.integers(1, 300), st.integers(0, 2 ** 31 - 1))
    def test_traversal_reaches_valid_leaf(depth, n, seed):
        check_traversal_reaches_valid_leaf(depth, n, seed)

    @settings(max_examples=20, deadline=None)
    @given(st.integers(1, 400), st.integers(1, 8), st.integers(0, 2 ** 31 - 1))
    def test_partition_conserves_records(n, nn, seed):
        check_partition_conserves_records(n, nn, seed)


# --------------------------------------------------------------------------
# deterministic fallback — always collectable, runs the same invariants on
# a fixed sample when hypothesis is absent
# --------------------------------------------------------------------------
needs_fallback = pytest.mark.skipif(
    HAVE_HYPOTHESIS, reason="hypothesis present: randomized versions run")


@needs_fallback
@pytest.mark.parametrize("strategy", ["scatter", "sort", "onehot",
                                      "pallas_grouped"])
@pytest.mark.parametrize("shape,seed", [((1, 1, 2, 1), 0),
                                        ((97, 5, 16, 4), 1),
                                        ((400, 9, 7, 3), 2)])
def test_histogram_equivalence_fallback(shape, seed, strategy):
    check_histogram_equivalence(shape, seed, strategy)


@needs_fallback
@pytest.mark.parametrize("n,seed", [(1, 0), (100, 1), (500, 2)])
def test_histogram_permutation_invariance_fallback(n, seed):
    check_histogram_permutation_invariance(n, seed)


@needs_fallback
@pytest.mark.parametrize("n_bins,seed", [(2, 0), (17, 1), (64, 2)])
def test_split_gain_nonneg_additivity_fallback(n_bins, seed):
    check_split_gain_nonneg_additivity(n_bins, seed)


@needs_fallback
@pytest.mark.parametrize("depth,n,seed", [(1, 1, 0), (3, 100, 1),
                                          (5, 300, 2)])
def test_traversal_reaches_valid_leaf_fallback(depth, n, seed):
    check_traversal_reaches_valid_leaf(depth, n, seed)


@needs_fallback
@pytest.mark.parametrize("n,nn,seed", [(1, 1, 0), (128, 4, 1), (400, 8, 2)])
def test_partition_conserves_records_fallback(n, nn, seed):
    check_partition_conserves_records(n, nn, seed)

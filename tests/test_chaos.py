"""Seeded chaos suite (PR 9): training and data-path resilience.

Everything here is DETERMINISTIC — fault schedules are seeded or pinned
to exact step indices, so the assertions are exact (bit-equal models,
exact recovery counters), never probabilistic.  The matching serving
chaos tests (bounded-queue shedding, deadline expiry, dispatcher crash
supervision) live in ``tests/test_serving.py``.

The headline invariants:

  * a streamed fit under injected IO errors, one device OOM, and one
    mid-round preemption produces the SAME model as the fault-free fit
    (chunked accumulation is chunk-size-invariant; rounds commit
    atomically and replay under per-round RNG keys);
  * checkpoint-restore recovery reproduces tree structure bit-exactly
    and leaf values to float tolerance (restored margins are recomputed
    by streamed inference);
  * corruption is LOUD: a flipped byte in a staged shard raises
    ``ShardCorruptionError`` instead of feeding garbage into a fit, and
    is never retried.
"""
import hashlib
import json
import os
import signal
import subprocess
import sys

import numpy as np
import pytest

from repro.api import (BoosterRegressor, ExecutionPlan, NpzShardSource,
                       RecoveryPolicy, RetryPolicy, RetryingSource,
                       write_npz_shards)
from repro.core.binning import StreamingBinner
from repro.core.gbdt import GBDTConfig, train_streaming
from repro.data.pipeline import BinnedShardSource, write_binned_shards
from repro.data.synthetic import SyntheticSource
from repro.distributed import checkpoint as ckpt
from repro.resilience import (DeviceOOMError, FaultSchedule, FaultySource,
                              GracefulShutdown, NumericalDivergenceError,
                              Preemption, ShardCorruptionError,
                              TrainingInterrupted, TransientIOError,
                              corrupt_file, seeded_schedule)

N, F, CHUNK = 1200, 5, 256
NO_BACKOFF = RetryPolicy(base_delay_s=0.0, max_delay_s=0.0, jitter=0.0)


def _materialize(src, n):
    xs, ys = zip(*src.chunks(n))
    return np.concatenate(xs), np.concatenate(ys)


def _fresh_source():
    return SyntheticSource(N, F, seed=7)


def _assert_trees_equal(a, b, *, leaf_rtol=None):
    """Bit-equal forests; with ``leaf_rtol`` the structure stays
    bit-strict but leaf values compare to float tolerance (the
    checkpoint-restore path recomputes margins by streamed inference)."""
    for field, u, v in zip(a.trees._fields, a.trees, b.trees):
        u, v = np.asarray(u), np.asarray(v)
        if field == "leaf_value" and leaf_rtol is not None:
            np.testing.assert_allclose(u, v, rtol=leaf_rtol, atol=1e-6,
                                       err_msg=field)
        else:
            np.testing.assert_array_equal(u, v, err_msg=field)


@pytest.fixture(scope="module")
def base():
    """Fault-free reference fit (shared: every chaos run compares to it)."""
    src = _fresh_source()
    X, y = _materialize(src, N)
    binner = StreamingBinner(max_bins=32, sketch_size=4096).fit(X)
    cfg = GBDTConfig(n_trees=6, max_depth=3, learning_rate=0.3,
                     objective="reg:squarederror")
    res = train_streaming(cfg, src, binner, y, chunk_rows=CHUNK)
    return {"X": X, "y": y, "binner": binner, "cfg": cfg, "res": res}


# --------------------------------------------------------------------------
# streaming training under injected faults
# --------------------------------------------------------------------------
def test_seeded_io_errors_absorbed_bit_equal(base):
    """A seeded storm of transient read errors, fully absorbed by
    RetryingSource: the trainer never notices, the model is bit-equal."""
    sched = seeded_schedule(123, "source", 120, rate=0.15)
    assert sched.pending() > 0
    flaky = RetryingSource(FaultySource(_fresh_source(), sched), NO_BACKOFF)
    res = train_streaming(base["cfg"], flaky, base["binner"], base["y"],
                          chunk_rows=CHUNK)
    assert flaky.stats["retries"] > 0          # the storm actually hit
    assert all(kind == "error" for _, _, kind in sched.fired)
    assert res.stats["recoveries"] == 0        # absorbed below the trainer
    _assert_trees_equal(res.model, base["res"].model)
    np.testing.assert_array_equal(res.history["train_loss"],
                                  base["res"].history["train_loss"])


def test_oom_degrades_chunk_and_preserves_model(base):
    """A device OOM mid-round halves chunk_rows and retries the round;
    chunk-size-invariant accumulation keeps the model bit-equal."""
    sched = FaultSchedule().add("source", 7, exc=DeviceOOMError)
    faulty = FaultySource(_fresh_source(), sched)
    res = train_streaming(base["cfg"], faulty, base["binner"], base["y"],
                          chunk_rows=CHUNK,
                          recovery=RecoveryPolicy(min_chunk_rows=64))
    assert res.stats["oom_halvings"] == 1
    assert res.stats["chunk_rows"] == CHUNK // 2
    assert sched.fired == [("source", 7, "error")]
    _assert_trees_equal(res.model, base["res"].model)
    np.testing.assert_array_equal(res.history["train_loss"],
                                  base["res"].history["train_loss"])


def test_oom_budget_exhaustion_propagates(base):
    """min_chunk_rows == chunk_rows leaves no room to degrade: the OOM
    must propagate instead of looping."""
    sched = FaultSchedule().add("source", 3, exc=DeviceOOMError)
    faulty = FaultySource(_fresh_source(), sched)
    with pytest.raises(DeviceOOMError):
        train_streaming(base["cfg"], faulty, base["binner"], base["y"],
                        chunk_rows=CHUNK,
                        recovery=RecoveryPolicy(min_chunk_rows=CHUNK))


def test_midround_preemption_replays_in_memory(base):
    """No checkpoint_dir: a transient failure mid-round replays the round
    from the end-of-previous-round in-memory state, bit-equal (rounds
    commit atomically; the round RNG is keyed by (seed, round))."""
    sched = FaultSchedule().add("source", 50, exc=Preemption)
    faulty = FaultySource(_fresh_source(), sched)
    res = train_streaming(base["cfg"], faulty, base["binner"], base["y"],
                          chunk_rows=CHUNK, recovery=RecoveryPolicy())
    assert res.stats["recoveries"] == 1
    assert res.stats["replayed_rounds"] == 0   # in-memory, no restore
    _assert_trees_equal(res.model, base["res"].model)
    np.testing.assert_array_equal(res.history["train_loss"],
                                  base["res"].history["train_loss"])


def test_recovery_budget_exhaustion_propagates(base):
    sched = (FaultSchedule()
             .add("source", 30, exc=Preemption)
             .add("source", 45, exc=Preemption))   # fires during the replay
    faulty = FaultySource(_fresh_source(), sched)
    with pytest.raises(Preemption):
        train_streaming(base["cfg"], faulty, base["binner"], base["y"],
                        chunk_rows=CHUNK,
                        recovery=RecoveryPolicy(max_recoveries=1))


def test_preemption_restores_from_checkpoint(base, tmp_path):
    """With checkpoint_dir set, a late preemption restores the newest
    save_named bundle and replays only the lost rounds: tree structure is
    bit-equal; leaf values match to float tolerance (restored margins are
    recomputed via streamed inference)."""
    sched = FaultSchedule().add("source", 100, exc=Preemption)  # round 5
    faulty = FaultySource(_fresh_source(), sched)
    res = train_streaming(
        base["cfg"], faulty, base["binner"], base["y"], chunk_rows=CHUNK,
        recovery=RecoveryPolicy(checkpoint_dir=str(tmp_path),
                                checkpoint_every=2))
    assert res.stats["recoveries"] == 1
    assert res.stats["replayed_rounds"] == 1   # restored round 4, lost 5
    assert res.model.n_trees == base["res"].model.n_trees
    _assert_trees_equal(res.model, base["res"].model, leaf_rtol=1e-5)


def test_combined_chaos_matches_fault_free(base):
    """The acceptance scenario: seeded IO errors + one device OOM + one
    mid-round preemption in a single fit — every recovery layer fires,
    and the final model is bit-equal to the fault-free run."""
    io_sched = seeded_schedule(5, "source", 120, rate=0.1)
    io_sched.add("source", 33, exc=DeviceOOMError)       # not retryable
    inner = RetryingSource(FaultySource(_fresh_source(), io_sched),
                           NO_BACKOFF)
    preempt = FaultSchedule().add("source", 70, exc=Preemption)
    outer = FaultySource(inner, preempt)    # above the retry wrapper: the
    res = train_streaming(                  # trainer must handle this one
        base["cfg"], outer, base["binner"], base["y"], chunk_rows=CHUNK,
        recovery=RecoveryPolicy(min_chunk_rows=64, max_recoveries=2))
    assert inner.stats["retries"] > 0                    # IO storm absorbed
    assert res.stats["oom_halvings"] == 1                # chunk degraded
    assert res.stats["recoveries"] == 1                  # round replayed
    assert ("source", 70, "error") in preempt.fired
    _assert_trees_equal(res.model, base["res"].model)
    np.testing.assert_array_equal(res.history["train_loss"],
                                  base["res"].history["train_loss"])


def test_estimator_recovery_end_to_end():
    """The same invariant through the public estimator surface:
    fit(data=RetryingSource(...), recovery=...) under seeded faults
    predicts identically to the fault-free fit."""
    src = SyntheticSource(1500, 6, seed=9)
    X, _ = _materialize(src, 1500)
    plan = ExecutionPlan(chunk_bytes=12_000)
    kw = dict(n_trees=5, max_depth=3, learning_rate=0.3, max_bins=32)
    clean = BoosterRegressor(**kw).fit(data=src, plan=plan)
    sched = seeded_schedule(11, "source", 200, rate=0.1)
    flaky = RetryingSource(
        FaultySource(SyntheticSource(1500, 6, seed=9), sched), NO_BACKOFF)
    rec = BoosterRegressor(**kw).fit(data=flaky, plan=plan,
                                     recovery=RecoveryPolicy())
    assert flaky.stats["retries"] > 0
    np.testing.assert_array_equal(np.asarray(clean.predict(X)),
                                  np.asarray(rec.predict(X)))


# --------------------------------------------------------------------------
# RetryingSource unit behavior
# --------------------------------------------------------------------------
def test_retry_budget_exhaustion_raises():
    sched = FaultSchedule()
    for step in range(3):                       # 3 consecutive failures
        sched.add("source", step, exc=TransientIOError)
    src = RetryingSource(
        FaultySource(SyntheticSource(400, 3, seed=1), sched),
        RetryPolicy(max_retries=2, base_delay_s=0.0, jitter=0.0))
    with pytest.raises(TransientIOError):
        list(src.chunks(200))
    assert src.stats["retries"] == 2


def test_corruption_is_never_retried():
    sched = FaultSchedule().add("source", 1, exc=ShardCorruptionError)
    src = RetryingSource(
        FaultySource(SyntheticSource(400, 3, seed=1), sched), NO_BACKOFF)
    with pytest.raises(ShardCorruptionError):
        list(src.chunks(200))
    assert src.stats["retries"] == 0


def test_hung_read_times_out_and_retries():
    """A latency spike past chunk_timeout_s surfaces as a (transient)
    ChunkTimeoutError; the pass re-opens and the stream stays identical."""
    plain = np.concatenate(
        [x for x, _ in SyntheticSource(400, 3, seed=1).chunks(100)])
    sched = FaultSchedule().add("source", 0, kind="latency", delay_s=0.6)
    src = RetryingSource(
        FaultySource(SyntheticSource(400, 3, seed=1), sched),
        RetryPolicy(chunk_timeout_s=0.1, base_delay_s=0.0, jitter=0.0))
    got = np.concatenate([x for x, _ in src.chunks(100)])
    assert src.stats["timeouts"] == 1 and src.stats["retries"] == 1
    np.testing.assert_array_equal(got, plain)


def test_seeded_schedule_is_deterministic():
    a = seeded_schedule(42, "source", 100, rate=0.2, latency_rate=0.1)
    b = seeded_schedule(42, "source", 100, rate=0.2, latency_rate=0.1)
    assert a.pending() == b.pending() > 0
    c = seeded_schedule(43, "source", 100, rate=0.2, latency_rate=0.1)
    assert {k for k in a._pending} != {k for k in c._pending}


# --------------------------------------------------------------------------
# shard corruption: crc32 manifests
# --------------------------------------------------------------------------
def test_corrupt_shard_detected_on_read(tmp_path):
    paths = write_npz_shards(str(tmp_path), SyntheticSource(600, 4, seed=3),
                             rows_per_shard=200)
    assert os.path.exists(tmp_path / "manifest.json")
    corrupt_file(paths[1], seed=0)             # flip bytes mid-directory
    src = NpzShardSource(str(tmp_path))        # shard 0 verifies fine
    with pytest.raises(ShardCorruptionError, match="crc32"):
        list(src.chunks(250))


def test_corrupt_first_shard_detected_at_open(tmp_path):
    paths = write_npz_shards(str(tmp_path), SyntheticSource(300, 4, seed=3),
                             rows_per_shard=200)
    corrupt_file(paths[0], seed=1)
    with pytest.raises(ShardCorruptionError, match="crc32"):
        NpzShardSource(str(tmp_path))


def test_corrupt_binned_shard_detected(tmp_path):
    src = SyntheticSource(500, 4, seed=5)
    X, _ = _materialize(src, 500)
    binner = StreamingBinner(max_bins=16, sketch_size=1024).fit(X)
    paths = write_binned_shards(str(tmp_path), src, binner,
                                rows_per_shard=200)
    corrupt_file(paths[-1], seed=2)
    with pytest.raises(ShardCorruptionError, match="crc32"):
        list(BinnedShardSource(str(tmp_path)).chunks(128))


def test_unmanifested_directory_still_loads(tmp_path):
    """Back-compat: shard directories that predate checksumming (or had
    the manifest deleted) load without verification."""
    write_npz_shards(str(tmp_path), SyntheticSource(300, 4, seed=3),
                     rows_per_shard=200)
    plain = np.concatenate(
        [x for x, _ in NpzShardSource(str(tmp_path)).chunks(100)])
    os.remove(tmp_path / "manifest.json")
    back = NpzShardSource(str(tmp_path))
    assert back.manifest is None
    got = np.concatenate([x for x, _ in back.chunks(100)])
    np.testing.assert_array_equal(got, plain)


def test_foreign_shard_rejected_by_manifest(tmp_path):
    """A file that appeared after export is not silently mixed into the
    dataset — the manifest is the directory's source of truth."""
    write_npz_shards(str(tmp_path), SyntheticSource(300, 4, seed=3),
                     rows_per_shard=200)
    np.savez(tmp_path / "zz_foreign.npz", X=np.zeros((4, 4), np.float32))
    with pytest.raises(ShardCorruptionError, match="manifest"):
        list(NpzShardSource(str(tmp_path)).chunks(100))


# --------------------------------------------------------------------------
# checkpoint torn-step fallback (satellite)
# --------------------------------------------------------------------------
def test_restore_named_falls_back_past_torn_step(tmp_path):
    """A step whose payload passes sha validation but cannot be loaded
    (torn write where the manifest was re-stamped) warns and falls back
    to the next-newest valid step instead of crashing the restore."""
    ckpt.save_named(str(tmp_path), {"a": np.arange(3)}, 1)
    ckpt.save_named(str(tmp_path), {"a": np.arange(5)}, 2)
    payload_path = tmp_path / "step_2" / "arrays.npz"
    torn = payload_path.read_bytes()[:20]       # truncated npz: unloadable
    payload_path.write_bytes(torn)
    manifest_path = tmp_path / "step_2" / "manifest.json"
    manifest = json.loads(manifest_path.read_text())
    manifest["sha256"] = hashlib.sha256(torn).hexdigest()
    manifest_path.write_text(json.dumps(manifest))
    with pytest.warns(RuntimeWarning, match="step_2"):
        arrays, step, _ = ckpt.restore_named(str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(arrays["a"], np.arange(3))


def test_restore_named_ignores_partial_dirs(tmp_path):
    """Crash debris — a stray ``step_N.tmp`` from an interrupted write,
    or a step directory with no payload — is skipped, not fatal."""
    ckpt.save_named(str(tmp_path), {"a": np.arange(2)}, 1)
    os.makedirs(tmp_path / "step_9.tmp")        # two-phase write, torn
    os.makedirs(tmp_path / "step_3")            # dir exists, no payload
    arrays, step, _ = ckpt.restore_named(str(tmp_path))
    assert step == 1
    np.testing.assert_array_equal(arrays["a"], np.arange(2))


# --------------------------------------------------------------------------
# estimator fit input validation (satellite)
# --------------------------------------------------------------------------
def _xy(n=32, f=3):
    rng = np.random.default_rng(0)
    return (rng.normal(size=(n, f)).astype(np.float32),
            rng.normal(size=n).astype(np.float32))


def test_fit_rejects_nan_labels():
    X, y = _xy()
    y[5] = np.nan
    with pytest.raises(ValueError, match="non-finite.*row 5"):
        BoosterRegressor(n_trees=1).fit(X, y)


def test_fit_rejects_inf_labels():
    X, y = _xy()
    y[-1] = np.inf
    with pytest.raises(ValueError, match="non-finite"):
        BoosterRegressor(n_trees=1).fit(X, y)


def test_fit_rejects_mismatched_lengths():
    X, y = _xy()
    with pytest.raises(ValueError, match="row-for-row"):
        BoosterRegressor(n_trees=1).fit(X, y[:-3])


def test_fit_rejects_empty_dataset():
    with pytest.raises(ValueError, match="empty dataset"):
        BoosterRegressor(n_trees=1).fit(np.zeros((0, 3), np.float32),
                                        np.zeros(0, np.float32))


def test_fit_rejects_non_2d_features():
    with pytest.raises(ValueError, match="2-D"):
        BoosterRegressor(n_trees=1).fit(np.zeros(8, np.float32),
                                        np.zeros(8, np.float32))


def test_fit_validates_eval_set():
    X, y = _xy()
    X_val, y_val = _xy(8)
    y_val[0] = np.nan
    with pytest.raises(ValueError, match="eval_set"):
        BoosterRegressor(n_trees=1).fit(X, y, eval_set=(X_val, y_val))


def test_fit_validates_streamed_labels():
    from repro.api import ArraySource
    X, y = _xy(200)
    y[77] = np.nan
    with pytest.raises(ValueError, match="streamed labels"):
        BoosterRegressor(n_trees=1).fit(
            data=ArraySource(X, y), plan=ExecutionPlan(chunk_bytes=2_000))


def test_recovery_accepted_on_every_fit_path():
    """PR 10: recovery= is no longer streaming-only — the in-memory fit
    arms the divergence sentinels (and the mesh path the full distributed
    recovery ladder) instead of rejecting the policy."""
    X, y = _xy(64)
    est = BoosterRegressor(n_trees=2, max_depth=2).fit(
        X, y, recovery=RecoveryPolicy())
    assert est.n_trees_ == 2


def test_recovery_policy_validates():
    with pytest.raises(ValueError, match="checkpoint_every"):
        RecoveryPolicy(checkpoint_every=0)
    with pytest.raises(ValueError, match="budgets"):
        RecoveryPolicy(max_recoveries=-1)
    with pytest.raises(ValueError, match="min_chunk_rows"):
        RecoveryPolicy(min_chunk_rows=0)


# --------------------------------------------------------------------------
# PR 10 — numerical divergence sentinels
# --------------------------------------------------------------------------
def test_divergence_sentinel_raises_typed():
    """An absurd learning rate overflows squared-error margins to inf in
    the first round; with a recovery policy armed the host loop raises the
    TYPED error (with the round index) instead of silently boosting NaNs."""
    X, y = _xy(64)
    with pytest.raises(NumericalDivergenceError) as ei:
        BoosterRegressor(n_trees=3, max_depth=2, learning_rate=1e20).fit(
            X, y, recovery=RecoveryPolicy(max_divergence_rollbacks=0))
    assert ei.value.round_index >= 0


def test_divergence_fused_rollback_budget_exhausts():
    """The fused engine rolls back and halves the LR on a divergence trip;
    a persistently-diverging config exhausts max_divergence_rollbacks and
    the typed error propagates (never an unbounded retry loop)."""
    X, y = _xy(64)
    with pytest.raises(NumericalDivergenceError):
        BoosterRegressor(n_trees=4, max_depth=2, learning_rate=1e30,
                         fused_rounds=True, log_every=1).fit(
            X, y, recovery=RecoveryPolicy(max_divergence_rollbacks=2))


def test_divergence_without_recovery_is_legacy_silent():
    """No recovery policy → the sentinel stays unarmed and legacy behavior
    (a NaN-loss model, caller's responsibility) is preserved."""
    X, y = _xy(64)
    est = BoosterRegressor(n_trees=2, max_depth=2, learning_rate=1e20).fit(
        X, y)
    assert not np.isfinite(est.history_["train_loss"][-1])


# --------------------------------------------------------------------------
# PR 10 — graceful shutdown: typed resumable interrupts, resume equality
# --------------------------------------------------------------------------
def test_shutdown_interrupts_host_and_fused_and_resumes_bit_equal(tmp_path):
    """sd.request() after round 2 interrupts BOTH single-process engines
    after the commit; the partial model stays fitted state and a resume
    from the checkpoint lands on the bit-identical final ensemble."""
    X, y = _xy(256)
    for i, fused in enumerate((False, True)):
        kw = dict(n_trees=6, max_depth=3, max_bins=32, seed=3,
                  fused_rounds=fused)
        gold = BoosterRegressor(**kw).fit(X, y)
        ckdir = str(tmp_path / f"ck{i}")
        est = BoosterRegressor(**kw)
        sd = GracefulShutdown()

        def cb(t_idx, model):
            if t_idx == 2:
                sd.request("SIGTERM")

        with pytest.raises(TrainingInterrupted) as ei:
            est.fit(X, y, checkpoint_dir=ckdir, checkpoint_every=2,
                    callback=cb, shutdown=sd)
        assert ei.value.rounds_done == 3
        assert ei.value.result.stats["interrupted"]
        assert est.is_fitted and est.n_trees_ == 3   # partial model kept
        res = BoosterRegressor(**kw).fit(X, y, checkpoint_dir=ckdir)
        _assert_trees_equal(res.model_, gold.model_)


def test_streaming_sigterm_delivers_typed_interrupt(base, tmp_path):
    """A REAL SIGTERM (os.kill) mid-streaming-fit: the handler finishes
    the in-flight round, commits a checkpoint, and raises the typed
    resumable error naming the signal."""
    sd = GracefulShutdown()

    def cb(t_idx, model):
        if t_idx == 2:
            os.kill(os.getpid(), signal.SIGTERM)

    with sd:
        with pytest.raises(TrainingInterrupted) as ei:
            train_streaming(
                base["cfg"], _fresh_source(), base["binner"], base["y"],
                chunk_rows=CHUNK, callback=cb, shutdown=sd,
                recovery=RecoveryPolicy(checkpoint_dir=str(tmp_path),
                                        checkpoint_every=2))
    stop = ei.value
    assert stop.signal_name == "SIGTERM"
    assert stop.rounds_done == 3
    assert stop.checkpoint_dir == str(tmp_path)
    from repro.api import serialize
    assert serialize.has_checkpoint(str(tmp_path))


def test_streaming_sigterm_resume_bit_equal(tmp_path):
    """Acceptance: SIGTERM mid-fit + resume == uninterrupted fit, bit-for-
    bit, through the public streaming estimator surface."""
    from repro.api import ArraySource
    src = SyntheticSource(1500, 6, seed=9)
    X, y = _materialize(src, 1500)
    plan = ExecutionPlan(chunk_bytes=12_000)
    kw = dict(n_trees=6, max_depth=3, learning_rate=0.3, max_bins=32)
    gold = BoosterRegressor(**kw).fit(data=ArraySource(X, y), plan=plan)
    ckdir = str(tmp_path / "ck")
    est = BoosterRegressor(**kw)
    sd = GracefulShutdown()

    def cb(t_idx, model):
        if t_idx == 2:
            os.kill(os.getpid(), signal.SIGTERM)

    with sd:
        with pytest.raises(TrainingInterrupted):
            est.fit(data=ArraySource(X, y), plan=plan, checkpoint_dir=ckdir,
                    checkpoint_every=2, callback=cb,
                    recovery=RecoveryPolicy(), shutdown=sd)
    assert est.n_trees_ == 3
    res = BoosterRegressor(**kw).fit(data=ArraySource(X, y), plan=plan,
                                     checkpoint_dir=ckdir)
    _assert_trees_equal(res.model_, gold.model_)
    np.testing.assert_array_equal(np.asarray(res.predict(X)),
                                  np.asarray(gold.predict(X)))


def test_graceful_shutdown_restores_prior_handlers():
    before = signal.getsignal(signal.SIGTERM)
    with GracefulShutdown() as sd:
        assert signal.getsignal(signal.SIGTERM) is not before
        assert not sd.requested
    assert signal.getsignal(signal.SIGTERM) is before


# --------------------------------------------------------------------------
# PR 10 — graceful kernel degradation
# --------------------------------------------------------------------------
def test_kernel_degradation_demotes_and_counts(monkeypatch):
    """A failing Pallas histogram launch demotes to the jnp scatter twin:
    the fit completes with the SAME model, warns exactly once per
    (step, strategy), and both the per-step counter and the process-wide
    resilience metric record every event."""
    from repro.kernels import histogram as hist_k
    from repro.kernels import ops
    from repro.resilience import metrics as rmetrics

    def boom(*a, **k):
        raise RuntimeError("injected kernel launch failure")

    X, y = _xy(200, 4)
    kw = dict(n_trees=3, max_depth=3, max_bins=32)
    ref = BoosterRegressor(**kw).fit(
        X, y, plan=ExecutionPlan(hist_strategy="scatter"))

    ops.reset_degradation_stats()
    before = rmetrics.counts().get("degradations", 0)
    monkeypatch.setattr(hist_k, "histogram_pallas", boom)
    with pytest.warns(RuntimeWarning, match="histogram.*scatter"):
        demoted = BoosterRegressor(**kw).fit(
            X, y, plan=ExecutionPlan(hist_strategy="pallas_grouped",
                                     interpret=True))
    stats = ops.degradation_stats()
    assert stats.get("histogram:pallas_grouped->scatter", 0) >= 1, stats
    assert rmetrics.counts().get("degradations", 0) > before
    _assert_trees_equal(demoted.model_, ref.model_)
    ops.reset_degradation_stats()


def test_pallas_probe_reports_availability():
    """plan.resolved() consults this probe before promising a Pallas
    strategy; in interpret mode (this container) every step is available
    and the probe is cached."""
    from repro.kernels import ops
    for step in ("histogram", "partition", "traversal"):
        assert ops.pallas_available(step, interpret=True) is True
        assert ops.pallas_available(step, interpret=True) is True  # cached


# --------------------------------------------------------------------------
# PR 10 — RetryingSource lifecycle
# --------------------------------------------------------------------------
def test_retrying_source_close_is_idempotent():
    src = RetryingSource(SyntheticSource(400, 3, seed=1), NO_BACKOFF)
    list(src.chunks(200))
    src.close()
    src.close()                                    # second close: no-op
    with RetryingSource(SyntheticSource(400, 3, seed=1), NO_BACKOFF) as s2:
        assert len(list(s2.chunks(200))) == 2
    assert s2._closed


def test_train_streaming_closes_source_on_every_exit(base):
    """Both the success and the failure exit path of train_streaming
    release the RetryingSource watchdog."""
    ok = RetryingSource(FaultySource(_fresh_source(), FaultSchedule()),
                        NO_BACKOFF)
    train_streaming(base["cfg"], ok, base["binner"], base["y"],
                    chunk_rows=CHUNK)
    assert ok._closed
    sched = FaultSchedule().add("source", 3, exc=DeviceOOMError)
    bad = RetryingSource(FaultySource(_fresh_source(), sched), NO_BACKOFF)
    with pytest.raises(DeviceOOMError):
        train_streaming(base["cfg"], bad, base["binner"], base["y"],
                        chunk_rows=CHUNK,
                        recovery=RecoveryPolicy(min_chunk_rows=CHUNK))
    assert bad._closed


# --------------------------------------------------------------------------
# PR 10 — deprecated distributed.fault shim
# --------------------------------------------------------------------------
def test_distributed_fault_shim_warns_once_per_access():
    from repro.distributed import fault as dfault
    from repro.resilience import faults as rfaults
    with pytest.warns(DeprecationWarning, match="resilience.faults"):
        assert dfault.FaultInjector is rfaults.FaultInjector
    with pytest.warns(DeprecationWarning):
        assert dfault.FaultSchedule is rfaults.FaultSchedule
    # the names that genuinely live there import warning-free
    assert dfault.StepJournal is not None
    with pytest.raises(AttributeError):
        dfault.NoSuchThing


# --------------------------------------------------------------------------
# PR 10 — distributed chaos matrix (in-process, D=1)
# --------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dist_base():
    """Fault-free distributed reference fit on the in-process device set
    (D=1 under plain pytest; the D∈{2,8} points run in subprocesses)."""
    import jax
    from repro.core import bin_dataset
    from repro.distributed.trainer import (data_parallel_mesh,
                                           train_distributed)
    rng = np.random.default_rng(2)
    X = rng.normal(size=(1024, 5))
    y = (rng.integers(-8, 9, 1024) * 0.25).astype(np.float32)
    data = bin_dataset(X, max_bins=32)
    cfg = GBDTConfig(n_trees=6, max_depth=3, subsample=0.8, seed=11,
                     hist_strategy="scatter")
    mesh = data_parallel_mesh(jax.devices())
    gold = train_distributed(cfg, data, y, mesh=mesh)
    return dict(data=data, y=y, cfg=cfg, mesh=mesh, gold=gold)


def _dist_run(dist_base, sched, *, dist_kw=None, recovery=None):
    from repro.distributed.trainer import (DistributedConfig,
                                           train_distributed)
    dist = DistributedConfig(fault_schedule=sched, **(dist_kw or {}))
    return train_distributed(dist_base["cfg"], dist_base["data"],
                             dist_base["y"], mesh=dist_base["mesh"],
                             dist=dist,
                             recovery=recovery or RecoveryPolicy())


def test_distributed_transient_retried_bit_equal(dist_base):
    """A transient IO error post-dispatch is retried on the SAME mesh
    (the round never committed) — no remesh, bit-equal trajectory."""
    sched = FaultSchedule().add("round", 2, exc=TransientIOError)
    res = _dist_run(dist_base, sched)
    assert res.stats["recoveries"] == 1
    assert res.stats["restarts"] == 0
    assert not sched.pending()
    _assert_trees_equal(res.model, dist_base["gold"].model)


def test_distributed_oom_subbatches_bit_equal(dist_base):
    """Device OOM doubles hist_slices (sub-batched accumulation) and
    retries; zero-stat padding keeps histograms — and therefore the whole
    model — bit-equal to the monolithic path."""
    sched = FaultSchedule().add("round", 3, exc=DeviceOOMError)
    res = _dist_run(dist_base, sched)
    assert res.stats["oom_halvings"] == 1
    assert res.stats["hist_slices"] == 2
    _assert_trees_equal(res.model, dist_base["gold"].model)


def test_distributed_injected_nan_round_replays_bit_equal(dist_base):
    """A divergence trip rolls the round back; the first replay runs at
    the SAME learning rate, so a one-shot NaN round replays bit-equal."""
    sched = FaultSchedule().add("round", 4, exc=NumericalDivergenceError)
    res = _dist_run(dist_base, sched)
    assert res.stats["divergence_rollbacks"] == 1
    _assert_trees_equal(res.model, dist_base["gold"].model)


def test_distributed_divergence_budget_exhausts(dist_base):
    sched = FaultSchedule().add("round", 2, exc=NumericalDivergenceError)
    with pytest.raises(NumericalDivergenceError):
        _dist_run(dist_base, sched,
                  recovery=RecoveryPolicy(max_divergence_rollbacks=0))


def test_distributed_preemption_restores_and_replays(dist_base, tmp_path):
    """Preemption re-meshes onto the survivors (the sole in-process device
    keeps itself), restores the newest named checkpoint, and replays —
    structure bit-equal, leaves to float tolerance."""
    sched = FaultSchedule().add("elastic", 4, exc=Preemption)
    res = _dist_run(dist_base, sched,
                    dist_kw=dict(checkpoint_dir=str(tmp_path),
                                 checkpoint_every=2),
                    recovery=RecoveryPolicy(checkpoint_dir=str(tmp_path),
                                            checkpoint_every=2))
    assert res.stats["restarts"] == 1
    assert res.stats["n_shards"] == 1
    assert res.model.n_trees == dist_base["cfg"].n_trees
    _assert_trees_equal(res.model, dist_base["gold"].model, leaf_rtol=1e-5)


def test_distributed_shutdown_interrupts_after_commit(dist_base):
    sd = GracefulShutdown()

    def cb(t_idx, model):
        if t_idx == 2:
            sd.request("SIGTERM")

    from repro.distributed.trainer import train_distributed
    with pytest.raises(TrainingInterrupted) as ei:
        train_distributed(dist_base["cfg"], dist_base["data"],
                          dist_base["y"], mesh=dist_base["mesh"],
                          callback=cb, shutdown=sd)
    assert ei.value.rounds_done == 3
    assert ei.value.result.stats["interrupted"]
    assert ei.value.result.stats["distributed"]


# --------------------------------------------------------------------------
# PR 10 — distributed chaos matrix (subprocess, D ∈ {2, 8})
# --------------------------------------------------------------------------
_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run_with_devices(code: str, n_devices: int) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=_ROOT,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


_STORM_CHILD = r"""
import numpy as np, jax, tempfile
from repro.core import GBDTConfig, bin_dataset
from repro.distributed.trainer import (DistributedConfig, data_parallel_mesh,
                                       train_distributed)
from repro.resilience import (DeviceOOMError, FaultSchedule,
                              NumericalDivergenceError, Preemption,
                              RecoveryPolicy, TransientIOError)

rng = np.random.default_rng(0)
n, F = 4096, 6
X = rng.normal(size=(n, F))
y = (rng.integers(-8, 9, n) * 0.25).astype(np.float32)
data = bin_dataset(X, max_bins=32)
cfg = GBDTConfig(n_trees=8, max_depth=3, subsample=0.8, seed=11,
                 hist_strategy="scatter")
mesh = data_parallel_mesh(jax.devices())
gold = train_distributed(cfg, data, y, mesh=mesh)

# the acceptance storm: IO + OOM + one injected NaN round + a preemption
sched = (FaultSchedule()
         .add("round", 2, exc=TransientIOError)
         .add("round", 3, exc=DeviceOOMError)
         .add("round", 4, exc=NumericalDivergenceError)
         .add("elastic", 6, exc=Preemption))
with tempfile.TemporaryDirectory() as d:
    res = train_distributed(
        cfg, data, y, mesh=mesh,
        dist=DistributedConfig(checkpoint_dir=d, checkpoint_every=1,
                               fault_schedule=sched),
        recovery=RecoveryPolicy(checkpoint_dir=d, checkpoint_every=1))
st = res.stats
assert st["recoveries"] == 1, st
assert st["oom_halvings"] == 1 and st["hist_slices"] == 2, st
assert st["divergence_rollbacks"] == 1, st
assert st["restarts"] == 1, st
assert not sched.pending()
assert res.model.n_trees == cfg.n_trees
for nm in ("feature", "threshold", "is_cat", "default_left"):
    np.testing.assert_array_equal(
        np.asarray(getattr(res.model.trees, nm)),
        np.asarray(getattr(gold.model.trees, nm)), err_msg=nm)
np.testing.assert_allclose(np.asarray(res.model.trees.leaf_value),
                           np.asarray(gold.model.trees.leaf_value),
                           rtol=1e-5, atol=1e-6)
print("DIST_STORM_OK", st["n_shards"])
"""


@pytest.mark.slow
@pytest.mark.parametrize("n_devices", [2, 8])
def test_distributed_chaos_storm_matrix(n_devices):
    """Acceptance: a seeded storm (transient IO + device OOM + one NaN
    round + a worker preemption) at D shards produces a model bit-equal
    in structure and rtol=1e-5 in leaves to the fault-free run, with
    every recovery reported in stats."""
    out = _run_with_devices(_STORM_CHILD, n_devices)
    assert f"DIST_STORM_OK {n_devices - 1}" in out   # preemption: D-1 left


_SIGTERM_CHILD = r"""
import os, signal, tempfile
import numpy as np, jax
from repro.api import (BoosterRegressor, GracefulShutdown, RecoveryPolicy,
                       TrainingInterrupted, data_parallel_mesh)

rng = np.random.default_rng(1)
X = rng.normal(size=(2048, 6))
y = rng.normal(size=2048).astype(np.float32)
mesh = data_parallel_mesh(jax.devices())
kw = dict(n_trees=8, max_depth=3, max_bins=32, seed=4)
gold = BoosterRegressor(**kw).fit(X, y, mesh=mesh)

with tempfile.TemporaryDirectory() as d:
    est = BoosterRegressor(**kw)

    def cb(t_idx, model):
        if t_idx == 3:
            os.kill(os.getpid(), signal.SIGTERM)    # real delivery

    try:
        with GracefulShutdown() as sd:
            est.fit(X, y, mesh=mesh, checkpoint_dir=d, checkpoint_every=2,
                    callback=cb, recovery=RecoveryPolicy(), shutdown=sd)
        raise AssertionError("fit survived SIGTERM")
    except TrainingInterrupted as stop:
        assert stop.signal_name == "SIGTERM", stop.signal_name
        assert stop.rounds_done == 4, stop.rounds_done
        assert est.n_trees_ == 4
    # recovery= exposes the trainer's named round checkpoint, whose EXACT
    # live margins make the D>1 resume bit-equal (a host-side margin
    # replay can differ from the fused sharded step in the last ulp)
    res = BoosterRegressor(**kw).fit(X, y, mesh=mesh, checkpoint_dir=d,
                                     recovery=RecoveryPolicy())

for a, b in zip(res.model_.trees, gold.model_.trees):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
print("DIST_SIGTERM_RESUME_OK")
"""


@pytest.mark.slow
def test_distributed_sigterm_resume_bit_equal():
    """Acceptance: SIGTERM mid-distributed-fit commits the in-flight
    round; resuming from the checkpoint yields the bit-identical final
    ensemble."""
    out = _run_with_devices(_SIGTERM_CHILD, 2)
    assert "DIST_SIGTERM_RESUME_OK" in out

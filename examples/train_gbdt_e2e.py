"""End-to-end training driver — the paper's workload at laptop scale.

Trains a few-hundred-tree GBDT (the paper trains 500 x depth-6) on a
Higgs-like dataset analog with train/validation split, early stopping,
periodic atomic checkpoints, a step journal, and crash recovery — all
through the ``repro.api`` estimator facade:

    PYTHONPATH=src python examples/train_gbdt_e2e.py \
        --records 50000 --trees 200 --ckpt-dir /tmp/gbdt_ckpt

Re-running the same command after an interruption resumes from the last
valid checkpoint and reproduces the uninterrupted run exactly
(deterministic per-tree RNG streams).
"""
import argparse
import os


from repro.api import BoosterClassifier, ExecutionPlan, paper_dataset
from repro.distributed.fault import StepJournal


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=50_000)
    ap.add_argument("--trees", type=int, default=200)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default="/tmp/gbdt_e2e_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--strategy", default="auto")
    args = ap.parse_args()

    X, y, cats, spec = paper_dataset("higgs", n_override=args.records)
    n_tr = int(args.records * 0.9)
    Xtr, ytr = X[:n_tr], y[:n_tr]
    Xte, yte = X[n_tr:], y[n_tr:]
    print(f"[e2e] {spec.comment}: {n_tr} train / {len(yte)} valid records, "
          f"{X.shape[1]} fields")

    journal = StepJournal(os.path.join(args.ckpt_dir, "journal.jsonl"))

    def cb(t_idx, model):
        if (t_idx + 1) % args.ckpt_every == 0:
            journal.append(t_idx, {"trees": model.n_trees})

    est = BoosterClassifier(n_trees=args.trees, max_depth=args.depth,
                            learning_rate=args.lr, max_bins=128,
                            categorical_fields=cats,
                            early_stopping_rounds=20, seed=0)
    est.fit(Xtr, ytr, eval_set=(Xte, yte),
            plan=ExecutionPlan.auto(hist_strategy=args.strategy),
            checkpoint_dir=args.ckpt_dir, checkpoint_every=args.ckpt_every,
            callback=cb, verbose=True)

    acc = (est.predict(Xte) == yte).mean()
    print(f"\n[e2e] {est.n_trees_} trees")
    print(f"[e2e] valid accuracy = {acc:.4f}")
    if est.history_.get("eval_loss"):
        print(f"[e2e] valid logloss  = {est.history_['eval_loss'][-1]:.5f}")
    print(f"[e2e] step times     = {est.step_times_}")


if __name__ == "__main__":
    main()

"""End-to-end training driver — the paper's workload at laptop scale.

Trains a few-hundred-tree GBDT (the paper trains 500 x depth-6) on a
Higgs-like dataset analog with train/validation split, early stopping,
periodic atomic checkpoints, a step journal, and crash recovery:

    PYTHONPATH=src python examples/train_gbdt_e2e.py \
        --records 50000 --trees 200 --ckpt-dir /tmp/gbdt_ckpt

Re-running the same command after an interruption resumes from the last
valid checkpoint and reproduces the uninterrupted run exactly
(deterministic per-tree RNG streams).
"""
import argparse
import os

import numpy as np
import jax.numpy as jnp

from repro.core import GBDTConfig, GBDTModel, bin_dataset, train
from repro.core.binning import BinnedDataset
from repro.data import paper_dataset
from repro.distributed import checkpoint as ckpt
from repro.distributed.fault import StepJournal


def split(data: BinnedDataset, y, n_tr: int):
    def sub(sl):
        return BinnedDataset(
            data.codes[sl],
            jnp.asarray(np.asarray(data.codes[sl]).T.copy()),
            data.is_categorical, data.n_bins, data.bin_edges,
            data.n_value_bins)
    return sub(slice(0, n_tr)), y[:n_tr], sub(slice(n_tr, None)), y[n_tr:]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=50_000)
    ap.add_argument("--trees", type=int, default=200)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--ckpt-dir", default="/tmp/gbdt_e2e_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--strategy", default="auto")
    args = ap.parse_args()

    X, y, cats, spec = paper_dataset("higgs", n_override=args.records)
    data = bin_dataset(X, max_bins=128, categorical_fields=cats)
    n_tr = int(args.records * 0.9)
    tr, ytr, te, yte = split(data, y, n_tr)
    print(f"[e2e] {spec.comment}: {n_tr} train / {len(yte)} valid records, "
          f"{data.n_fields} fields")

    journal = StepJournal(os.path.join(args.ckpt_dir, "journal.jsonl"))
    cfg = GBDTConfig(n_trees=args.trees, max_depth=args.depth,
                     learning_rate=args.lr,
                     objective="binary:logistic",
                     early_stopping_rounds=20,
                     hist_strategy=args.strategy, seed=0)

    init_model = None
    if ckpt.list_steps(args.ckpt_dir):
        like = train(GBDTConfig(n_trees=1, max_depth=args.depth,
                                objective=cfg.objective,
                                hist_strategy="scatter"),
                     tr, ytr).model.to_state()
        state, step, _ = ckpt.restore(args.ckpt_dir, like=like)
        init_model = GBDTModel.from_state(state)
        print(f"[e2e] resuming from checkpoint at tree {step}")
        import dataclasses
        cfg = dataclasses.replace(cfg, n_trees=args.trees - step)

    def cb(t_idx, model):
        if (t_idx + 1) % args.ckpt_every == 0:
            ckpt.save(args.ckpt_dir, model.to_state(), step=t_idx + 1)
            journal.append(t_idx, {"trees": model.n_trees})

    res = train(cfg, tr, ytr, eval_set=(te, jnp.asarray(yte)),
                init_model=init_model, callback=cb, verbose=True)
    ckpt.save(args.ckpt_dir, res.model.to_state(), step=res.model.n_trees)

    p = np.asarray(res.model.predict(te))
    acc = ((p > 0.5) == yte).mean()
    print(f"\n[e2e] {res.model.n_trees} trees")
    print(f"[e2e] valid accuracy = {acc:.4f}")
    print(f"[e2e] valid logloss  = {res.history['eval_loss'][-1]:.5f}")
    print(f"[e2e] step times     = {res.step_times}")


if __name__ == "__main__":
    main()

"""Multi-class softmax GBDT: K per-class trees per boosting round.

``BoosterClassifier`` auto-detects the class count from the label set
(integer labels 0..K-1) and trains ``multi:softmax``: vector margins
(n, K), one class-batched histogram pass per tree level, argmax
prediction.

    PYTHONPATH=src python examples/multiclass.py
"""
import numpy as np

from repro.api import BoosterClassifier, ExecutionPlan, make_tabular


def main():
    # 6k records, 4-class planted-margin target, 10 numeric fields
    X, y, _ = make_tabular(6000, 10, 0, task="multiclass", n_classes=4,
                           seed=0)
    y = y.astype(int)
    X_tr, y_tr = X[:5000], y[:5000]
    X_te, y_te = X[5000:], y[5000:]

    plan = ExecutionPlan.auto()
    print(f"execution plan: {plan.describe()}")

    est = BoosterClassifier(n_trees=30, max_depth=5, learning_rate=0.3,
                            max_bins=64)
    est.fit(X_tr, y_tr, eval_set=(X_te, y_te), plan=plan)

    model = est.model_
    print(f"objective = {model.objective}  (K = {model.n_classes} classes, "
          f"{model.n_rounds} rounds x {model.n_classes} trees = "
          f"{model.n_trees} trees)")

    proba = est.predict_proba(X_te)          # (n, K) softmax rows
    labels = est.predict(X_te)               # argmax class ids
    acc = float((labels == y_te).mean())
    majority = np.bincount(y_te).max() / len(y_te)
    print(f"test accuracy = {acc:.3f}  (majority-class baseline "
          f"{majority:.3f})")
    print(f"mean max-class probability = {proba.max(axis=1).mean():.3f}")

    # the multi-class bundle round-trips through the same one-format story
    path = est.save("/tmp/multiclass_booster")
    est2 = BoosterClassifier.load(path)
    assert np.array_equal(est2.predict(X_te), labels)
    print(f"saved + reloaded bundle at {path}; predictions identical")


if __name__ == "__main__":
    main()

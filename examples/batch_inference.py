"""Batch inference (paper §III-D): every record traverses a 500-tree
ensemble; each tree is pinned resident (one tree per BU / per VMEM table)
while records stream.

    PYTHONPATH=src python examples/batch_inference.py --records 20000
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import GBDTConfig, bin_dataset, train
from repro.data import make_tabular
from repro.kernels import ops


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=20_000)
    ap.add_argument("--trees", type=int, default=100)
    ap.add_argument("--depth", type=int, default=6)
    args = ap.parse_args()

    X, y, cats = make_tabular(args.records, 20, 8, n_cats=12,
                              task="binary", seed=0)
    data = bin_dataset(X, max_bins=64, categorical_fields=cats)
    res = train(GBDTConfig(n_trees=args.trees, max_depth=args.depth,
                           learning_rate=0.2, objective="binary:logistic",
                           hist_strategy="scatter"), data, y)
    model = res.model
    print(f"trained {model.n_trees} trees (depth {args.depth})")

    for strategy in ("reference", "pallas"):
        fn = lambda: ops.predict_ensemble(
            model.trees, data.codes, missing_bin=data.missing_bin,
            depth=args.depth, strategy=strategy)
        jax.block_until_ready(fn())  # compile
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        dt = time.perf_counter() - t0
        print(f"{strategy:10s}: {args.records/dt:12.0f} records/s "
              f"({dt*1e3:.1f} ms)  [pallas runs in interpret mode on CPU]")

    margins = np.asarray(model.predict_margin(data.codes))
    acc = ((1 / (1 + np.exp(-margins)) > 0.5) == y).mean()
    print(f"batch accuracy = {acc:.4f}")


if __name__ == "__main__":
    main()

"""Batch inference (paper §III-D): every record traverses a trained
ensemble; each tree is pinned resident (one tree per BU / per VMEM table)
while records stream.  The traversal substrate is an ``ExecutionPlan``
knob — the same ``predict`` call runs the gather walk or the Pallas
one-hot walk.

    PYTHONPATH=src python examples/batch_inference.py --records 20000
"""
import argparse
import time

import jax

from repro.api import BoosterClassifier, ExecutionPlan, make_tabular


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=20_000)
    ap.add_argument("--trees", type=int, default=100)
    ap.add_argument("--depth", type=int, default=6)
    args = ap.parse_args()

    X, y, cats = make_tabular(args.records, 20, 8, n_cats=12,
                              task="binary", seed=0)
    est = BoosterClassifier(n_trees=args.trees, max_depth=args.depth,
                            learning_rate=0.2, max_bins=64,
                            categorical_fields=cats)
    est.fit(X, y, plan=ExecutionPlan.auto(hist_strategy="scatter"))
    print(f"trained {est.n_trees_} trees (depth {args.depth})")

    # bin once up front so the timings isolate the traversal kernels;
    # "scan" is the legacy per-tree baseline, "reference" the
    # tree-batched level walk, "pallas" the tree-blocked kernel
    codes = est.binner_.transform(X)
    for name in ("scan", "reference", "pallas"):
        plan = ExecutionPlan.auto(traversal_strategy=name)
        fn = lambda: est.model_.predict_margin(codes, plan=plan)
        jax.block_until_ready(fn())  # compile
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        dt = time.perf_counter() - t0
        print(f"{name:10s}: {args.records/dt:12.0f} records/s "
              f"({dt*1e3:.1f} ms)  [pallas runs in interpret mode on CPU]")

    acc = (est.predict(X) == y).mean()
    print(f"batch accuracy = {acc:.4f}")


if __name__ == "__main__":
    main()

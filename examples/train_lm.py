"""Train a reduced LM config for a few hundred steps on synthetic text.

Shows the LM substrate (the assigned-architecture stack) end to end:
any of the ten --arch ids runs with its smoke-scale config on CPU.

    PYTHONPATH=src python examples/train_lm.py --arch qwen3-14b --steps 200
"""
import argparse
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import lm, optim


def synthetic_batch(cfg, rng, batch=8, seq=32):
    """Learnable synthetic language: next token = (3*t + 7) % vocab-ish."""
    start = rng.integers(0, cfg.vocab, (batch, 1))
    toks = [start]
    for _ in range(seq):
        toks.append((3 * toks[-1] + 7) % max(cfg.vocab - 3, 2))
    seqs = np.concatenate(toks, axis=1)
    b = {"tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
         "labels": jnp.asarray(seqs[:, 1:], jnp.int32)}
    if cfg.mrope:
        b["positions"] = jnp.broadcast_to(
            jnp.arange(seq)[None, None], (3, batch, seq)).astype(jnp.int32)
    if cfg.family == "vlm":
        b["patch_embeds"] = jnp.zeros((batch, 4, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        b["audio_embeds"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_len, cfg.d_model)),
            jnp.float32)
    return b


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--steps", type=int, default=200)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = optim.adamw_init(params)
    step = jax.jit(lm.make_train_step(cfg, base_lr=3e-3, warmup=20,
                                      total_steps=args.steps))
    rng = np.random.default_rng(0)
    first = last = None
    t0 = time.time()
    for i in range(args.steps):
        params, opt, m = step(params, opt, synthetic_batch(cfg, rng))
        loss = float(m["loss"])
        first = first if first is not None else loss
        last = loss
        if i % 25 == 0 or i == args.steps - 1:
            print(f"step {i:4d}  loss {loss:.4f}  lr {float(m['lr']):.2e}")
    print(f"\n{args.arch} ({cfg.lr_schedule} schedule): "
          f"loss {first:.3f} -> {last:.3f} in {time.time()-t0:.1f}s")
    assert last < first, "training should reduce loss"


if __name__ == "__main__":
    main()

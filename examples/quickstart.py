"""Quickstart: train a GBDT on a synthetic tabular dataset and predict.

Everything goes through the ``repro.api`` facade — raw NaN-carrying
matrices in, predictions out; binning, kernel-strategy selection and
training all happen behind ``fit``.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import BoosterRegressor, ExecutionPlan, make_tabular


def main():
    # 5k records, 8 numeric + 4 categorical fields, 5% missing values
    X, y, cat_ids = make_tabular(5000, 8, 4, n_cats=10, task="regression",
                                 missing_rate=0.05, seed=0)

    # ExecutionPlan.auto() probes the backend once: Pallas one-hot kernels
    # on TPU, the scatter/reference software paths on this CPU host.
    plan = ExecutionPlan.auto()
    print(f"execution plan: {plan.describe()}")

    est = BoosterRegressor(n_trees=40, max_depth=5, learning_rate=0.3,
                           lambda_=1.0, max_bins=64,
                           categorical_fields=cat_ids)
    est.fit(X, y, plan=plan, verbose=True)

    pred = np.asarray(est.predict(X))
    r2 = 1 - np.mean((pred - y) ** 2) / np.var(y)
    print(f"\ntrain R^2 = {r2:.4f}")
    print(f"final loss = {est.history_['train_loss'][-1]:.5f}")
    print(f"top fields by gain importance = "
          f"{np.argsort(est.feature_importances_)[::-1][:4].tolist()}")

    # one serialization story: estimator -> bundle -> estimator
    path = est.save("/tmp/quickstart_booster")
    print(f"saved bundle at {path}")
    est2 = BoosterRegressor.load(path)
    assert np.allclose(np.asarray(est2.predict(X)), pred)
    print("reloaded bundle reproduces predictions")


if __name__ == "__main__":
    main()

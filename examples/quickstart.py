"""Quickstart: train a GBDT on a synthetic tabular dataset and predict.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import GBDTConfig, bin_dataset, train
from repro.data import make_tabular


def main():
    # 5k records, 8 numeric + 4 categorical fields, 5% missing values
    X, y, cat_ids = make_tabular(5000, 8, 4, n_cats=10, task="regression",
                                 missing_rate=0.05, seed=0)
    data = bin_dataset(X, max_bins=64, categorical_fields=cat_ids)

    config = GBDTConfig(
        n_trees=40, max_depth=5, learning_rate=0.3,
        lambda_=1.0, objective="reg:squarederror",
        hist_strategy="auto",        # pallas one-hot kernel on TPU,
    )                                # scatter on this CPU host

    result = train(config, data, y, verbose=True)
    pred = np.asarray(result.model.predict(data))
    r2 = 1 - np.mean((pred - y) ** 2) / np.var(y)
    print(f"\ntrain R^2 = {r2:.4f}")
    print(f"final loss = {result.history['train_loss'][-1]:.5f}")
    print(f"step times = {result.step_times}")


if __name__ == "__main__":
    main()

"""Out-of-core training: fit a GBDT over data that never sits in memory.

Three stages:
  1. generate a synthetic larger-than-chunk dataset as on-disk npz shards
     (any DataSource works; shards are what a real export pipeline drops);
  2. fit with ``data=`` + ``ExecutionPlan(chunk_bytes=...)`` — bin edges
     from quantile sketches, histograms accumulated chunk by chunk, the
     binned matrix never materialized;
  3. compare against the in-memory fit of the same records, with and
     without GOSS.

Run:  PYTHONPATH=src python examples/streaming.py [--rows 200000]
"""
import argparse
import tempfile
import time

import numpy as np

from repro.api import (BoosterRegressor, ExecutionPlan, NpzShardSource,
                       SyntheticSource, write_npz_shards)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=100_000)
    ap.add_argument("--fields", type=int, default=32)
    ap.add_argument("--trees", type=int, default=10)
    args = ap.parse_args()

    src = SyntheticSource(args.rows, args.fields, seed=0)
    with tempfile.TemporaryDirectory() as shard_dir:
        print(f"staging {args.rows} x {args.fields} as npz shards ...")
        write_npz_shards(shard_dir, src, rows_per_shard=32_768)
        shards = NpzShardSource(shard_dir)

        # resident chunk capped at ~1/8 of the dataset
        chunk_bytes = (args.rows // 8) * (2 * args.fields + 12)
        plan = ExecutionPlan(chunk_bytes=chunk_bytes)
        est = BoosterRegressor(n_trees=args.trees, max_depth=5,
                               learning_rate=0.3, max_bins=128)
        t0 = time.perf_counter()
        est.fit(data=shards, plan=plan)
        t_stream = time.perf_counter() - t0
        s = est.stats_
        print(f"streamed fit: {t_stream:.1f}s  "
              f"({args.rows * args.trees / t_stream:,.0f} rows/s boosted); "
              f"{s['n_chunks']} chunks x {s['chunk_rows']} rows resident "
              f"({s['chunk_rows'] / s['n_rows']:.1%} of the data), "
              f"{s['passes_per_round']} passes/round")

        # GOSS: top 10% by |gradient| + 10% sampled rest, hessians reweighted
        goss = BoosterRegressor(n_trees=args.trees, max_depth=5,
                                learning_rate=0.3, max_bins=128,
                                goss_top_rate=0.1, goss_other_rate=0.1)
        t0 = time.perf_counter()
        goss.fit(data=shards, plan=plan)
        print(f"streamed+GOSS fit: {time.perf_counter() - t0:.1f}s")

        # in-memory reference on the same records
        X = np.concatenate([x for x, _ in src.chunks(args.rows)])
        y = np.concatenate([yy for _, yy in src.chunks(args.rows)])
        mem = BoosterRegressor(n_trees=args.trees, max_depth=5,
                               learning_rate=0.3, max_bins=128)
        t0 = time.perf_counter()
        mem.fit(X, y)
        print(f"in-memory fit: {time.perf_counter() - t0:.1f}s")

        for name, e in [("in-memory", mem), ("streamed", est),
                        ("streamed+GOSS", goss)]:
            rmse = float(np.sqrt(np.mean(
                (np.asarray(e.predict(X)) - y) ** 2)))
            print(f"  train RMSE {name:>14}: {rmse:.4f}")


if __name__ == "__main__":
    main()

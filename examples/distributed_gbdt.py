import os
if "XLA_FLAGS" not in os.environ:  # 8 placeholder devices for the demo mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Distributed GBDT training — the paper's §III-B cluster decomposition.

Records are sharded across a 1-D ("data",) mesh; each shard accumulates
class-batched histograms for its rows and ONE psum per level reduces
them, after which split decisions are replicated math — every shard
grows the identical tree.  On top of that the engine is elastic: a
worker killed mid-round triggers a re-mesh onto the survivors, a
restore from the newest round checkpoint, and a deterministic replay of
the in-flight rounds, all without restarting the fit.

    python examples/distributed_gbdt.py
"""
import tempfile          # noqa: E402

import jax               # noqa: E402
import numpy as np       # noqa: E402

from repro.api import ExecutionPlan                    # noqa: E402
from repro.core import GBDTConfig, bin_dataset, train  # noqa: E402
from repro.data import make_tabular                    # noqa: E402
from repro.distributed.trainer import (DistributedConfig,  # noqa: E402
                                       data_parallel_mesh,
                                       train_distributed)
from repro.resilience.faults import FaultInjector      # noqa: E402


def main():
    print(f"devices: {len(jax.devices())}")
    X, y, _ = make_tabular(8192, 8, 0, task="regression", seed=0)
    data = bin_dataset(X, max_bins=32)
    cfg = GBDTConfig(n_trees=12, max_depth=5, subsample=0.8, seed=7)
    plan = ExecutionPlan(hist_strategy="scatter").resolved()

    # single-device reference fit (per-op trainer)
    ref = train(cfg, data, y, plan=plan)
    pref = np.asarray(ref.model.predict(data))

    # ① data-parallel fit on all 8 shards: per-shard histograms, one
    #   psum per level, whole round = one jitted dispatch per shard
    mesh = data_parallel_mesh(jax.devices())
    res = train_distributed(cfg, data, y, mesh=mesh, plan=plan)
    p8 = np.asarray(res.model.predict(data))
    print(f"8-shard fit: {res.model.n_trees} trees, "
          f"final loss {res.history['train_loss'][-1]:.5f}")

    # identical tree structure; floats within the documented tolerance
    for nm in ("feature", "threshold", "is_cat", "default_left"):
        np.testing.assert_array_equal(
            np.asarray(getattr(res.model.trees, nm)),
            np.asarray(getattr(ref.model.trees, nm)), err_msg=nm)
    np.testing.assert_allclose(p8, pref, rtol=1e-5, atol=1e-6)
    print("8-shard tree structure == single-device (bit-equal), "
          "predictions allclose")

    # ② fault tolerance: kill a worker at round 5, lose two devices,
    #   restore the round-4 checkpoint and replay — fit never restarts
    with tempfile.TemporaryDirectory() as d:
        dist = DistributedConfig(
            checkpoint_dir=d, checkpoint_every=2,
            fault_injector=FaultInjector(fail_at_steps=(5,)),
            survivors=lambda devs: devs[:-2])
        hurt = train_distributed(cfg, data, y, mesh=mesh, dist=dist,
                                 plan=plan)
    print(f"injected fault: restarts={hurt.stats['restarts']}, "
          f"remesh_events={hurt.stats['remesh_events']}, "
          f"finished on {hurt.stats['n_shards']} shards")
    np.testing.assert_allclose(np.asarray(hurt.model.predict(data)), p8,
                               rtol=1e-5, atol=1e-6)
    print("post-fault ensemble matches the uninterrupted run")

    # ③ elasticity: start on 4 shards, grow to 8 between rounds
    grew = train_distributed(
        cfg, data, y, mesh=data_parallel_mesh(jax.devices()[:4]),
        dist=DistributedConfig(
            available_devices=lambda t:
            jax.devices()[:4] if t < 4 else jax.devices()))
    print(f"elastic grow: remesh_events={grew.stats['remesh_events']}")
    np.testing.assert_allclose(np.asarray(grew.model.predict(data)), p8,
                               rtol=1e-5, atol=1e-6)
    print("elastic run matches too — OK")


if __name__ == "__main__":
    main()

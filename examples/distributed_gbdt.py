import os
if "XLA_FLAGS" not in os.environ:  # 8 placeholder devices for the demo mesh
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

"""Distributed GBDT training on a (data=4, model=2) mesh — the paper's
cluster decomposition: records partitioned across the data axis (histogram
psum at the end of step ①), fields/histogram slabs across the model axis
(group-by-field at chip granularity).

    python examples/distributed_gbdt.py
"""
import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import bin_dataset, fit_tree  # noqa: E402
from repro.data import make_tabular  # noqa: E402
from repro.distributed.sharding import (gbdt_shardings, pjit_fit_tree,  # noqa: E402
                                        shard_dataset)
from repro.launch.mesh import make_mesh  # noqa: E402


def main():
    print(f"devices: {len(jax.devices())}")
    mesh = make_mesh((4, 2), ("data", "model"))
    print(f"mesh: {dict(mesh.shape)}")

    X, y, cats = make_tabular(8192, 8, 0, task="regression", seed=0)
    data = bin_dataset(X, max_bins=32)
    sharded = shard_dataset(data, mesh)
    print(f"codes sharding: {sharded.codes.sharding.spec}")

    g = jnp.asarray(y - y.mean(), jnp.float32)
    h = jnp.ones_like(g)
    sh = gbdt_shardings(mesh)
    g = jax.device_put(g, sh["per_record"])
    h = jax.device_put(h, sh["per_record"])

    grow = pjit_fit_tree(mesh, depth=5, n_bins=data.n_bins,
                         missing_bin=data.missing_bin, lambda_=1.0,
                         gamma=0.0, min_child_weight=1.0)
    tree_d = grow(sharded.codes, sharded.codes_cm, g, h,
                  sharded.is_categorical, jnp.ones((data.n_fields,), bool))

    # must equal the single-device grower bit-for-bit (same splits)
    tree_s = fit_tree(data.codes, data.codes_cm, g, h, depth=5,
                      n_bins=data.n_bins, missing_bin=data.missing_bin,
                      is_cat_field=data.is_categorical,
                      field_mask=jnp.ones((data.n_fields,), bool),
                      lambda_=1.0, gamma=0.0, min_child_weight=1.0,
                      hist_strategy="scatter",
                      partition_strategy="reference")
    same = all(bool(jnp.allclose(a, b, rtol=1e-4, atol=1e-5))
               for a, b in zip(tree_d, tree_s))
    print(f"distributed tree == single-device tree: {same}")
    assert same


if __name__ == "__main__":
    main()

"""Recovery policy for self-healing training — streaming AND distributed.

``train_streaming(recovery=RecoveryPolicy(...))`` and
``train_distributed(recovery=RecoveryPolicy(...))`` share one policy
object and one classification: a transient failure mid-round restores
the newest checkpoint and deterministically replays the lost rounds
WITHOUT restarting the fit (the per-round RNG stream is keyed by
``(seed, round)``, so a replayed round reproduces the fault-free round);
a device OOM degrades the per-round memory footprint bit-equally (the
streaming trainer halves the streamed chunk size, the distributed
trainer doubles the per-shard histogram sub-batch count — both
accumulations are split-invariant); a preemption additionally re-meshes
the distributed fit onto the surviving devices before the replay; and a
numerical divergence (non-finite loss/margins caught by the sentinels)
rolls back to the last finite round, backing off the learning rate when
the same round diverges twice.

Action classification lives here (:func:`classify`) so the trainers'
except-clauses stay dispatch tables, not policy decisions.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.resilience.errors import (NumericalDivergenceError, is_oom,
                                     is_transient)


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """What a trainer may do when a round fails.

    checkpoint_dir:    where round checkpoints live.  When set, the
                       trainer writes one every ``checkpoint_every``
                       rounds (atomic bundles) and transient recovery
                       restores the newest valid one; when None,
                       transient recovery replays from the in-memory
                       end-of-previous-round state instead.
    checkpoint_every:  round cadence of trainer-side checkpoints.
    max_recoveries:    transient-failure budget for the whole fit; the
                       (max_recoveries + 1)-th transient failure
                       propagates.
    max_oom_halvings:  how many times an OOM may degrade the round's
                       memory footprint (chunk_rows halving / histogram
                       sub-batch doubling) before propagating.
    min_chunk_rows:    streaming degradation floor — never stream
                       smaller chunks.
    retry_delay_s:     pause before a replay (lets a flaky mount settle).
    max_divergence_rollbacks:
                       how many divergence-sentinel trips may roll the
                       fit back to the last finite round before the
                       :class:`NumericalDivergenceError` propagates.
    divergence_backoff:
                       learning-rate multiplier applied when the SAME
                       round diverges on its replay (a one-shot injected
                       divergence replays at the original rate and stays
                       bit-equal; persistent divergence shrinks steps).
    """

    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 5
    max_recoveries: int = 3
    max_oom_halvings: int = 3
    min_chunk_rows: int = 256
    retry_delay_s: float = 0.0
    max_divergence_rollbacks: int = 2
    divergence_backoff: float = 0.5

    def __post_init__(self):
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if self.max_recoveries < 0 or self.max_oom_halvings < 0:
            raise ValueError("recovery budgets must be >= 0")
        if self.min_chunk_rows < 1:
            raise ValueError("min_chunk_rows must be >= 1")
        if self.max_divergence_rollbacks < 0:
            raise ValueError("max_divergence_rollbacks must be >= 0")
        if not 0.0 < self.divergence_backoff < 1.0:
            raise ValueError("divergence_backoff must be in (0, 1)")


def classify(exc: BaseException) -> str:
    """``"divergence"`` | ``"oom"`` | ``"transient"`` | ``"fatal"`` —
    the trainers' recovery branches (rollback, degrade, replay,
    propagate)."""
    if isinstance(exc, NumericalDivergenceError):
        return "divergence"
    if is_oom(exc):
        return "oom"
    if is_transient(exc):
        return "transient"
    return "fatal"

"""Recovery policy for self-healing streaming training.

``train_streaming(recovery=RecoveryPolicy(...))`` turns the out-of-core
trainer into the single-device twin of PR 6's elastic distributed
engine: a transient source failure mid-round restores the newest
``save_named`` checkpoint and deterministically replays the lost rounds
WITHOUT restarting the fit (the per-round RNG stream is keyed by
``(seed, round)``, so a replayed round reproduces the fault-free round);
a device OOM halves the streamed chunk size and retries the round
(chunked histogram accumulation is chunk-size-invariant, so degradation
never changes the model — only its memory footprint).

Action classification lives here (:func:`classify`) so the trainer's
except-clause stays a dispatch table, not a policy decision.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.resilience.errors import is_oom, is_transient


@dataclasses.dataclass(frozen=True)
class RecoveryPolicy:
    """What ``train_streaming`` may do when a round fails.

    checkpoint_dir:    where round checkpoints live.  When set, the
                       trainer writes one every ``checkpoint_every``
                       rounds (atomic ``save_named`` bundles) and
                       transient recovery restores the newest valid one;
                       when None, transient recovery replays from the
                       in-memory end-of-previous-round state instead.
    checkpoint_every:  round cadence of trainer-side checkpoints.
    max_recoveries:    transient-failure budget for the whole fit; the
                       (max_recoveries + 1)-th transient failure
                       propagates.
    max_oom_halvings:  how many times an OOM may halve ``chunk_rows``
                       before propagating.
    min_chunk_rows:    degradation floor — never stream smaller chunks.
    retry_delay_s:     pause before a replay (lets a flaky mount settle).
    """

    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 5
    max_recoveries: int = 3
    max_oom_halvings: int = 3
    min_chunk_rows: int = 256
    retry_delay_s: float = 0.0

    def __post_init__(self):
        if self.checkpoint_every < 1:
            raise ValueError("checkpoint_every must be >= 1")
        if self.max_recoveries < 0 or self.max_oom_halvings < 0:
            raise ValueError("recovery budgets must be >= 0")
        if self.min_chunk_rows < 1:
            raise ValueError("min_chunk_rows must be >= 1")


def classify(exc: BaseException) -> str:
    """``"oom"`` | ``"transient"`` | ``"fatal"`` — the trainer's three
    recovery branches (degrade, replay, propagate)."""
    if is_oom(exc):
        return "oom"
    if is_transient(exc):
        return "transient"
    return "fatal"

"""``repro.resilience`` — the unified fault-domain authority.

One place for everything that keeps long fits and serving daemons alive
under real-world failure: a typed error taxonomy (``errors``), shared
seeded fault-injection primitives (``faults`` — the generalization of
PR 6's round-level ``FaultInjector``), a self-healing ``DataSource``
wrapper (``retry``), the recovery policy driving BOTH trainers'
checkpoint-restore/replay, OOM degradation and divergence rollback
(``recovery``), the preemption-safe signal layer (``shutdown``) and the
process-wide resilience counters the perf gate reads (``metrics``).
Serving-side hardening (bounded queues, deadline failures, the
dispatcher supervisor) lives in ``repro.serving`` and fails futures with
the types defined here.
"""
from repro.resilience import metrics
from repro.resilience.errors import (ChunkTimeoutError, DeadlineExceededError,
                                     DeviceOOMError, DispatcherCrashError,
                                     NumericalDivergenceError, Preemption,
                                     QueueFullError, ResilienceError,
                                     ShardCorruptionError,
                                     TrainingInterrupted, TransientIOError,
                                     is_oom, is_transient)
from repro.resilience.faults import (Fault, FaultInjector, FaultSchedule,
                                     FaultySource, corrupt_file,
                                     seeded_schedule)
from repro.resilience.recovery import RecoveryPolicy, classify
from repro.resilience.retry import RetryPolicy, RetryingSource
from repro.resilience.shutdown import GracefulShutdown

__all__ = [
    "ResilienceError", "TransientIOError", "ChunkTimeoutError", "Preemption",
    "ShardCorruptionError", "DeviceOOMError", "NumericalDivergenceError",
    "TrainingInterrupted", "QueueFullError", "DeadlineExceededError",
    "DispatcherCrashError", "is_oom", "is_transient",
    "Fault", "FaultSchedule", "FaultInjector", "FaultySource",
    "seeded_schedule", "corrupt_file",
    "RecoveryPolicy", "classify",
    "RetryPolicy", "RetryingSource",
    "GracefulShutdown", "metrics",
]

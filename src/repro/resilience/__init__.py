"""``repro.resilience`` — the unified resilience layer.

One place for everything that keeps long fits and serving daemons alive
under real-world failure: a typed error taxonomy (``errors``), shared
seeded fault-injection primitives (``faults`` — the generalization of
PR 6's round-level ``FaultInjector``), a self-healing ``DataSource``
wrapper (``retry``), and the recovery policy driving
``train_streaming``'s checkpoint-restore/replay and OOM chunk
degradation (``recovery``).  Serving-side hardening (bounded queues,
deadline failures, the dispatcher supervisor) lives in ``repro.serving``
and fails futures with the types defined here.
"""
from repro.resilience.errors import (ChunkTimeoutError, DeadlineExceededError,
                                     DeviceOOMError, DispatcherCrashError,
                                     Preemption, QueueFullError,
                                     ResilienceError, ShardCorruptionError,
                                     TransientIOError, is_oom, is_transient)
from repro.resilience.faults import (Fault, FaultInjector, FaultSchedule,
                                     FaultySource, corrupt_file,
                                     seeded_schedule)
from repro.resilience.recovery import RecoveryPolicy, classify
from repro.resilience.retry import RetryPolicy, RetryingSource

__all__ = [
    "ResilienceError", "TransientIOError", "ChunkTimeoutError", "Preemption",
    "ShardCorruptionError", "DeviceOOMError", "QueueFullError",
    "DeadlineExceededError", "DispatcherCrashError", "is_oom", "is_transient",
    "Fault", "FaultSchedule", "FaultInjector", "FaultySource",
    "seeded_schedule", "corrupt_file",
    "RecoveryPolicy", "classify",
    "RetryPolicy", "RetryingSource",
]

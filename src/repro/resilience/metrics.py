"""Process-wide resilience counters.

The perf gate needs to distinguish "slow" from "silently degraded" and
"slow" from "spent the round budget recovering" — so every resilience
event increments a named process-wide counter here, and the benchmark
harness snapshots the counters around each lane
(``benchmarks/run.py`` records per-lane ``degradations`` /
``recoveries`` in the emitted JSON).

Two event families today:

  * ``"degradations"`` — a Pallas kernel launch failed and the dispatch
    demoted the plan's strategy to the jnp twin
    (``repro.kernels.ops``);
  * ``"recoveries"`` — a trainer recovery branch fired (transient
    replay, OOM degradation, divergence rollback — streaming or
    distributed).

Counters are cumulative per process; use :func:`snapshot` around a
region to attribute events to it.  Thread-safe (the serving daemon and
prefetch threads may record concurrently).
"""
from __future__ import annotations

import threading
from collections import Counter
from typing import Dict

_lock = threading.Lock()
_counts: Counter = Counter()


def record(kind: str, n: int = 1) -> None:
    """Increment the ``kind`` counter by ``n``."""
    with _lock:
        _counts[kind] += int(n)


def counts() -> Dict[str, int]:
    """A copy of every counter (cumulative since process start/reset)."""
    with _lock:
        return dict(_counts)


def snapshot() -> Dict[str, int]:
    """Alias of :func:`counts` — pair two calls to diff a region."""
    return counts()


def delta(before: Dict[str, int]) -> Dict[str, int]:
    """Counters accumulated since ``before`` (a :func:`snapshot`)."""
    now = counts()
    keys = set(now) | set(before)
    return {k: now.get(k, 0) - before.get(k, 0) for k in keys
            if now.get(k, 0) - before.get(k, 0)}


def reset() -> Dict[str, int]:
    """Zero every counter; returns the pre-reset values."""
    with _lock:
        old = dict(_counts)
        _counts.clear()
        return old

"""RetryingSource — a self-healing ``DataSource`` wrapper.

Long out-of-core fits stream the same shards hundreds of times (one pass
per tree level), so a transient read error minutes into a run must not
kill the fit.  ``RetryingSource`` wraps any ``DataSource`` and retries
*transient* failures (see :func:`repro.resilience.errors.is_transient`)
with exponential backoff + seeded jitter; corruption
(:class:`ShardCorruptionError`) and other non-transient errors propagate
immediately — retrying them would loop forever or mask real damage.

Recovery mechanics: the ``DataSource`` contract guarantees restartable,
deterministic passes, so after a failed read the wrapper re-opens
``source.chunks(rows)`` and fast-forwards past the chunks already
delivered this pass — consumers observe an uninterrupted, identical
chunk stream (possibly delayed).  The fast-forward re-reads skipped
chunks, which is the price of not buffering them; the per-*chunk* retry
budget resets on every successful read so one flaky shard cannot starve
a long pass.

An optional per-chunk timeout (``chunk_timeout_s``) guards against hung
reads: the fetch runs on a worker thread and a timeout surfaces as
:class:`ChunkTimeoutError` (transient, so it retries).  The thread is
only spawned when a timeout is configured — the fault-free hot path adds
no thread hops and no measurable overhead (gated by the streaming bench
lanes).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Optional

import numpy as np

from repro.resilience.errors import ChunkTimeoutError, is_transient


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Backoff/timeout knobs for :class:`RetryingSource`.

    max_retries:      consecutive failed attempts allowed per chunk.
    base_delay_s:     backoff starts here and doubles per attempt...
    max_delay_s:      ...capped here.
    jitter:           +/- fraction of the delay randomized (seeded) so
                      parallel readers don't retry in lockstep.
    chunk_timeout_s:  per-chunk fetch deadline (None = no watchdog).
    seed:             jitter RNG seed (determinism for tests).
    """

    max_retries: int = 4
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    jitter: float = 0.5
    chunk_timeout_s: Optional[float] = None
    seed: int = 0

    def __post_init__(self):
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")
        if not 0.0 <= self.jitter <= 1.0:
            raise ValueError("jitter must be in [0, 1]")

    def delay_s(self, attempt: int, rng: np.random.Generator) -> float:
        """Backoff before retry ``attempt`` (1-based): exponential,
        capped, jittered."""
        base = min(self.base_delay_s * (2.0 ** (attempt - 1)),
                   self.max_delay_s)
        if self.jitter:
            base *= 1.0 + self.jitter * (2.0 * float(rng.random()) - 1.0)
        return max(base, 0.0)


class RetryingSource:
    """Wrap ``source`` so transient chunk-read failures self-heal.

    Presents the unchanged ``DataSource`` protocol; ``stats`` counts the
    recovery work (retries, timeouts, reopened passes) so chaos tests —
    and operators — can see the wrapper actually absorbed faults.
    """

    def __init__(self, source, policy: RetryPolicy = RetryPolicy()):
        self._source = source
        self.policy = policy
        self.stats = {"retries": 0, "timeouts": 0, "reopened_passes": 0}
        self._watchdog: Optional[threading.Thread] = None
        self._closed = False

    @property
    def n_fields(self) -> int:
        return self._source.n_fields

    def __getattr__(self, name):
        return getattr(self._source, name)

    # -- lifecycle -----------------------------------------------------------
    def close(self, timeout_s: float = 1.0) -> None:
        """Finalize the wrapper: join the last watchdog thread (bounded
        wait — a genuinely hung fetch stays abandoned, the thread is a
        daemon) and close the wrapped source when it supports closing.
        Idempotent, and parity with ``PrefetchIterator.close()``:
        ``train_streaming`` calls this on every exit path so a fit never
        leaks a fetch thread or an open shard handle."""
        if self._closed:
            return
        self._closed = True
        t = self._watchdog
        if t is not None and t.is_alive():
            t.join(timeout=timeout_s)
        self._watchdog = None
        inner_close = getattr(self._source, "close", None)
        if callable(inner_close):
            inner_close()

    def __enter__(self) -> "RetryingSource":
        self._closed = False
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    # -- the protected pass --------------------------------------------------
    def _open(self, rows: int, skip: int):
        """A fresh pass iterator fast-forwarded past ``skip`` delivered
        chunks (DataSource passes are deterministic, so chunk ``skip``
        of the new pass IS the chunk that failed)."""
        it = iter(self._source.chunks(rows))
        for _ in range(skip):
            next(it)
        return it

    def _fetch(self, it):
        """One ``next(it)``, under the watchdog when configured.  A
        timed-out fetch abandons the worker thread (daemonized) and
        raises ChunkTimeoutError; the caller re-opens the pass."""
        timeout = self.policy.chunk_timeout_s
        if timeout is None:
            return next(it)
        out: queue.Queue = queue.Queue(maxsize=1)

        def worker():
            try:
                out.put(("ok", next(it)))
            except BaseException as e:  # noqa: BLE001 — re-raised below
                out.put(("err", e))
        t = threading.Thread(target=worker, daemon=True)
        self._watchdog = t           # joined (bounded) by close()
        t.start()
        try:
            status, value = out.get(timeout=timeout)
        except queue.Empty:
            self.stats["timeouts"] += 1
            raise ChunkTimeoutError(
                f"chunk fetch exceeded {timeout:g}s") from None
        if status == "err":
            raise value
        return value

    def chunks(self, rows: int):
        rng = np.random.default_rng(self.policy.seed)
        it = iter(self._source.chunks(rows))
        delivered = 0          # chunks yielded this pass
        attempts = 0           # consecutive failures at the current chunk
        reopen = False
        while True:
            try:
                if reopen:
                    # the reopen + fast-forward reads the source too, so it
                    # must sit INSIDE the retry loop: a fault that fires
                    # while skipping already-delivered chunks is just
                    # another transient failure, not a fit-killer
                    it = self._open(rows, delivered)
                    reopen = False
                chunk = self._fetch(it)
            except StopIteration:
                return
            except BaseException as exc:  # noqa: BLE001 — classified below
                if not is_transient(exc) or attempts >= \
                        self.policy.max_retries:
                    raise
                attempts += 1
                self.stats["retries"] += 1
                time.sleep(self.policy.delay_s(attempts, rng))
                self.stats["reopened_passes"] += 1
                reopen = True
                continue
            attempts = 0
            delivered += 1
            yield chunk

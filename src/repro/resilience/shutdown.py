"""GracefulShutdown — preemption-safe fits via a typed signal layer.

Long fits on preemptible capacity receive SIGTERM with a grace window.
Killing the process mid-round loses the in-flight tree and any state
since the last checkpoint cadence; this layer converts the signal into a
*between-rounds* exit instead:

  1. ``with GracefulShutdown() as gs`` installs SIGTERM/SIGINT handlers
     that only set a flag (handlers must stay async-signal-safe);
  2. the trainers check ``gs.requested`` after each round COMMITS —
     the in-flight round always finishes;
  3. on a requested shutdown the trainer writes one final atomic
     checkpoint (when a checkpoint dir is configured) and raises
     :class:`~repro.resilience.errors.TrainingInterrupted`, a typed
     resumable status carrying the committed round count, the
     checkpoint dir and the partial ``TrainResult``;
  4. re-running the same fit against the same ``checkpoint_dir``
     (``launch/train.py --resume``, or any ``fit(checkpoint_dir=...)``)
     restores the committed rounds and deterministically grows the rest
     — the per-round RNG stream is keyed by ``(seed, round)``, so the
     resumed ensemble reproduces the uninterrupted one.

The context manager restores the previous handlers on exit, so a fit
inside a larger application never leaks handler state.  ``request()``
lets tests (and in-process supervisors) trigger the same path without
delivering a real signal.
"""
from __future__ import annotations

import signal
import threading
from typing import Optional, Tuple


class GracefulShutdown:
    """Flag-setting signal handler scope (see module doc).

    signals:  which signals request a graceful exit (default
              SIGTERM + SIGINT).  Installation requires the main
              thread; constructing on a worker thread is allowed but
              ``__enter__`` will raise (Python restricts
              ``signal.signal`` to the main thread).
    """

    def __init__(self, signals: Tuple[int, ...] = (signal.SIGTERM,
                                                   signal.SIGINT)):
        self.signals = tuple(signals)
        self._requested = threading.Event()
        self._signal_name: Optional[str] = None
        self._previous = {}

    # -- handler scope -------------------------------------------------------
    def __enter__(self) -> "GracefulShutdown":
        for sig in self.signals:
            self._previous[sig] = signal.signal(sig, self._handler)
        return self

    def __exit__(self, *exc_info) -> None:
        for sig, prev in self._previous.items():
            signal.signal(sig, prev)
        self._previous.clear()

    def _handler(self, signum, frame) -> None:
        # async-signal-safe: set the flag, remember the name, return
        if self._signal_name is None:
            try:
                self._signal_name = signal.Signals(signum).name
            except ValueError:
                self._signal_name = str(signum)
        self._requested.set()

    # -- trainer surface -----------------------------------------------------
    @property
    def requested(self) -> bool:
        """Has a shutdown been requested?  Checked between rounds."""
        return self._requested.is_set()

    @property
    def signal_name(self) -> Optional[str]:
        """Name of the signal that requested the exit (None if none)."""
        return self._signal_name

    def request(self, name: str = "manual") -> None:
        """Programmatic shutdown request (tests, in-process supervisors)
        — same observable behavior as a delivered signal."""
        if self._signal_name is None:
            self._signal_name = name
        self._requested.set()

"""Shared fault-injection primitives — one harness for IO, device and
serving chaos.

PR 6 grew a single-purpose ``FaultInjector`` (raise at given training
rounds) inside ``repro.distributed.fault``; this module generalizes it
into *sites* and *kinds* so every layer injects through the same,
seeded, deterministic machinery:

  * a :class:`Fault` targets one ``(site, step)`` point — sites are free
    strings owned by the instrumented layer (``"step"`` for training
    rounds, ``"source"`` for chunk reads, ``"dispatch"`` for serving
    flushes);
  * a :class:`FaultSchedule` holds the pending faults and fires each at
    most once: ``kind="error"`` raises, ``kind="latency"`` sleeps
    ``delay_s`` (an IO latency spike) and returns;
  * :class:`FaultySource` wraps any ``DataSource`` and applies a
    schedule to its chunk stream — the step index is the monotonic read
    counter across passes, so a schedule can target "the 7th chunk read
    overall", i.e. mid-round for a multi-pass streaming trainer;
  * :func:`corrupt_file` deterministically flips bytes in a staged shard
    (what the crc32 manifest verification must catch);
  * :func:`seeded_schedule` draws a reproducible random schedule from a
    seed — the chaos suite's input.

Everything is deterministic given the constructor arguments: chaos tests
assert exact outcomes, not probabilistic ones.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.resilience.errors import TransientIOError


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault at ``(site, step)``.

    kind:     ``"error"`` raises ``exc(message)``; ``"latency"`` sleeps
              ``delay_s`` then lets the step proceed.
    exc:      exception type for ``kind="error"``.
    """

    site: str
    step: int
    kind: str = "error"
    exc: type = RuntimeError
    message: Optional[str] = None
    delay_s: float = 0.0

    def raise_(self) -> None:
        raise self.exc(self.message
                       or f"injected fault at {self.site}[{self.step}]")


class FaultSchedule:
    """A set of pending faults, each fired at most once.

    ``apply(site, step)`` is the ONE instrumentation point a layer
    needs: latency faults sleep, error faults raise.  ``fired`` records
    ``(site, step, kind)`` triples in firing order so tests can assert
    the schedule actually exercised what it claims to.
    """

    def __init__(self, faults: Iterable[Fault] = ()):
        self._pending: Dict[Tuple[str, int], List[Fault]] = {}
        for f in faults:
            self._pending.setdefault((f.site, f.step), []).append(f)
        self.fired: List[Tuple[str, int, str]] = []

    def add(self, site: str, step: int, *, kind: str = "error",
            exc: type = RuntimeError, message: Optional[str] = None,
            delay_s: float = 0.0) -> "FaultSchedule":
        self._pending.setdefault((site, int(step)), []).append(
            Fault(site, int(step), kind, exc, message, delay_s))
        return self

    def pending(self) -> int:
        return sum(len(v) for v in self._pending.values())

    def apply(self, site: str, step: int) -> None:
        """Fire every fault scheduled at ``(site, step)``: sleep for
        latency kinds, then raise the first error kind (if any)."""
        faults = self._pending.pop((site, int(step)), None)
        if not faults:
            return
        to_raise = None
        for f in faults:
            self.fired.append((f.site, f.step, f.kind))
            if f.kind == "latency":
                time.sleep(f.delay_s)
            elif to_raise is None:
                to_raise = f
        if to_raise is not None:
            to_raise.raise_()


class FaultInjector(FaultSchedule):
    """PR 6's round-level injector, now a thin shim over the shared
    schedule (``distributed.fault`` re-exports it unchanged): raise
    ``exc`` the first time each step in ``fail_at_steps`` is checked."""

    def __init__(self, fail_at_steps: Iterable[int] = (),
                 exc: type = RuntimeError):
        super().__init__(Fault("step", int(s), exc=exc,
                               message=f"injected fault at step {int(s)}")
                         for s in fail_at_steps)
        self.fail_at = {int(s) for s in fail_at_steps}
        self.exc = exc

    def check(self, step: int) -> None:
        self.apply("step", step)


class FaultySource:
    """Inject scheduled faults into a ``DataSource``'s chunk stream.

    Each chunk read consumes one step of ``site`` (monotonic across
    passes AND across retries — a retried read gets a fresh index, so a
    one-shot fault does not re-fire on the retry).  The fault fires
    BEFORE the chunk is yielded: an ``"error"`` fault makes the read
    fail as a flaky filesystem would, a ``"latency"`` fault stalls it.
    """

    def __init__(self, source, schedule: FaultSchedule,
                 site: str = "source"):
        self._source = source
        self.schedule = schedule
        self.site = site
        self.reads = 0               # monotonic chunk-read counter

    @property
    def n_fields(self) -> int:
        return self._source.n_fields

    def chunks(self, rows: int):
        for chunk in self._source.chunks(rows):
            step = self.reads
            self.reads += 1
            self.schedule.apply(self.site, step)
            yield chunk

    def __getattr__(self, name):
        return getattr(self._source, name)


def seeded_schedule(seed: int, site: str, n_steps: int, *,
                    rate: float = 0.1, exc: type = TransientIOError,
                    latency_rate: float = 0.0,
                    max_delay_s: float = 0.01) -> FaultSchedule:
    """Draw a deterministic random schedule: each step in
    ``range(n_steps)`` independently gets an error fault with
    probability ``rate`` and a latency spike with ``latency_rate``.
    Same seed → same schedule, every run."""
    rng = np.random.default_rng(seed)
    sched = FaultSchedule()
    for step in range(int(n_steps)):
        if rng.random() < rate:
            sched.add(site, step, exc=exc,
                      message=f"injected {exc.__name__} at "
                              f"{site}[{step}] (seed {seed})")
        if latency_rate and rng.random() < latency_rate:
            sched.add(site, step, kind="latency",
                      delay_s=float(rng.random() * max_delay_s))
    return sched


def corrupt_file(path: str, *, seed: int = 0, n_bytes: int = 8) -> List[int]:
    """Deterministically flip ``n_bytes`` bytes of the file in place
    (bit-rot / torn-write stand-in); returns the flipped offsets.  The
    shard-manifest crc32 verification must turn this into a
    ``ShardCorruptionError`` instead of silently mis-training."""
    with open(path, "rb") as f:
        data = bytearray(f.read())
    if not data:
        raise ValueError(f"cannot corrupt empty file {path!r}")
    rng = np.random.default_rng(seed)
    offsets = sorted(int(o) for o in
                     rng.choice(len(data), size=min(n_bytes, len(data)),
                                replace=False))
    for o in offsets:
        data[o] ^= 0xFF
    with open(path, "wb") as f:
        f.write(bytes(data))
    return offsets

"""Typed error taxonomy for the resilience layer.

Every failure the training/serving stack can recover from (or must fail
loudly on) gets a distinct type, so recovery policy is written against
*types*, never string matching — with one deliberate exception:
:func:`is_oom` classifies the backend's ``RESOURCE_EXHAUSTED`` errors by
message because XLA raises them as an opaque ``XlaRuntimeError``.

The split that matters:

  * **transient** (:func:`is_transient`) — worth retrying: flaky reads,
    chunk timeouts, preemptions.  The retry/recovery machinery
    (``RetryingSource``, ``train_streaming(recovery=...)``) only ever
    retries these.
  * **corruption** — :class:`ShardCorruptionError` is NOT transient: a
    checksum mismatch reproduces on every read, so retrying converts a
    loud failure into an infinite loop (and masking it converts it into
    silent garbage).
  * **overload** — :class:`QueueFullError` / :class:`DeadlineExceededError`
    / :class:`DispatcherCrashError` fail serving futures with a reason a
    client can act on (back off, re-submit, route elsewhere); the daemon
    never drops a request without resolving its future.
"""
from __future__ import annotations


class ResilienceError(Exception):
    """Base of the resilience taxonomy."""


# -- data-path errors --------------------------------------------------------
class TransientIOError(ResilienceError, OSError):
    """A retryable IO failure (flaky read, dropped connection, ...)."""


class ChunkTimeoutError(TransientIOError):
    """A chunk fetch exceeded the per-chunk timeout (treated transient:
    the pass is re-opened and fast-forwarded, then the chunk re-read)."""


class Preemption(TransientIOError):
    """A mid-run preemption (spot-instance style).  Transient: training
    recovers by checkpoint restore + deterministic replay."""


class ShardCorruptionError(ResilienceError):
    """A shard's bytes do not match its manifest checksum.  NOT
    transient — re-reading corrupt bytes yields corrupt bytes."""


class DeviceOOMError(ResilienceError):
    """Injected stand-in for the backend's RESOURCE_EXHAUSTED error
    (real OOMs surface as ``XlaRuntimeError``; both classify via
    :func:`is_oom`)."""


class NumericalDivergenceError(ResilienceError):
    """A non-finite value entered the training state (loss, margins or a
    histogram).  NOT transient and NOT an OOM: the recovery is its own
    domain — roll back to the last finite round and retry, backing off
    the learning rate when the same round diverges again (bounded by
    ``RecoveryPolicy.max_divergence_rollbacks``).  ``round_index`` is the
    boosting round whose sentinel tripped."""

    def __init__(self, message: str, *, round_index: int = -1,
                 what: str = "loss"):
        super().__init__(message)
        self.round_index = int(round_index)
        self.what = what


class TrainingInterrupted(ResilienceError):
    """A graceful-shutdown signal (SIGTERM/SIGINT) stopped the fit
    BETWEEN rounds: the in-flight round finished, state was committed
    (and checkpointed when a checkpoint dir was configured), and this
    typed status carries everything a supervisor needs to resume —
    ``rounds_done``, the ``checkpoint_dir`` holding the resumable state,
    the ``signal_name`` that triggered the exit, and the partial
    ``result`` (a ``TrainResult`` over the committed rounds)."""

    def __init__(self, message: str, *, rounds_done: int = 0,
                 checkpoint_dir=None, signal_name=None, result=None):
        super().__init__(message)
        self.rounds_done = int(rounds_done)
        self.checkpoint_dir = checkpoint_dir
        self.signal_name = signal_name
        self.result = result


# -- serving errors ----------------------------------------------------------
class QueueFullError(ResilienceError):
    """Load shed: the model's bounded queue cannot take this request.
    The request's future fails with this — it was never enqueued."""


class DeadlineExceededError(ResilienceError):
    """The request's hard deadline expired while it sat queued; it is
    failed typed instead of being served late or dropped silently."""


class DispatcherCrashError(ResilienceError):
    """The dispatcher thread died with this request in flight; the
    supervisor failed it cleanly while restarting the dispatcher."""


# -- classification ----------------------------------------------------------
_OOM_MARKERS = ("resource_exhausted", "resource exhausted", "out of memory")


def is_oom(exc: BaseException) -> bool:
    """Does ``exc`` look like a device-memory exhaustion?  Matches the
    typed :class:`DeviceOOMError` and (by message) the backend's
    ``RESOURCE_EXHAUSTED`` ``XlaRuntimeError``."""
    if isinstance(exc, DeviceOOMError):
        return True
    msg = str(exc).lower()
    return any(m in msg for m in _OOM_MARKERS)


def is_transient(exc: BaseException) -> bool:
    """Is ``exc`` worth retrying?  Corruption, OOM, divergence and a
    graceful interrupt are NOT transient (OOM and divergence have their
    own recovery branches; an interrupt must propagate)."""
    if isinstance(exc, (ShardCorruptionError, DeviceOOMError,
                        NumericalDivergenceError, TrainingInterrupted)):
        return False
    if is_oom(exc):
        return False
    return isinstance(exc, (TransientIOError, OSError, TimeoutError,
                            ConnectionError))

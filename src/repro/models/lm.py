"""Unified LM substrate covering all ten assigned architectures.

One parameter schema + one forward pass handle dense / MoE / SSM / hybrid /
enc-dec / VLM families, driven entirely by ``ArchConfig``:

  * layers are grouped by the config's repeating pattern period and run
    under ``jax.lax.scan`` (one compiled block body regardless of depth —
    essential for 512-device dry-run compile times) with optional remat;
  * three execution modes share the block code: train (no cache), prefill
    (fills KV/SSM caches), decode (one token against ring caches);
  * parameters exist in three forms: real arrays (``init_params``, smoke
    scale), ShapeDtypeStructs (``abstract_params``, full scale — the
    dry-run never allocates), and PartitionSpecs (``partition_specs``).

Sharding rules (MaxText-flavored):
  data axes = all mesh axes but "model" (i.e. ("pod","data") multi-pod).
  embed (V, d)            -> ("model", fsdp)
  in-proj  (d, X)         -> (fsdp, "model")
  out-proj (X, d)         -> ("model", fsdp)
  experts  (E, d, f)      -> EP ("model", fsdp, None) when E divides the
                             model axis, else TP (None, fsdp, "model")
  fsdp = data axes when cfg.fsdp (ZeRO-3: params+moments spread over data)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey

from repro.configs.registry import ArchConfig
from repro.models import layers as L
from repro.models import optim
from repro.models.mamba import mamba2_mixer
from repro.models.moe import moe_ffn


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# activation sharding pins live in repro.models.layers (shared with the
# attention kernels); re-exported here for the launcher.
activation_pins = L.activation_pins
_pin = L.pin_hidden


def mrope_sections(cfg: ArchConfig) -> Tuple[int, int, int]:
    d2 = cfg.head_dim // 2
    hw = int(round(d2 * 3 / 8))
    return (d2 - 2 * hw, hw, hw)       # (16, 24, 24) at head_dim=128


# ==========================================================================
# parameters
# ==========================================================================
def _init(key, shape, dtype, scale=0.02):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def _attn_params(cfg: ArchConfig, key, dt, *, cross: bool = False) -> Dict:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {"wq": _init(ks[0], (d, h * hd), dt),
         "wk": _init(ks[1], (d, kv * hd), dt),
         "wv": _init(ks[2], (d, kv * hd), dt),
         "wo": _init(ks[3], (h * hd, d), dt)}
    if cfg.attn_bias:
        p["bq"] = jnp.zeros((h * hd,), dt)
        p["bk"] = jnp.zeros((kv * hd,), dt)
        p["bv"] = jnp.zeros((kv * hd,), dt)
    if cfg.qk_norm and not cross:
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    return p


def _mlp_params(cfg: ArchConfig, key, dt) -> Dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"w_in": _init(ks[0], (d, f), dt),
         "w_out": _init(ks[1], (f, d), dt)}
    if cfg.act == "silu":
        p["w_gate"] = _init(ks[2], (d, f), dt)
    return p


def _moe_params(cfg: ArchConfig, key, dt) -> Dict:
    d = cfg.d_model
    f = cfg.moe_d_ff or cfg.d_ff
    e = cfg.n_experts
    ks = jax.random.split(key, 7)
    p = {"router": _init(ks[0], (d, e), jnp.float32),
         "w_in": _init(ks[1], (e, d, f), dt),
         "w_gate": _init(ks[2], (e, d, f), dt),
         "w_out": _init(ks[3], (e, f, d), dt)}
    if cfg.shared_expert:
        p["shared_w_in"] = _init(ks[4], (d, cfg.d_ff), dt)
        p["shared_w_gate"] = _init(ks[5], (d, cfg.d_ff), dt)
        p["shared_w_out"] = _init(ks[6], (cfg.d_ff, d), dt)
    return p


def _mamba_params(cfg: ArchConfig, key, dt) -> Dict:
    d, di = cfg.d_model, cfg.d_inner
    h, n, k = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * n + h), dt),
        "conv_w": _init(ks[1], (k, di + 2 * n), dt, scale=0.1),
        "A_log": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.full((h,), -2.0, jnp.float32),
        "gate_norm": jnp.ones((di,), dt),
        "out_proj": _init(ks[2], (di, d), dt),
    }


def _block_params(cfg: ArchConfig, kind, key, dt, *, decoder_cross: bool
                  ) -> Dict:
    mixer, ffn = kind
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,), dt)}
    p["mixer"] = (_attn_params(cfg, ks[0], dt) if mixer == "attn"
                  else _mamba_params(cfg, ks[0], dt))
    if decoder_cross and mixer == "attn":
        p["lnx"] = jnp.ones((cfg.d_model,), dt)
        p["xattn"] = _attn_params(cfg, ks[1], dt, cross=True)
    if ffn != "none":
        p["ln2"] = jnp.ones((cfg.d_model,), dt)
        p["ffn"] = (_mlp_params(cfg, ks[2], dt) if ffn == "mlp"
                    else _moe_params(cfg, ks[2], dt))
    return p


def init_params(cfg: ArchConfig, key) -> Dict:
    dt = _dtype(cfg.param_dtype)
    kinds = cfg.layer_kinds()
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    k_embed, k_dec, k_enc = jax.random.split(key, 3)

    def stack_blocks(base_key, n_groups, kind, cross):
        ks = jax.random.split(base_key, n_groups)
        per = [_block_params(cfg, kind, ks[g], dt, decoder_cross=cross)
               for g in range(n_groups)]
        return jax.tree.map(lambda *xs: jnp.stack(xs), *per)

    dec_keys = jax.random.split(k_dec, period)
    params: Dict[str, Any] = {
        "embed": _init(k_embed, (cfg.vocab_padded, cfg.d_model), dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
        "blocks": [stack_blocks(dec_keys[j], groups, kinds[j],
                                cfg.family == "encdec")
                   for j in range(period)],
    }
    if cfg.family == "encdec":
        params["enc_blocks"] = [stack_blocks(k_enc, cfg.encoder_layers,
                                             ("attn", "mlp"), False)]
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    return params


def abstract_params(cfg: ArchConfig):
    """Full-scale parameter ShapeDtypeStructs — no allocation (dry-run)."""
    return jax.eval_shape(functools.partial(init_params, cfg),
                          jax.ShapeDtypeStruct((2,), jnp.uint32))


def param_count(cfg: ArchConfig) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree.leaves(abstract_params(cfg)))


def active_param_count(cfg: ArchConfig) -> int:
    """MoE-aware active parameters (top_k / n_experts of expert weights)."""
    total = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(
            abstract_params(cfg)):
        n = int(np.prod(leaf.shape))
        names = [p.key for p in path if isinstance(p, DictKey)]
        if cfg.n_experts and leaf.ndim >= 3 and names[-1].startswith("w_"):
            n = n * cfg.top_k // cfg.n_experts
        total += n
    return total


# ==========================================================================
# partition specs
# ==========================================================================
_IN_W = ("wq", "wk", "wv", "w_in", "w_gate", "in_proj",
         "shared_w_in", "shared_w_gate")
_OUT_W = ("wo", "w_out", "out_proj", "shared_w_out")


def partition_specs(cfg: ArchConfig, mesh: Mesh):
    """PartitionSpec pytree matching ``init_params`` / ``abstract_params``."""
    da = tuple(a for a in mesh.axis_names if a != "model")
    da = da if len(da) > 1 else da[0]
    m = mesh.shape["model"]
    fsdp = da if cfg.fsdp else None
    ep = cfg.n_experts >= m and cfg.n_experts % m == 0

    def rule(path, leaf):
        names = [p.key for p in path if isinstance(p, DictKey)]
        stacked = "blocks" in names or "enc_blocks" in names
        name = names[-1]
        rank = leaf.ndim - (1 if stacked else 0)

        def S(*spec):
            return P(*(((None,) + spec) if stacked else spec))

        if name == "embed":
            return P("model", fsdp)
        if name in _IN_W:
            if rank == 3:                      # (E, d, ff) expert weights
                if ep:
                    return S("model", fsdp, None)
                if cfg.moe_ff_fsdp:            # keep contracted d unsharded
                    return S(None, None,
                             (fsdp + ("model",)) if isinstance(fsdp, tuple)
                             else ((fsdp, "model") if fsdp else "model"))
                return S(None, fsdp, "model")
            return S(fsdp, "model")
        if name in _OUT_W:
            if rank == 3:                      # (E, ff, d)
                if ep:
                    return S("model", fsdp, None)
                if cfg.moe_ff_fsdp:
                    return S(None,
                             (fsdp + ("model",)) if isinstance(fsdp, tuple)
                             else ((fsdp, "model") if fsdp else "model"),
                             None)
                return S(None, "model", fsdp)
            return S("model", fsdp)
        if name == "conv_w":
            return S(None, "model")
        if name in ("A_log", "D", "dt_bias"):
            return S("model") if cfg.ssm_heads % m == 0 else S(None)
        if name == "gate_norm":
            return S("model") if cfg.d_inner % m == 0 else S(None)
        return S(*([None] * rank))             # norms, biases, router

    return jax.tree_util.tree_map_with_path(rule, abstract_params(cfg))


def param_shardings(cfg: ArchConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        partition_specs(cfg, mesh),
                        is_leaf=lambda x: isinstance(x, P))


# ==========================================================================
# forward
# ==========================================================================
def _rope(cfg: ArchConfig, positions, mrope_pos=None):
    if not cfg.rope:
        return None
    if cfg.mrope:
        return L.mrope_cos_sin(mrope_pos, mrope_sections(cfg), cfg.head_dim,
                               cfg.rope_theta)
    return L.rope_cos_sin(positions, cfg.head_dim, cfg.rope_theta)


_KEEP_F32 = ("A_log", "D", "dt_bias", "router")


def _cast_block(bp, cdt):
    """Cast block weights to the compute dtype at use (MaxText-style);
    SSM decay scalars and router weights stay f32 for stability."""
    def cast(path, w):
        name = path[-1].key if isinstance(path[-1], DictKey) else None
        if name in _KEEP_F32 or not jnp.issubdtype(w.dtype, jnp.floating):
            return w
        return w.astype(cdt)
    return jax.tree_util.tree_map_with_path(cast, bp)


def _apply_block(cfg: ArchConfig, kind, bp, x, cos_sin, mode, cache=None,
                 pos=None, enc=None, causal: bool = True):
    mixer, ffn = kind
    bp = _cast_block(bp, _dtype(cfg.compute_dtype))
    new_cache: Dict[str, Any] = {}
    h = L.rms_norm(x, bp["ln1"], cfg.norm_eps)
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               head_dim=cfg.head_dim, qk_norm=cfg.qk_norm,
               norm_eps=cfg.norm_eps)
    if mixer == "attn":
        if mode == "train":
            out = L.attn_train(bp["mixer"], h, causal=causal,
                               cos_sin=cos_sin,
                               sliding_window=cfg.sliding_window,
                               attn_chunk=cfg.attn_chunk,
                               chunk_unroll=cfg.scan_unroll, **akw)
        elif mode == "prefill":
            out, nc = L.attn_prefill(bp["mixer"], h, cache["self"],
                                     cos_sin=cos_sin,
                                     sliding_window=cfg.sliding_window,
                                     attn_chunk=cfg.attn_chunk,
                                     chunk_unroll=cfg.scan_unroll, **akw)
            new_cache["self"] = nc
        else:
            out, nc = L.attn_decode(bp["mixer"], h, cache["self"], pos,
                                    cos_sin=cos_sin, **akw)
            new_cache["self"] = nc
        x = _pin(x + out.astype(x.dtype))
        if "xattn" in bp:
            hx = L.rms_norm(x, bp["lnx"], cfg.norm_eps)
            if mode == "decode":
                out = L.xattn_decode(bp["xattn"], hx, cache["cross"],
                                     n_heads=cfg.n_heads,
                                     n_kv_heads=cfg.n_kv_heads,
                                     head_dim=cfg.head_dim)
                new_cache["cross"] = cache["cross"]
            else:
                out = L.attn_train(bp["xattn"], hx, causal=False,
                                   cos_sin=None, x_kv=enc, **akw)
                if mode == "prefill":
                    new_cache["cross"] = L.xattn_make_cache(
                        bp["xattn"], enc, n_kv_heads=cfg.n_kv_heads,
                        head_dim=cfg.head_dim, dtype=cache["cross"]["k"].dtype)
            x = _pin(x + out.astype(x.dtype))
    else:  # mamba
        mkw = dict(n_heads=cfg.ssm_heads, head_dim=cfg.ssm_head_dim,
                   ssm_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
                   norm_eps=cfg.norm_eps, unroll=cfg.scan_unroll)
        if mode == "train":
            out, _ = mamba2_mixer(bp["mixer"], h, **mkw)
        elif mode == "prefill":
            out, nc = mamba2_mixer(bp["mixer"], h, return_cache=True, **mkw)
            new_cache = nc
        else:
            out, nc = mamba2_mixer(bp["mixer"], h, cache=cache, **mkw)
            new_cache = nc
        x = _pin(x + out.astype(x.dtype))

    aux = jnp.zeros((), jnp.float32)
    if ffn != "none":
        h2 = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
        if ffn == "mlp":
            out = L.mlp(bp["ffn"], h2, act=cfg.act)
        elif cfg.moe_aux_weight and mode == "train":
            out, aux = moe_ffn(bp["ffn"], h2, n_experts=cfg.n_experts,
                               top_k=cfg.top_k, act=cfg.act,
                               capacity_factor=cfg.moe_capacity_factor,
                               return_aux=True)
        else:
            out = moe_ffn(bp["ffn"], h2, n_experts=cfg.n_experts,
                          top_k=cfg.top_k, act=cfg.act,
                          capacity_factor=cfg.moe_capacity_factor)
        x = _pin(x + out.astype(x.dtype))
    return x, new_cache, aux


def _remat(cfg: ArchConfig, fn):
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    return jax.checkpoint(fn)


def _tree_slice(tree, g):
    return jax.tree.map(lambda a: a[g], tree)


def _run_stack_unrolled(cfg: ArchConfig, blocks: List, x, *, kinds, mode,
                        cos_sin=None, caches=None, pos=None, enc=None,
                        remat: bool = False, causal: bool = True):
    """Python-unrolled twin of ``_run_stack`` (cfg.scan_unroll=True).

    Used by the dry-run: XLA's cost_analysis counts a while-loop body once
    regardless of trip count, so honest roofline FLOPs/bytes/collective
    numbers need the layer loop unrolled in the HLO.  Semantically
    identical to the scan path (tested)."""
    p = len(blocks)
    G = jax.tree.leaves(blocks[0])[0].shape[0]
    new_caches = [[] for _ in range(p)] if caches is not None else None

    def group_body(x, bps, cs):
        ncs = []
        aux = jnp.zeros((), jnp.float32)
        for j in range(p):
            x, nc, a = _apply_block(cfg, kinds[j], bps[j], x, cos_sin, mode,
                                    cache=None if cs is None else cs[j],
                                    pos=pos, enc=enc, causal=causal)
            ncs.append(nc)
            aux = aux + a
        return x, ncs, aux

    aux_total = jnp.zeros((), jnp.float32)
    for g in range(G):
        bps = [_tree_slice(blocks[j], g) for j in range(p)]
        cs = (None if caches is None
              else [_tree_slice(caches[j], g) for j in range(p)])
        if remat and caches is None:
            x, ncs, aux = _remat(cfg,
                                 lambda x_, bps_: group_body(x_, bps_, None)
                                 )(x, bps)
        else:
            x, ncs, aux = group_body(x, bps, cs)
        aux_total = aux_total + aux
        if new_caches is not None:
            for j in range(p):
                new_caches[j].append(ncs[j])
    if new_caches is not None:
        new_caches = [jax.tree.map(lambda *xs: jnp.stack(xs), *nc)
                      for nc in new_caches]
    return x, new_caches, aux_total


def _run_stack(cfg: ArchConfig, blocks: List, x, *, kinds, mode,
               cos_sin=None, caches=None, pos=None, enc=None,
               remat: bool = False, causal: bool = True):
    """Scan over layer groups; ``blocks``/``caches`` are lists over the
    pattern period, each leaf stacked (G, ...)."""
    if cfg.scan_unroll:
        return _run_stack_unrolled(cfg, blocks, x, kinds=kinds, mode=mode,
                                   cos_sin=cos_sin, caches=caches, pos=pos,
                                   enc=enc, remat=remat, causal=causal)
    p = len(blocks)

    if caches is None:
        def body(carry, bps):
            x_, aux_ = carry
            for j in range(p):
                x_, _, a = _apply_block(cfg, kinds[j], bps[j], x_,
                                        cos_sin, mode, enc=enc,
                                        causal=causal)
                aux_ = aux_ + a
            return (x_, aux_), None
        body_fn = _remat(cfg, body) if remat else body
        (x, aux), _ = jax.lax.scan(body_fn,
                                   (x, jnp.zeros((), jnp.float32)),
                                   tuple(blocks))
        return x, None, aux

    def body(carry, xs):
        bps, cs = xs
        ncs = []
        for j in range(p):
            carry, nc, _ = _apply_block(cfg, kinds[j], bps[j], carry,
                                        cos_sin, mode, cache=cs[j],
                                        pos=pos, enc=enc)
            ncs.append(nc)
        return carry, tuple(ncs)

    x, new_caches = jax.lax.scan(body, x, (tuple(blocks), tuple(caches)))
    return x, list(new_caches), jnp.zeros((), jnp.float32)


def _encode(cfg: ArchConfig, params, audio_embeds, remat: bool):
    cdt = _dtype(cfg.compute_dtype)
    enc = audio_embeds.astype(cdt)
    enc = enc + L.sinusoidal_positions(enc.shape[1], cfg.d_model
                                       ).astype(cdt)[None]
    enc, _, _ = _run_stack(cfg, params["enc_blocks"], enc,
                           kinds=[("attn", "mlp")], mode="train",
                           cos_sin=None, remat=remat, causal=False)
    return L.rms_norm(enc, params["enc_norm"], cfg.norm_eps)


def _embed_tokens(cfg, params, tokens, batch):
    cdt = _dtype(cfg.compute_dtype)
    # cast BEFORE the gather: the vocab-sharded take needs a cross-shard
    # all-reduce, which otherwise rides at f32 (2x traffic) — §Perf iter.
    x = jnp.take(params["embed"].astype(cdt), tokens, axis=0)
    if cfg.family == "vlm" and "patch_embeds" in batch:
        x = jax.lax.dynamic_update_slice(
            x, batch["patch_embeds"].astype(cdt), (0, 0, 0))
    return _pin(x)


def forward_hidden(cfg: ArchConfig, params, batch):
    """Forward pass up to the final norm.

    Returns ((B, S, d) hidden states, moe aux loss scalar)."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(cfg, params, tokens, batch)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos_sin = _rope(cfg, positions, batch.get("positions"))
    enc = (_encode(cfg, params, batch["audio_embeds"], cfg.remat)
           if cfg.family == "encdec" else None)
    x, _, aux = _run_stack(cfg, params["blocks"], x,
                           kinds=cfg.layer_kinds(), mode="train",
                           cos_sin=cos_sin, enc=enc, remat=cfg.remat)
    return L.rms_norm(x, params["final_norm"], cfg.norm_eps), aux


def forward_train(cfg: ArchConfig, params, batch):
    """batch: tokens (B,S) [+ labels], optional positions (3,B,S) for
    M-RoPE, patch_embeds (B,P,d) for VLM, audio_embeds (B,F,d) for encdec.
    Returns logits (B, S, vocab_padded) in compute dtype."""
    x, _ = forward_hidden(cfg, params, batch)
    return x @ params["embed"].T.astype(x.dtype)


def loss_fn(cfg: ArchConfig, params, batch):
    x, aux = forward_hidden(cfg, params, batch)
    logits = (x @ params["embed"].T.astype(x.dtype)).astype(jnp.float32)
    if cfg.vocab_padded != cfg.vocab:  # mask the padded vocab rows
        pad_mask = jnp.arange(cfg.vocab_padded) >= cfg.vocab
        logits = jnp.where(pad_mask[None, None], -1e30, logits)
    labels = batch["labels"]
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    if cfg.moe_aux_weight:  # Python gate: DCE'd entirely when disabled
        loss = loss + cfg.moe_aux_weight * aux
    return loss


def loss_fn_blocked(cfg: ArchConfig, params, batch, n_blocks: int = 8):
    """Vocab-blocked cross entropy (§Perf beyond-paper optimization).

    Never materializes the (B, S, vocab) logits: scans vocab chunks with an
    online logsumexp (running max + rescaled sum) and picks the gold logit
    from whichever chunk holds the label.  Peak logits memory drops by
    ``n_blocks``x — targets the memory-term bottleneck of big-vocab train
    cells (command-r 256k, llama4 202k)."""
    h, aux = forward_hidden(cfg, params, batch)
    h = h.astype(jnp.float32)                                    # (B,S,d)
    labels = batch["labels"]
    vp = cfg.vocab_padded
    assert vp % n_blocks == 0
    vb = vp // n_blocks
    embed = params["embed"]

    def body(carry, i):
        m, s, gold = carry
        emb_c = jax.lax.dynamic_slice(embed, (i * vb, 0),
                                      (vb, embed.shape[1]))
        logits = h @ emb_c.T.astype(h.dtype)                    # (B,S,vb)
        vocab_ids = i * vb + jnp.arange(vb)
        logits = jnp.where((vocab_ids >= cfg.vocab)[None, None],
                           -1e30, logits)
        m_new = jnp.maximum(m, logits.max(-1))
        s = s * jnp.exp(m - m_new) + jnp.exp(
            logits - m_new[..., None]).sum(-1)
        in_chunk = (labels >= i * vb) & (labels < (i + 1) * vb)
        local = jnp.take_along_axis(
            logits, jnp.clip(labels - i * vb, 0, vb - 1)[..., None],
            axis=-1)[..., 0]
        gold = jnp.where(in_chunk, local, gold)
        return (m_new, s, gold), None

    init = (jnp.full(labels.shape, -jnp.inf, jnp.float32),
            jnp.zeros(labels.shape, jnp.float32),
            jnp.zeros(labels.shape, jnp.float32))
    (m, s, gold), _ = jax.lax.scan(
        body, init, jnp.arange(n_blocks, dtype=jnp.int32),
        unroll=n_blocks if cfg.scan_unroll else 1)
    loss = jnp.mean(m + jnp.log(s) - gold)
    if cfg.moe_aux_weight:
        loss = loss + cfg.moe_aux_weight * aux
    return loss


def make_train_step(cfg: ArchConfig, *, base_lr: float = 3e-4,
                    warmup: int = 100, total_steps: int = 10_000,
                    vocab_blocks: int = 0):
    """Returns step(params, opt_state, batch) -> (params, opt, metrics).

    ``vocab_blocks > 0`` switches to the blocked cross entropy."""
    sched = optim.get_schedule(cfg.lr_schedule)
    lfn = (loss_fn if not vocab_blocks
           else functools.partial(loss_fn_blocked, n_blocks=vocab_blocks))

    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(
            lambda p: lfn(cfg, p, batch))(params)
        lr = sched(opt_state.step + 1, base_lr=base_lr, warmup=warmup,
                   total=total_steps)
        params, opt_state, gnorm = optim.adamw_update(
            params, grads, opt_state, lr=lr)
        return params, opt_state, {"loss": loss, "lr": lr, "gnorm": gnorm}

    return step


# ==========================================================================
# serving (prefill + decode)
# ==========================================================================
def cache_len(cfg: ArchConfig, max_len: int) -> int:
    return min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, abstract: bool = False):
    """Cache pytree: list over the pattern period, leaves stacked (G,...)."""
    kinds = cfg.layer_kinds()
    period = cfg.scan_period()
    groups = cfg.n_layers // period
    w = cache_len(cfg, max_len)
    mk = (jax.ShapeDtypeStruct if abstract
          else (lambda sh, dt: jnp.zeros(sh, dt)))
    caches = []
    for j in range(period):
        mixer, _ = kinds[j]
        if mixer == "attn":
            c = {"self": {
                "k": mk((groups, batch, w, cfg.n_kv_heads, cfg.head_dim),
                        dtype),
                "v": mk((groups, batch, w, cfg.n_kv_heads, cfg.head_dim),
                        dtype)}}
            if cfg.family == "encdec":
                c["cross"] = {
                    "k": mk((groups, batch, cfg.frontend_len,
                             cfg.n_kv_heads, cfg.head_dim), dtype),
                    "v": mk((groups, batch, cfg.frontend_len,
                             cfg.n_kv_heads, cfg.head_dim), dtype)}
        else:
            c = {"conv": mk((groups, batch, cfg.ssm_conv - 1,
                             cfg.d_inner + 2 * cfg.ssm_state), dtype),
                 "ssm": mk((groups, batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32)}
        caches.append(c)
    return caches


def cache_specs(cfg: ArchConfig, mesh: Mesh, batch: int, max_len: int,
                dtype=jnp.bfloat16, kv_shard: str = "hd"):
    """(abstract cache, shardings).  SSM state shards heads; batch shards
    the data axes (replicated when it cannot divide them, e.g. long_500k's
    B=1).  K/V model-axis placement is selectable (§Perf):
      * ``hd``  — shard head_dim (always divisible; contraction psum)
      * ``seq`` — shard the cache sequence dim (balanced attention read;
                  the decode write touches one shard per step)
      * ``kv``  — shard the KV-head dim (pads 8 heads -> model width)
      * ``none``— replicate over the model axis
    """
    da_t = tuple(a for a in mesh.axis_names if a != "model")
    n_da = int(np.prod([mesh.shape[a] for a in da_t]))
    da = da_t if len(da_t) > 1 else da_t[0]
    if batch % n_da:
        da = None
    cache = init_cache(cfg, batch, max_len, dtype, abstract=True)
    kv_spec = {"hd": P(None, da, None, None, "model"),
               "seq": P(None, da, "model", None, None),
               "kv": P(None, da, None, "model", None),
               "none": P(None, da, None, None, None)}[kv_shard]

    def rule(path, leaf):
        names = [p.key for p in path if isinstance(p, DictKey)]
        if names[-1] in ("k", "v"):
            return kv_spec
        if names[-1] == "conv":
            return P(None, da, None, "model")
        return P(None, da, "model", None, None)   # ssm state

    specs = jax.tree_util.tree_map_with_path(rule, cache)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return cache, shardings


def prefill(cfg: ArchConfig, params, batch, *, cache_dtype=jnp.bfloat16,
            max_len: Optional[int] = None):
    """Full-prefix forward + cache fill.  Returns (last logits (B,V), cache).

    ``max_len`` sizes the cache (prefix + generation headroom); without a
    sliding window the ring must never wrap, so callers decoding beyond the
    prefix must pass prefix + max_new_tokens here."""
    tokens = batch["tokens"]
    b, s = tokens.shape
    x = _embed_tokens(cfg, params, tokens, batch)
    positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
    cos_sin = _rope(cfg, positions, batch.get("positions"))
    enc = (_encode(cfg, params, batch["audio_embeds"], False)
           if cfg.family == "encdec" else None)
    caches = init_cache(cfg, b, max_len or s, cache_dtype)
    x, caches, _ = _run_stack(cfg, params["blocks"], x,
                              kinds=cfg.layer_kinds(), mode="prefill",
                              cos_sin=cos_sin, caches=caches, enc=enc)
    x = L.rms_norm(x[:, -1:], params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    return logits.astype(jnp.float32), caches


def decode_step(cfg: ArchConfig, params, caches, token, pos,
                mrope_pos=None):
    """One decode step.  token (B,1) int32; pos scalar int32 (absolute).
    Returns (logits (B, vocab) f32, new caches)."""
    b = token.shape[0]
    x = jnp.take(params["embed"].astype(_dtype(cfg.compute_dtype)),
                 token, axis=0)
    positions = jnp.broadcast_to(pos[None, None], (b, 1))
    if cfg.mrope and mrope_pos is None:
        mrope_pos = jnp.broadcast_to(pos[None, None, None], (3, b, 1))
    cos_sin = _rope(cfg, positions, mrope_pos)
    x, caches, _ = _run_stack(cfg, params["blocks"], x,
                              kinds=cfg.layer_kinds(), mode="decode",
                              cos_sin=cos_sin, caches=caches, pos=pos)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
    return logits.astype(jnp.float32), caches

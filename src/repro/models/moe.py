"""Mixture-of-Experts layer (Mixtral / Llama-4 / Jamba style).

Token→expert dispatch is the transformer-side transfer of the paper's core
primitive: routing tokens to per-expert buffers is the same
irregular-scatter-to-small-structures problem as histogram binning
(group-by-expert ≙ group-by-field; see DESIGN.md §5).  At LM token counts a
materialized one-hot would not fit, so the production layer uses the
capacity-buffer scatter/gather formulation (GShard-style); the one-hot
contraction form lives in ``repro.kernels.ops.onehot_matmul`` and is what
the Pallas histogram kernel applies at VMEM-block granularity.

Expert placement rule (see configs): expert-parallel over the "model" mesh
axis when n_experts divides it, otherwise tensor-parallel inside each
expert (small expert counts, e.g. Mixtral's 8 on a 16-wide axis).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.models.layers import mlp


def moe_ffn(params, x, *, n_experts: int, top_k: int,
            capacity_factor: float = 1.25, act: str = "silu",
            router_dtype=jnp.float32, return_aux: bool = False):
    """params: router (d, E), w_in/w_gate (E, d, ff), w_out (E, ff, d),
    optional shared_* (plain MLP applied to every token).

    x: (B, S, d) -> (B, S, d).
    """
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)

    logits = (xf.astype(router_dtype)
              @ params["router"].astype(router_dtype))          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, top_k)                  # (T, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    capacity = max(int(t * top_k * capacity_factor / n_experts), 4)

    e_flat = top_e.reshape(-1)                                  # (T*k,)
    w_flat = top_p.reshape(-1)
    # position-in-expert via a cumulative count over dispatch order
    oh = jax.nn.one_hot(e_flat, n_experts, dtype=jnp.int32)     # (T*k, E)
    pos_flat = jnp.take_along_axis(jnp.cumsum(oh, axis=0) - oh,
                                   e_flat[:, None], axis=1)[:, 0]
    keep = pos_flat < capacity
    pos_c = jnp.minimum(pos_flat, capacity - 1)

    tok_idx = jnp.repeat(jnp.arange(t), top_k)
    # dispatch: scatter tokens into (E, C, d) expert buffers
    buf = jnp.zeros((n_experts, capacity, d), x.dtype)
    buf = buf.at[e_flat, pos_c].add(
        xf[tok_idx] * keep[:, None].astype(x.dtype))

    hidden = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, params["w_in"]) \
        if "w_gate" in params else \
        jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, params["w_in"]))
    out_buf = jnp.einsum("ecf,efd->ecd", hidden, params["w_out"])

    # combine: gather each token's expert outputs, weight, and sum over k
    y_flat = out_buf[e_flat, pos_c] * (w_flat * keep)[:, None].astype(x.dtype)
    y = jax.ops.segment_sum(y_flat, tok_idx, num_segments=t)

    if "shared_w_in" in params:
        shared = {k[len("shared_"):]: v for k, v in params.items()
                  if k.startswith("shared_")}
        y = y + mlp(shared, xf, act=act)
    y = y.reshape(b, s, d)
    if return_aux:
        return y, moe_aux_loss(logits, top_e, n_experts)
    return y


def moe_aux_loss(logits, top_e, n_experts: int):
    """Load-balancing auxiliary loss (Switch-style): E * sum(f_i * p_i),
    where f_i is the fraction of tokens whose top-1 pick is expert i and
    p_i the mean router probability of expert i.  Minimized (=1) at a
    perfectly uniform load."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    me = probs.mean(0)
    oh = jax.nn.one_hot(top_e[:, 0], n_experts, dtype=jnp.float32)
    ce = oh.mean(0)
    return n_experts * jnp.sum(me * ce)

"""Neural model zoo (LM / Mamba / MoE) sharing the accelerator substrate."""

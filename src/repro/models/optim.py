"""AdamW + LR schedules (cosine and MiniCPM's WSD) on raw pytrees.

m/v moments are f32 regardless of the parameter dtype; under the FSDP
partition rules the moments inherit the parameter sharding (ZeRO-style:
with ``fsdp=True`` params are already spread over the data axes, so
optimizer state is too).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def adamw_update(params, grads, state: AdamWState, *, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, grad_clip: float = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        u = (m / c1) / (jnp.sqrt(v / c2) + eps)
        u = u + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype), m, v

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_flatten(grads)[0]
    flat_m = jax.tree_util.tree_flatten(state.m)[0]
    flat_v = jax.tree_util.tree_flatten(state.v)[0]
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), gnorm


def cosine_schedule(step, *, base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1):
    step = step.astype(jnp.float32)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_ratio + (1 - min_ratio) * 0.5
                     * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)


def wsd_schedule(step, *, base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, min_ratio: float = 0.01):
    """MiniCPM's Warmup-Stable-Decay: linear warmup, long flat stage, short
    exponential-ish decay tail (arXiv:2404.06395 §4)."""
    step = step.astype(jnp.float32)
    decay_start = total * (1.0 - decay_frac)
    warm = base_lr * step / jnp.maximum(warmup, 1)
    t = jnp.clip((step - decay_start) / jnp.maximum(total - decay_start, 1),
                 0.0, 1.0)
    decay = base_lr * (min_ratio ** t)
    lr = jnp.where(step < warmup, warm,
                   jnp.where(step < decay_start, base_lr, decay))
    return lr


def get_schedule(name: str):
    return {"cosine": cosine_schedule, "wsd": wsd_schedule}[name]

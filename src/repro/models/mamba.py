"""Mamba-2 (SSD — state-space duality) mixer, chunked-scan training form
plus the O(1)-per-token recurrent decode form.

Follows the minimal SSD algorithm of Dao & Gu (arXiv:2405.21060): split the
sequence into chunks; compute intra-chunk outputs with a masked
attention-like quadratic form, carry inter-chunk state with a scan.  Both
forms share parameters, so prefill can hand its final state to decode.

Shapes (single group, g=1, as in mamba2-370m):
  x (B, S, d_model); d_inner = expand*d_model; H heads of head_dim P;
  state size N; dt (B, S, H); A (H,) negative; B_, C_ (B, S, N).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import rms_norm


def _segsum(x):
    """Stable 'segment sum' producing the lower-triangular decay matrix.

    x (..., L) -> (..., L, L) with out[i, j] = sum_{k in (j, i]} x[k] for
    j < i, 0 on diagonal, -inf above."""
    L = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((L, L), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(xh, dt, A, B_, C_, *, chunk: int,
                unroll: bool = False):
    """Chunked SSD scan.

    xh (B, S, H, P); dt (B, S, H) (already softplus'd); A (H,) < 0;
    B_, C_ (B, S, N).  Returns (y (B, S, H, P), final_state (B, H, P, N)).
    """
    b, s, h, p = xh.shape
    n = B_.shape[-1]
    pad = -s % chunk
    if pad:  # dt=0 padding is state-neutral (decay exp(0)=1, zero update)
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B_ = jnp.pad(B_, ((0, 0), (0, pad), (0, 0)))
        C_ = jnp.pad(C_, ((0, 0), (0, pad), (0, 0)))
    s_p = s + pad
    c = s_p // chunk

    # chunked views
    xc = xh.reshape(b, c, chunk, h, p)
    dtc = dt.reshape(b, c, chunk, h)
    Bc = B_.reshape(b, c, chunk, n)
    Cc = C_.reshape(b, c, chunk, n)

    dA = dtc * A[None, None, None, :]                      # (b,c,l,h) ≤ 0
    dA_cum = jnp.cumsum(dA, axis=2)                        # (b,c,l,h)

    # 1. intra-chunk (the "duality": masked attention within a chunk)
    L = jnp.exp(_segsum(jnp.moveaxis(dA, 2, 3)))           # (b,c,h,l,l)
    att = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)            # (b,c,l,l)
    scores = att[:, :, None, :, :] * L                     # (b,c,h,l,m)
    xw = xc * dtc[..., None]                               # dt-weighted input
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", scores, xw)

    # 2. per-chunk final states
    decay_states = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)  # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bc,
                        decay_states * dtc, xc)            # (b,c,h,p,n)

    # 3. inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])             # (b,c,h)

    def scan_fn(carry, inp):
        st, dec = inp                                      # (b,h,p,n),(b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                  # emit PREVIOUS

    init = jnp.zeros((b, h, p, n), jnp.float32)  # state carried in f32
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)),
        unroll=c if unroll else 1)
    prev_states = jnp.moveaxis(prev_states, 0, 1)          # (b,c,h,p,n)

    # 4. state -> output contribution
    state_decay = jnp.exp(dA_cum)                          # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", Cc, prev_states,
                       state_decay)
    y = (y_diag + y_off).reshape(b, s_p, h, p)[:, :s]
    return y, final


def _causal_conv(x, w, cache: Optional[jax.Array] = None,
                 cache_pos=None) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Depthwise causal conv1d, kernel K.  x (B, S, C); w (K, C).

    With ``cache`` (B, K-1, C): decode mode (S == 1), returns new cache.
    """
    k = w.shape[0]
    if cache is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
        out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None]
                  for i in range(k))
        return out, None
    ctx = jnp.concatenate([cache, x], axis=1)              # (B, K, C)
    out = jnp.einsum("bkc,kc->bc", ctx, w)[:, None, :]
    return out, ctx[:, 1:, :]


def mamba2_mixer(params, x, *, n_heads: int, head_dim: int, ssm_state: int,
                 chunk: int = 256, norm_eps: float = 1e-6,
                 cache: Optional[dict] = None, cache_pos=None,
                 return_cache: bool = False, unroll: bool = False):
    """Mamba-2 block mixer.  params:
      in_proj (d, 2*di + 2*N + H), conv_w (K, di + 2*N), A_log (H,),
      D (H,), dt_bias (H,), gate_norm (di,), out_proj (di, d).

    cache (decode): {"conv": (B, K-1, di+2N), "ssm": (B, H, P, N)}.
    Returns (y (B,S,d), new_cache | None).
    """
    b, s, d = x.shape
    di = n_heads * head_dim
    n = ssm_state

    zxbcdt = x @ params["in_proj"]                         # (B,S,2di+2N+H)
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    dt = jax.nn.softplus(dt + params["dt_bias"])           # (B,S,H)

    conv_cache = cache["conv"] if cache is not None else None
    xbc_raw = xbc  # pre-conv stream (its tail seeds the decode conv cache)
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_cache)
    xbc = jax.nn.silu(xbc)
    xs, B_, C_ = jnp.split(xbc, [di, di + n], axis=-1)
    xh = xs.reshape(b, s, n_heads, head_dim)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))      # (H,) < 0

    if cache is None:
        y, final = ssd_chunked(xh, dt, A, B_, C_, chunk=chunk,
                               unroll=unroll)
        new_cache = None
        if return_cache:  # prefill: hand the final state to decode
            k = params["conv_w"].shape[0]
            new_cache = {"conv": xbc_raw[:, -(k - 1):, :], "ssm": final}
    else:
        # recurrent decode: h' = exp(dt*A) h + dt * B ⊗ x ; y = C·h
        h_prev = cache["ssm"]                              # (B,H,P,N)
        dA = jnp.exp(dt[:, 0, :] * A[None, :])             # (B,H)
        upd = jnp.einsum("bh,bhp,bn->bhpn", dt[:, 0], xh[:, 0], B_[:, 0])
        h_new = h_prev * dA[..., None, None] + upd
        y = jnp.einsum("bn,bhpn->bhp", C_[:, 0], h_new)[:, None]
        y = y.reshape(b, 1, n_heads, head_dim)
        final = h_new
        new_cache = {"conv": new_conv, "ssm": h_new}

    y = y + xh * params["D"][None, None, :, None]          # skip connection
    y = y.reshape(b, s, di)
    y = rms_norm(y * jax.nn.silu(z), params["gate_norm"], norm_eps)
    return y @ params["out_proj"], new_cache

"""Shared transformer layers: norm, rotary embeddings, GQA attention, MLP.

Pure functions over parameter dicts (no framework): the same code path is
traced for real arrays (smoke tests), ShapeDtypeStructs (the 512-device
dry-run) and under pjit (production mesh).  Compute dtype is bf16 with f32
softmax/norm accumulation, MaxText-style.

Attention comes in three explicit modes:
  * ``attn_train``   — full-sequence, no cache (also the encoder path)
  * ``attn_prefill`` — full-sequence + writes the KV cache (ring-rolled
                       when a sliding window bounds the cache)
  * ``attn_decode``  — one token against a (possibly ring-buffer) cache;
                       keys carry RoPE applied at *write* time, so a ring
                       slot permutation never corrupts relative positions.
"""
from __future__ import annotations

import contextlib
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# activation sharding pins (set by the launcher during tracing)
# --------------------------------------------------------------------------
# "hidden": (B, S, d) block-boundary activations -> batch-sharded, so GSPMD
#   all-gathers weights instead of all-reducing activations (MaxText-style);
# "heads":  (B, S, H, D) q/k/v -> head-sharded on the model axis (padded
#   when H doesn't divide it), so per-head attention math stays shard-local
#   instead of psum-ing logits over a flat sharded head*dim contraction.
_ACT_PINS = {"hidden": None, "heads": None}


@contextlib.contextmanager
def activation_pins(hidden=None, heads=None):
    old = dict(_ACT_PINS)
    _ACT_PINS.update(hidden=hidden, heads=heads)
    try:
        yield
    finally:
        _ACT_PINS.update(old)


def pin_hidden(x):
    if _ACT_PINS["hidden"] is not None and x.ndim == 3:
        return jax.lax.with_sharding_constraint(x, _ACT_PINS["hidden"])
    return x


def _pin_heads(x):
    if _ACT_PINS["heads"] is not None and x.ndim == 4:
        return jax.lax.with_sharding_constraint(x, _ACT_PINS["heads"])
    return x


def rms_norm(x, weight, eps: float = 1e-6):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * weight.astype(jnp.float32)).astype(dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset=0):
    """Whisper-style fixed positional encoding (stands in for its learned
    embeddings; noted in DESIGN.md)."""
    pos = jnp.arange(seq_len, dtype=jnp.float32) + offset
    dim = jnp.arange(0, d_model, 2, dtype=jnp.float32)
    inv = jnp.exp(-dim * jnp.log(10000.0) / d_model)
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# --------------------------------------------------------------------------
# rotary position embeddings (standard RoPE + Qwen2-VL's 3-section M-RoPE)
# --------------------------------------------------------------------------
def rope_cos_sin(positions, head_dim: int, theta: float):
    """positions (B, S) -> cos/sin (B, S, head_dim/2) in f32."""
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang), jnp.sin(ang)


def mrope_cos_sin(positions_3d, sections: Tuple[int, int, int],
                  head_dim: int, theta: float):
    """Qwen2-VL M-RoPE: head_dim/2 frequency slots split into (temporal,
    height, width) sections, each rotated by its own position stream.
    positions_3d (3, B, S) -> cos/sin (B, S, head_dim/2)."""
    t_sec, h_sec, w_sec = sections
    assert t_sec + h_sec + w_sec == head_dim // 2
    sel = jnp.concatenate([jnp.zeros((t_sec,), jnp.int32),
                           jnp.ones((h_sec,), jnp.int32),
                           jnp.full((w_sec,), 2, jnp.int32)])
    pos = jnp.take(positions_3d, sel, axis=0)      # (d2, B, S)
    pos = jnp.moveaxis(pos, 0, -1)                 # (B, S, d2)
    inv = 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                           / head_dim))
    ang = pos.astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, S, H, D); cos/sin (B, S, D/2) — rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1 = x[..., :d2].astype(jnp.float32)
    x2 = x[..., d2:].astype(jnp.float32)
    cos = cos[:, :, None, :]
    sin = sin[:, :, None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], -1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# attention core
# --------------------------------------------------------------------------
def _repeat_kv(x, n_rep: int):
    if n_rep == 1:
        return x
    b, s, kv, d = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, d)
                            ).reshape(b, s, kv * n_rep, d)


def sdpa(q, k, v, *, causal: bool, sliding_window: Optional[int] = None,
         kv_valid: Optional[jax.Array] = None):
    """q (B,Sq,H,D); k,v (B,Sk,KV,D); f32 softmax accumulation.

    ``kv_valid``: (Sk,) bool validity (decode ring caches); when given,
    causal/sliding masks are assumed already encoded in validity.
    """
    b, sq, h, d = q.shape
    q = _pin_heads(q)
    k = _pin_heads(_repeat_kv(k, h // k.shape[2]))
    v = _pin_heads(_repeat_kv(v, h // v.shape[2]))
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    logits = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * scale
    sk = k.shape[1]
    if kv_valid is not None:
        logits = jnp.where(kv_valid[None, None, None, :], logits, -1e30)
    else:
        q_pos = jnp.arange(sq)[:, None]
        k_pos = jnp.arange(sk)[None, :]
        mask = jnp.ones((sq, sk), bool)
        if causal:
            mask &= k_pos <= q_pos
        if sliding_window is not None:
            mask &= k_pos > q_pos - sliding_window
        logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def sdpa_chunked(q, k, v, *, causal: bool,
                 sliding_window: Optional[int] = None,
                 kv_chunk: int = 2048, unroll: bool = False):
    """Flash-style attention: scan over KV chunks with an online softmax.

    Never materializes the (B, H, Sq, Sk) logits — peak attention memory
    drops from O(Sq*Sk) to O(Sq*kv_chunk).  §Perf beyond-paper
    optimization for the 32k prefill / 4k train cells; numerically matches
    ``sdpa`` (tested)."""
    b, sq, h, d = q.shape
    sk = k.shape[1]
    q = _pin_heads(q)
    k = _pin_heads(_repeat_kv(k, h // k.shape[2]))
    v = _pin_heads(_repeat_kv(v, h // v.shape[2]))
    pad = -sk % kv_chunk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (sk + pad) // kv_chunk
    scale = 1.0 / jnp.sqrt(jnp.float32(d))
    qf = q.astype(jnp.float32)
    q_pos = jnp.arange(sq)[:, None]

    def body(carry, i):
        o, m, s = carry
        kc = jax.lax.dynamic_slice(k, (0, i * kv_chunk, 0, 0),
                                   (b, kv_chunk, h, d)).astype(jnp.float32)
        vc = jax.lax.dynamic_slice(v, (0, i * kv_chunk, 0, 0),
                                   (b, kv_chunk, h, d)).astype(jnp.float32)
        logits = jnp.einsum("bqhd,bkhd->bhqk", qf, kc,
                            preferred_element_type=jnp.float32) * scale
        k_pos = i * kv_chunk + jnp.arange(kv_chunk)[None, :]
        mask = k_pos < sk                       # drop the pad tail
        if causal:
            mask = mask & (k_pos <= q_pos)
        if sliding_window is not None:
            mask = mask & (k_pos > q_pos - sliding_window)
        logits = jnp.where(mask[None, None], logits, -1e30)
        m_new = jnp.maximum(m, logits.max(-1))
        corr = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        s_new = s * corr + p.sum(-1)
        o_new = o * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vc, preferred_element_type=jnp.float32)
        return (o_new, m_new, s_new), None

    o0 = jnp.zeros((b, h, sq, d), jnp.float32)
    m0 = jnp.full((b, h, sq), -jnp.inf, jnp.float32)
    s0 = jnp.zeros((b, h, sq), jnp.float32)
    (o, m, s), _ = jax.lax.scan(body, (o0, m0, s0),
                                jnp.arange(n_chunks, dtype=jnp.int32),
                                unroll=n_chunks if unroll else 1)
    out = o / jnp.maximum(s[..., None], 1e-30)
    return jnp.moveaxis(out, 1, 2).astype(q.dtype)   # (B, Sq, H, D)


def _qkv(params, x, x_kv, n_heads, n_kv_heads, head_dim, qk_norm, norm_eps):
    b, sq, _ = x.shape
    src = x if x_kv is None else x_kv
    sk = src.shape[1]
    q = x @ params["wq"]
    k = src @ params["wk"]
    v = src @ params["wv"]
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    q = q.reshape(b, sq, n_heads, head_dim)
    k = k.reshape(b, sk, n_kv_heads, head_dim)
    v = v.reshape(b, sk, n_kv_heads, head_dim)
    if qk_norm:
        q = rms_norm(q, params["q_norm"], norm_eps)
        k = rms_norm(k, params["k_norm"], norm_eps)
    return q, k, v


def attn_train(params, x, *, n_heads, n_kv_heads, head_dim, causal=True,
               cos_sin=None, qk_norm=False, sliding_window=None,
               norm_eps=1e-6, x_kv=None, attn_chunk=0,
               chunk_unroll=False):
    """Full-sequence attention (training / encoder / cross-attention).

    ``attn_chunk > 0`` switches to the flash-style chunked kernel."""
    b, sq, _ = x.shape
    q, k, v = _qkv(params, x, x_kv, n_heads, n_kv_heads, head_dim,
                   qk_norm, norm_eps)
    if cos_sin is not None:
        q = apply_rope(q, *cos_sin)
        if x_kv is None:
            k = apply_rope(k, *cos_sin)
    if attn_chunk:
        out = sdpa_chunked(q, k, v, causal=causal and x_kv is None,
                           sliding_window=sliding_window,
                           kv_chunk=attn_chunk, unroll=chunk_unroll)
    else:
        out = sdpa(q, k, v, causal=causal and x_kv is None,
                   sliding_window=sliding_window)
    return out.reshape(b, sq, n_heads * head_dim) @ params["wo"]


def attn_prefill(params, x, cache, *, n_heads, n_kv_heads, head_dim,
                 cos_sin=None, qk_norm=False, sliding_window=None,
                 norm_eps=1e-6, attn_chunk=0, chunk_unroll=False):
    """Causal prefill; fills ``cache`` {"k","v"} (B, W, KV, D).

    W < S means a sliding-window ring cache: the last W (rope'd) keys are
    rolled so token t lands in slot t mod W — decode then appends at
    (pos mod W) with no relocation.
    """
    b, s, _ = x.shape
    q, k, v = _qkv(params, x, None, n_heads, n_kv_heads, head_dim,
                   qk_norm, norm_eps)
    if cos_sin is not None:
        q = apply_rope(q, *cos_sin)
        k = apply_rope(k, *cos_sin)
    if attn_chunk:
        out = sdpa_chunked(q, k, v, causal=True,
                           sliding_window=sliding_window,
                           kv_chunk=attn_chunk, unroll=chunk_unroll)
    else:
        out = sdpa(q, k, v, causal=True, sliding_window=sliding_window)
    w = cache["k"].shape[1]
    kd = k.astype(cache["k"].dtype)
    vd = v.astype(cache["v"].dtype)
    if w < s:
        ck = jnp.roll(kd[:, -w:], s % w, axis=1)
        cv = jnp.roll(vd[:, -w:], s % w, axis=1)
    else:
        ck = jax.lax.dynamic_update_slice(cache["k"], kd, (0, 0, 0, 0))
        cv = jax.lax.dynamic_update_slice(cache["v"], vd, (0, 0, 0, 0))
    new_cache = {"k": ck, "v": cv}
    return out.reshape(b, s, n_heads * head_dim) @ params["wo"], new_cache


def attn_decode(params, x, cache, pos, *, n_heads, n_kv_heads, head_dim,
                cos_sin=None, qk_norm=False, norm_eps=1e-6):
    """One-token decode against a (ring) cache; x (B, 1, d), pos scalar.

    Keys in the cache already carry RoPE; masking is pure validity:
    valid slots = min(pos+1, W) (a full ring holds exactly the last W
    tokens, which is the sliding window by construction).
    """
    b = x.shape[0]
    q, k, v = _qkv(params, x, None, n_heads, n_kv_heads, head_dim,
                   qk_norm, norm_eps)
    if cos_sin is not None:
        q = apply_rope(q, *cos_sin)
        k = apply_rope(k, *cos_sin)
    w = cache["k"].shape[1]
    slot = pos % w
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    kv_valid = jnp.arange(w) < jnp.minimum(pos + 1, w)
    out = sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=False,
               kv_valid=kv_valid)
    return (out.reshape(b, 1, n_heads * head_dim) @ params["wo"],
            {"k": ck, "v": cv})


def xattn_decode(params, x, cross_cache, *, n_heads, n_kv_heads, head_dim,
                 norm_eps=1e-6):
    """Cross-attention during decode: K/V fixed from the encoder (cached)."""
    b = x.shape[0]
    q = (x @ params["wq"])
    if "bq" in params:
        q = q + params["bq"].astype(q.dtype)
    q = q.reshape(b, 1, n_heads, head_dim)
    out = sdpa(q, cross_cache["k"].astype(q.dtype),
               cross_cache["v"].astype(q.dtype), causal=False)
    return out.reshape(b, 1, n_heads * head_dim) @ params["wo"]


def xattn_make_cache(params, enc, *, n_kv_heads, head_dim, dtype):
    """Precompute cross-attention K/V from encoder states (prefill)."""
    b, sk, _ = enc.shape
    k = enc @ params["wk"]
    v = enc @ params["wv"]
    if "bk" in params:
        k = k + params["bk"].astype(k.dtype)
        v = v + params["bv"].astype(v.dtype)
    return {"k": k.reshape(b, sk, n_kv_heads, head_dim).astype(dtype),
            "v": v.reshape(b, sk, n_kv_heads, head_dim).astype(dtype)}


# --------------------------------------------------------------------------
# feed-forward
# --------------------------------------------------------------------------
def mlp(params, x, act: str = "silu"):
    """SwiGLU (w_gate present) or plain 2-layer MLP."""
    a = jax.nn.silu if act == "silu" else jax.nn.gelu
    if "w_gate" in params:
        hidden = a(x @ params["w_gate"]) * (x @ params["w_in"])
    else:
        hidden = a(x @ params["w_in"])
    return hidden @ params["w_out"]

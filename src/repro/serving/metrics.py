"""Serving observability — per-model counters behind ``Server.stats()``.

One :class:`ModelMetrics` per published model name tracks request
latency percentiles (over a sliding window of completed requests),
rolling QPS (completions inside the last ``qps_window_s`` seconds),
batch-fill ratio (real rows flushed / power-of-two bucket rows they
padded to — how much of each compiled executable's capacity the
coalescer actually used), flush and drop counts.  All methods are
thread-safe: the dispatcher thread records while callers snapshot.

Failure accounting is EXPLICIT — zero silent drops by construction:
every request the daemon cannot serve lands in exactly one typed
counter (``shed`` = rejected at admission with
:class:`~repro.resilience.QueueFullError`, ``deadline_failures`` =
expired in queue with :class:`~repro.resilience.DeadlineExceededError`,
``dropped`` = flush/dispatcher failure) AND its future carries the same
typed exception.  :class:`ServerHealth` is the daemon-level
health/readiness snapshot behind ``Server.health()``.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Dict, Optional


class ModelMetrics:
    """Latency/QPS/fill counters for one served model."""

    def __init__(self, window: int = 2048, qps_window_s: float = 10.0):
        self._lock = threading.Lock()
        self._lat = deque(maxlen=window)     # completed-request latencies (s)
        self._done = deque()                 # completion stamps (rolling QPS)
        self._qps_window_s = float(qps_window_s)
        self._requests = 0
        self._rows = 0
        self._flushes = 0
        self._dropped = 0
        self._shed = 0                       # admission-rejected (queue full)
        self._deadline_failures = 0          # expired in queue
        self._fill_rows = 0                  # real rows across flushes
        self._bucket_rows = 0                # bucket capacity they padded to

    def record_flush(self, real_rows: int, bucket_rows: int) -> None:
        with self._lock:
            self._flushes += 1
            self._fill_rows += int(real_rows)
            self._bucket_rows += int(bucket_rows)

    def record_request(self, n_rows: int, latency_s: float,
                       now: Optional[float] = None) -> None:
        now = time.monotonic() if now is None else now
        with self._lock:
            self._requests += 1
            self._rows += int(n_rows)
            self._lat.append(float(latency_s))
            self._done.append(now)
            cutoff = now - self._qps_window_s
            while self._done and self._done[0] < cutoff:
                self._done.popleft()

    def record_drop(self) -> None:
        with self._lock:
            self._dropped += 1

    def record_shed(self) -> None:
        """A request rejected at admission — the queue bound held."""
        with self._lock:
            self._shed += 1

    def record_deadline(self) -> None:
        """A queued segment that expired before any flush took it."""
        with self._lock:
            self._deadline_failures += 1

    def snapshot(self) -> Dict[str, float]:
        with self._lock:
            lat = sorted(self._lat)
            now = time.monotonic()
            cutoff = now - self._qps_window_s
            recent = sum(1 for t in self._done if t >= cutoff)

            def pct(p: float) -> float:
                if not lat:
                    return 0.0
                i = min(len(lat) - 1, int(round(p / 100.0 * (len(lat) - 1))))
                return lat[i] * 1e3

            fill = (self._fill_rows / self._bucket_rows
                    if self._bucket_rows else 0.0)
            return {"requests": self._requests, "rows": self._rows,
                    "flushes": self._flushes, "dropped": self._dropped,
                    "shed": self._shed,
                    "deadline_failures": self._deadline_failures,
                    "p50_ms": pct(50), "p99_ms": pct(99),
                    "batch_fill": fill,
                    "qps": recent / self._qps_window_s}


@dataclasses.dataclass
class ServerHealth:
    """Daemon-level health/readiness — what an orchestrator probes.

    ``alive`` (liveness): the dispatcher thread is running (possibly
    after supervised restarts).  ``ready`` (readiness): alive AND
    accepting submissions (not stopping, restart budget not exhausted).
    ``failed_requests`` totals every typed failure across models —
    dropped + shed + deadline_failures — so ``failed_requests`` +
    completed requests always accounts for every submission.
    """

    alive: bool
    ready: bool
    dispatcher_restarts: int
    queued_rows: int
    models: int
    failed_requests: int

    def as_dict(self) -> Dict[str, float]:
        return dataclasses.asdict(self)


def format_stats_line(name: str, snap: Dict[str, float]) -> str:
    """The periodic one-line log the daemon emits per model."""
    return (f"[serving] {name}: {snap['requests']} req ({snap['rows']} rows,"
            f" {snap['qps']:.1f} qps) p50 {snap['p50_ms']:.1f} ms"
            f" p99 {snap['p99_ms']:.1f} ms fill {snap['batch_fill']:.2f}"
            f" flushes {snap['flushes']} dropped {snap['dropped']}"
            f" retraces {snap.get('traces', 0)}")

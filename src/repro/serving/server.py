"""The serving daemon: deadline-aware dynamic batching over shape buckets.

A :class:`Server` owns one dispatcher thread draining per-model request
queues.  ``submit(name, X)`` enqueues and returns a :class:`Request`
future immediately; the dispatcher coalesces queued requests for the same
model into one flush — the largest batch (up to ``max_batch`` rows) that
can be assembled before the OLDEST queued request's deadline slack
expires — runs it through the compile-once predict engine, and scatters
the result rows back per-request.  Because the engine pads each flush to
its power-of-two row bucket, coalescing k small requests into one flush
costs one warm executable dispatch instead of k, and padding never
changes results (padded rows are sliced off), so a coalesced batch is
served bit-equal to individual predicts.

Deadline semantics: each request carries ``slack_ms`` — how long it may
sit in the queue waiting for company.  A flush fires as soon as EITHER
the head request's slack expires OR ``max_batch`` rows are queued.
``slack_ms=0`` degenerates to immediate per-request dispatch; larger
slack trades head latency for batch fill.  Requests larger than
``max_batch`` are chopped into segments served across flushes and
reassembled before the future resolves.

Models come from a :class:`~repro.serving.registry.ModelRegistry`; the
plan is threaded once through the registry, hot-swaps are picked up at
the next flush (in-flight work keeps the entry it started with), and
``warmup(name)`` pre-compiles EVERY power-of-two row bucket a flush can
produce — the full bucket set up to ``max_batch``, a strict superset of
any reachable flush size, so a zero-retrace assertion after warmup can
never pass vacuously.

Overload and failure posture (PR 9): queues are bounded
(``max_queue_rows``) with EXPLICIT load shedding — an admission-rejected
request's future fails with :class:`~repro.resilience.QueueFullError`
and is counted, never silently dropped; queued segments carry an
optional hard deadline (``timeout_ms``) and expire with
:class:`~repro.resilience.DeadlineExceededError`; the dispatcher thread
runs under a supervisor that fails the crashed flush's in-flight
requests with :class:`~repro.resilience.DispatcherCrashError`, restarts
the dispatcher (bounded by ``max_dispatcher_restarts``), and keeps
serving.  ``health()`` reports liveness/readiness.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from concurrent.futures import Future
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.inference import ROW_BUCKET_FLOOR, bucket_pow2
from repro.resilience.errors import (DeadlineExceededError,
                                     DispatcherCrashError, QueueFullError)
from repro.serving.metrics import (ModelMetrics, ServerHealth,
                                   format_stats_line)
from repro.serving.registry import ModelRegistry


def warmup_buckets(max_rows: int,
                   floor: int = ROW_BUCKET_FLOOR) -> List[int]:
    """Every power-of-two row bucket a flush of <= ``max_rows`` rows can
    land in.  This is the warmup set AND the coalescer's reachable-bucket
    set — deriving both from one helper is what makes "zero retraces
    after warmup" a meaningful check."""
    out, b = [], floor
    top = bucket_pow2(max_rows, floor)
    while b <= top:
        out.append(b)
        b *= 2
    return out


class Request:
    """Handle for one ``submit()`` call — a future over the result rows.

    ``result(timeout)`` blocks until every segment of the request has
    been served and returns the (n_rows,) / (n_rows, K) predictions in
    submission row order.
    """

    def __init__(self, name: str, n_rows: int, slack_s: float,
                 timeout_s: Optional[float] = None):
        self.name = name
        self.n_rows = n_rows
        self.submitted_at = time.monotonic()
        self.flush_by = self.submitted_at + slack_s
        # hard queue deadline: past this, un-flushed segments fail with
        # DeadlineExceededError instead of waiting out a storm
        self.deadline = (None if timeout_s is None
                         else self.submitted_at + timeout_s)
        self._future: Future = Future()
        self._parts: Dict[int, np.ndarray] = {}
        self._pending = 0        # segments not yet delivered

    def result(self, timeout: Optional[float] = None) -> np.ndarray:
        return self._future.result(timeout)

    def done(self) -> bool:
        return self._future.done()

    @property
    def latency_s(self) -> float:
        """Submission-to-completion wall time (completed requests only)."""
        return self._completed_at - self.submitted_at

    def _deliver(self, index: int, rows: np.ndarray) -> bool:
        """Store one served segment; True when the request completed."""
        self._parts[index] = rows
        self._pending -= 1
        if self._pending:
            return False
        parts = [self._parts[i] for i in sorted(self._parts)]
        self._completed_at = time.monotonic()
        self._future.set_result(
            parts[0] if len(parts) == 1 else np.concatenate(parts))
        return True

    def _fail(self, exc: BaseException) -> None:
        if not self._future.done():
            self._future.set_exception(exc)


class _Segment:
    """A <= max_batch slice of one request — the queue/flush unit."""

    __slots__ = ("request", "index", "X", "rows")

    def __init__(self, request: Request, index: int, X: np.ndarray):
        self.request = request
        self.index = index
        self.X = X
        self.rows = int(X.shape[0])


class Server:
    """Deadline-aware batching daemon over a :class:`ModelRegistry`.

    Parameters
    ----------
    registry:         the model tenancy (its plan is THE predict plan).
    max_batch:        flush capacity in rows; also the request chop size.
    default_slack_ms: queue-wait budget for ``submit()`` calls that don't
                      pass their own ``slack_ms``.
    log_every_s:      emit one stats log line per model at this cadence
                      (None = silent; the ``stats()`` snapshot always works).
    max_queue_rows:   per-model queue bound; a submit that would exceed it
                      is SHED — its future fails with ``QueueFullError``
                      (None = unbounded, the pre-PR-9 behavior).
    timeout_ms:       default hard deadline for queued work; segments
                      still queued past it fail with
                      ``DeadlineExceededError`` (None = wait forever).
    max_dispatcher_restarts: supervisor restart budget; the crash that
                      exhausts it fails ALL queued work and marks the
                      server not ready.
    fault_injector:   a :class:`repro.resilience.FaultSchedule` applied at
                      site ``"dispatch"`` once per flush (chaos testing).
    """

    def __init__(self, registry: ModelRegistry, *, max_batch: int = 4096,
                 default_slack_ms: float = 20.0,
                 log_every_s: Optional[float] = None,
                 max_queue_rows: Optional[int] = None,
                 timeout_ms: Optional[float] = None,
                 max_dispatcher_restarts: int = 3,
                 fault_injector=None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if max_queue_rows is not None and max_queue_rows < max_batch:
            raise ValueError("max_queue_rows must be >= max_batch")
        self._registry = registry
        self._max_batch = int(max_batch)
        self._default_slack_s = float(default_slack_ms) / 1e3
        self._default_timeout_s = (None if timeout_ms is None
                                   else float(timeout_ms) / 1e3)
        self._max_queue_rows = (None if max_queue_rows is None
                                else int(max_queue_rows))
        self._max_restarts = int(max_dispatcher_restarts)
        self._faults = fault_injector
        self._log_every_s = log_every_s
        self._last_log = time.monotonic()
        self._cv = threading.Condition()
        self._queues: Dict[str, deque] = {}
        self._queued_rows: Dict[str, int] = {}
        self._metrics: Dict[str, ModelMetrics] = {}
        self._stopping = False
        self._dead = False               # restart budget exhausted
        self._restarts = 0
        self._flush_seq = 0              # fault-injection step counter
        self._inflight: List[_Segment] = []
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="repro-serving-dispatch")
        self._thread.start()

    # -- client surface ------------------------------------------------------
    def submit(self, name: str, X, *,
               slack_ms: Optional[float] = None,
               timeout_ms: Optional[float] = None) -> Request:
        """Enqueue one prediction request; returns immediately.

        The returned future fails typed when the daemon cannot serve it:
        ``QueueFullError`` (shed at admission — the request was never
        queued), ``DeadlineExceededError`` (expired in queue), or
        ``DispatcherCrashError`` (in flight when the dispatcher died).
        """
        self._registry.entry(name)            # fail fast on unknown tenants
        X = np.asarray(X, np.float32)
        if X.ndim != 2 or X.shape[0] < 1:
            raise ValueError(f"expected a (n_rows >= 1, n_fields) batch, "
                             f"got shape {X.shape}")
        slack_s = (self._default_slack_s if slack_ms is None
                   else float(slack_ms) / 1e3)
        timeout_s = (self._default_timeout_s if timeout_ms is None
                     else float(timeout_ms) / 1e3)
        req = Request(name, int(X.shape[0]), slack_s, timeout_s)
        segments = [_Segment(req, i, X[lo:lo + self._max_batch])
                    for i, lo in enumerate(range(0, X.shape[0],
                                                 self._max_batch))]
        req._pending = len(segments)
        with self._cv:
            if self._stopping:
                raise RuntimeError("server is stopped")
            metrics = self._metrics.setdefault(name, ModelMetrics())
            if self._dead:
                metrics.record_shed()
                req._fail(DispatcherCrashError(
                    "dispatcher restart budget exhausted; server is not "
                    "accepting work"))
                return req
            queued = self._queued_rows.get(name, 0)
            if (self._max_queue_rows is not None
                    and queued + req.n_rows > self._max_queue_rows):
                # explicit load shedding: typed failure + counter, and the
                # request never enters the queue
                metrics.record_shed()
                req._fail(QueueFullError(
                    f"queue for {name!r} holds {queued} rows; admitting "
                    f"{req.n_rows} more would exceed the "
                    f"{self._max_queue_rows}-row bound"))
                return req
            q = self._queues.setdefault(name, deque())
            q.extend(segments)
            self._queued_rows[name] = queued + req.n_rows
            self._cv.notify()
        return req

    def warmup(self, name: str, *, max_rows: Optional[int] = None) -> int:
        """Pre-compile every row bucket reachable by a flush (plus the
        model's step cache); returns the number of XLA traces it cost.
        A warm server must then serve ANY traffic mix with zero retraces.
        """
        entry = self._registry.entry(name)
        before = entry.cache.stats()["traces"]
        self._registry.warm(name,
                            warmup_buckets(max_rows or self._max_batch))
        return entry.cache.stats()["traces"] - before

    def stats(self) -> Dict[str, Dict]:
        """Snapshot: per-model latency/QPS/fill/drop counters merged with
        queue depth and the registry's version + retrace counters."""
        with self._cv:
            metrics = dict(self._metrics)
            depths = dict(self._queued_rows)
        registry = self._registry.stats()
        out: Dict[str, Dict] = {}
        for name in set(metrics) | set(registry):
            snap = (metrics[name].snapshot() if name in metrics
                    else ModelMetrics().snapshot())
            snap["queue_depth"] = depths.get(name, 0)
            reg = registry.get(name, {})
            snap["version"] = reg.get("version", 0)
            snap["traces"] = reg.get("cache", {}).get("traces", 0)
            out[name] = snap
        return out

    def health(self) -> ServerHealth:
        """Liveness/readiness snapshot (see :class:`ServerHealth`)."""
        with self._cv:
            alive = self._thread.is_alive() and not self._dead
            ready = alive and not self._stopping
            restarts = self._restarts
            queued = sum(self._queued_rows.values())
            metrics = dict(self._metrics)
        failed = 0
        for m in metrics.values():
            snap = m.snapshot()
            failed += (snap["dropped"] + snap["shed"]
                       + snap["deadline_failures"])
        return ServerHealth(alive=alive, ready=ready,
                            dispatcher_restarts=restarts,
                            queued_rows=queued, models=len(metrics),
                            failed_requests=int(failed))

    def stop(self, timeout: Optional[float] = None) -> None:
        """Drain every queue, then stop the dispatcher thread."""
        with self._cv:
            self._stopping = True
            self._cv.notify()
        self._thread.join(timeout)

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- dispatcher ----------------------------------------------------------
    @staticmethod
    def _head_by(seg: _Segment) -> float:
        """When the queue head demands attention: its flush-by slack or
        its hard deadline, whichever lands first."""
        by = seg.request.flush_by
        dl = seg.request.deadline
        return by if dl is None else min(by, dl)

    def _pick(self, now: float):
        """(model to flush now, earliest future deadline) — lock held."""
        pick, pick_deadline, wake = None, None, None
        for name, q in self._queues.items():
            if not q:
                continue
            head_by = self._head_by(q[0])
            ready = (self._stopping or head_by <= now
                     or self._queued_rows[name] >= self._max_batch)
            if ready:
                if pick is None or head_by < pick_deadline:
                    pick, pick_deadline = name, head_by
            elif wake is None or head_by < wake:
                wake = head_by
        return pick, wake

    def _take(self, name: str,
              now: float) -> Tuple[List[_Segment], List[_Segment]]:
        """Pop the flush batch: FIFO segments up to max_batch rows — the
        largest bucket that fits before the head's deadline.  Segments
        whose hard deadline already passed are popped into the expired
        list instead (failed typed by the caller).  Lock held."""
        q = self._queues[name]
        batch, rows, expired = [], 0, []
        while q:
            seg = q[0]
            dl = seg.request.deadline
            if dl is not None and dl <= now:
                q.popleft()
                self._queued_rows[name] -= seg.rows
                expired.append(seg)
                continue
            if rows + seg.rows > self._max_batch:
                break
            q.popleft()
            self._queued_rows[name] -= seg.rows
            batch.append(seg)
            rows += seg.rows
        return batch, expired

    def _run(self) -> None:
        """Dispatcher supervisor: restart a crashed ``_loop`` (bounded),
        failing the crashed flush's in-flight requests typed.  The crash
        that exhausts the budget fails ALL queued work and marks the
        server dead (not ready) — submissions then fail fast."""
        while True:
            try:
                self._loop()
                return                     # clean stop()
            except BaseException as exc:   # noqa: BLE001 — supervised
                with self._cv:
                    batch, self._inflight = self._inflight, []
                    self._restarts += 1
                    dead = self._restarts > self._max_restarts
                    drained: List[_Segment] = []
                    if dead:
                        self._dead = True
                        for q in self._queues.values():
                            drained.extend(q)
                            q.clear()
                        for name in self._queued_rows:
                            self._queued_rows[name] = 0
                err = DispatcherCrashError(
                    f"dispatcher crashed ({type(exc).__name__}: {exc})"
                    + ("; restart budget exhausted" if dead
                       else "; restarting"))
                err.__cause__ = exc
                for seg in batch + drained:
                    seg.request._fail(err)
                    self._metrics[seg.request.name].record_drop()
                if dead:
                    return

    def _loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    now = time.monotonic()
                    name, wake = self._pick(now)
                    if name is not None:
                        batch, expired = self._take(name, now)
                        self._inflight = batch
                        break
                    if self._stopping:
                        return
                    self._cv.wait(timeout=(None if wake is None
                                           else max(wake - now, 0.0)))
            for seg in expired:
                self._metrics[name].record_deadline()
                req = seg.request
                waited_ms = (time.monotonic() - req.submitted_at) * 1e3
                budget_ms = (req.deadline - req.submitted_at) * 1e3
                req._fail(DeadlineExceededError(
                    f"request for {name!r} expired after {waited_ms:.0f} ms "
                    f"in queue (deadline {budget_ms:.0f} ms)"))
            if batch:
                if self._faults is not None:
                    seq = self._flush_seq
                    self._flush_seq += 1
                    self._faults.apply("dispatch", seq)
                self._serve(name, batch)
            with self._cv:
                self._inflight = []

    def _serve(self, name: str, batch: List[_Segment]) -> None:
        metrics = self._metrics[name]
        try:
            entry = self._registry.entry(name)
            X = (batch[0].X if len(batch) == 1
                 else np.concatenate([s.X for s in batch]))
            preds = np.asarray(entry.pipeline.predict(
                X, plan=self._registry.plan, mode="cached",
                cache=entry.cache))
        except BaseException as exc:
            # a flush can only fail as a unit (e.g. the tenant was
            # unpublished mid-flight): fail the futures, count the drops
            for seg in batch:
                seg.request._fail(exc)
                metrics.record_drop()
            return
        rows = int(X.shape[0])
        entry.seen_buckets.add(bucket_pow2(rows, ROW_BUCKET_FLOOR))
        metrics.record_flush(rows, bucket_pow2(rows, ROW_BUCKET_FLOOR))
        lo = 0
        for seg in batch:
            if seg.request._deliver(seg.index, preds[lo:lo + seg.rows]):
                metrics.record_request(seg.request.n_rows,
                                       seg.request.latency_s)
            lo += seg.rows
        self._maybe_log()

    def _maybe_log(self) -> None:
        if self._log_every_s is None:
            return
        now = time.monotonic()
        if now - self._last_log < self._log_every_s:
            return
        self._last_log = now
        for model_name, snap in sorted(self.stats().items()):
            print(format_stats_line(model_name, snap))

"""Multi-model tenancy: named ensembles with zero-retrace hot-swap.

A :class:`ModelRegistry` keeps N named :class:`~repro.core.inference.
GBDTPipeline` bundles resident concurrently, each with its OWN
:class:`~repro.core.inference.PredictCache` — the compiled-step namespace
is keyed per model *name*, so tenants never evict each other's
executables and ``unpublish`` drops exactly one tenant's compilations.

Hot-swap contract (``publish`` on an already-published name): the cache
namespace SURVIVES the swap.  Trees are traced arguments to the jitted
predict step, not compile-time constants, so when the new version lands
in the same shape buckets as the old one (same depth, class count,
missing bin, ``bucket_trees`` tree bucket and field count) every warm
executable is reused as-is — zero retraces, by construction.  When the
buckets do NOT match, ``publish`` warms the new version over every row
bucket the old one has served *before* swapping the entry, so the
compilations happen off the serving hot path and in-flight requests keep
hitting the old version until the swap is atomic under the registry lock.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set

import numpy as np

from repro.api.plan import ExecutionPlan
from repro.core.inference import GBDTPipeline, PredictCache


def _as_pipeline(model) -> GBDTPipeline:
    """Coerce a publishable object: a bundle directory path (the unified
    ``repro.api`` serialization), an estimator (anything exposing
    ``to_pipeline()``), or a ready pipeline."""
    if isinstance(model, str):
        from repro.api.serialize import load
        model = load(model)
    if isinstance(model, GBDTPipeline):
        return model
    to_pipeline = getattr(model, "to_pipeline", None)
    if callable(to_pipeline):
        return to_pipeline()
    raise TypeError(
        f"cannot publish {type(model).__name__!r}: expected a bundle "
        "directory path, a fitted estimator, or a GBDTPipeline")


class _Entry:
    """One resident model version + its private jit-cache namespace."""

    __slots__ = ("pipeline", "cache", "version", "seen_buckets")

    def __init__(self, pipeline: GBDTPipeline, cache: PredictCache,
                 version: int, seen_buckets: Set[int]):
        self.pipeline = pipeline
        self.cache = cache
        self.version = version
        self.seen_buckets = seen_buckets     # row buckets served/warmed


class ModelRegistry:
    """Named, hot-swappable ensembles behind one predict plan.

    ``plan`` is threaded ONCE, here — every lookup/warmup/serve path
    reuses it, so no per-call plan resolution happens on the hot path.
    """

    def __init__(self, plan: Optional[ExecutionPlan] = None):
        self.plan = (plan if plan is not None else ExecutionPlan()).resolved()
        self._lock = threading.Lock()
        self._entries: Dict[str, _Entry] = {}

    # -- tenancy ------------------------------------------------------------
    def publish(self, name: str, model, *, warm: bool = True) -> int:
        """Make ``model`` the live version under ``name``; returns the new
        version number (1 for a first publish).

        Replacing an existing name keeps its :class:`PredictCache`, and
        (with ``warm=True``) runs the new version through every row
        bucket the old one has served before the atomic swap — see the
        module docstring for the zero-retrace contract.
        """
        pipeline = _as_pipeline(model)
        with self._lock:
            old = self._entries.get(name)
            cache = old.cache if old is not None else PredictCache()
            version = old.version + 1 if old is not None else 1
            seen = set(old.seen_buckets) if old is not None else set()
        if warm and seen:
            self._warm(pipeline, cache, sorted(seen))
        with self._lock:
            self._entries[name] = _Entry(pipeline, cache, version, seen)
        return version

    def unpublish(self, name: str) -> None:
        """Drop a tenant and evict its compiled predict steps."""
        with self._lock:
            entry = self._entries.pop(name, None)
        if entry is None:
            raise KeyError(name)
        entry.cache.clear()

    def entry(self, name: str) -> _Entry:
        with self._lock:
            try:
                return self._entries[name]
            except KeyError:
                raise KeyError(
                    f"no model published under {name!r} "
                    f"(published: {sorted(self._entries)})") from None

    def pipeline(self, name: str) -> GBDTPipeline:
        return self.entry(name).pipeline

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._entries)

    def __contains__(self, name: str) -> bool:
        with self._lock:
            return name in self._entries

    # -- warmup ---------------------------------------------------------------
    def _warm(self, pipeline: GBDTPipeline, cache: PredictCache,
              buckets) -> None:
        """Compile ``pipeline``'s steps for the given row buckets (synthetic
        zero batches — only shapes matter to the jit cache)."""
        F = pipeline.model.n_fields
        for b in buckets:
            np.asarray(pipeline.predict_margin(
                np.zeros((int(b), F), np.float32), plan=self.plan,
                mode="cached", cache=cache))

    def warm(self, name: str, buckets) -> None:
        """Warm the live version of ``name`` over explicit row buckets."""
        entry = self.entry(name)
        self._warm(entry.pipeline, entry.cache, buckets)
        entry.seen_buckets.update(int(b) for b in buckets)

    # -- observability ------------------------------------------------------
    def stats(self) -> Dict[str, Dict]:
        """Per-model registry view: live version + jit-cache counters."""
        with self._lock:
            entries = dict(self._entries)
        return {name: {"version": e.version,
                       "cache": e.cache.stats(),
                       "warm_buckets": sorted(e.seen_buckets)}
                for name, e in entries.items()}

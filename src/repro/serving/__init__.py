"""``repro.serving`` — the production serving daemon over the GBDT engine.

Public surface (re-exported through ``repro.api``):

  * :class:`Server` — worker thread draining a deadline-aware request
    queue; ragged ``submit()`` calls coalesce into power-of-two-bucketed
    flushes and scatter back per-request via :class:`Request` futures.
  * :class:`ModelRegistry` — N named ensembles resident concurrently,
    each with its own compiled-step namespace; ``publish`` hot-swaps a
    version with zero retraces when the shape buckets match.
  * :class:`Request` — the future handle ``submit()`` returns.
  * :func:`warmup_buckets` — the reachable flush-bucket set (shared by
    ``Server.warmup`` and any external zero-retrace check).
  * :class:`ServerHealth` — ``Server.health()``'s liveness/readiness
    snapshot; typed overload/crash failures are the exception types in
    :mod:`repro.resilience` (``QueueFullError``, ``DeadlineExceededError``,
    ``DispatcherCrashError``).
"""
from repro.resilience.errors import (DeadlineExceededError,  # noqa: F401
                                     DispatcherCrashError, QueueFullError)
from repro.serving.metrics import (ModelMetrics, ServerHealth,
                                   format_stats_line)
from repro.serving.registry import ModelRegistry
from repro.serving.server import Request, Server, warmup_buckets

__all__ = ["Server", "ModelRegistry", "Request", "ModelMetrics",
           "ServerHealth", "warmup_buckets", "format_stats_line",
           "QueueFullError", "DeadlineExceededError",
           "DispatcherCrashError"]

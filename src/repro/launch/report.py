"""Aggregate dry-run artifacts into the §Dry-run / §Roofline tables.

Usage:  PYTHONPATH=src python -m repro.launch.report [--dir artifacts/dryrun]
Emits markdown to stdout (pasted into EXPERIMENTS.md).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCH_IDS, SHAPES
from repro.launch.roofline import format_table


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}us"


def _fmt_b(x):
    if x is None:
        return "-"
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def load(directory: str, mesh: str = "single", variant: str = "base"):
    recs = {}
    for path in glob.glob(os.path.join(directory, f"{mesh}_*.json")):
        name = os.path.basename(path)[:-5]
        if variant == "base" and name.count("_") > 2:
            # variant artifacts carry a 4th underscore-separated token
            parts = name.split("_")
            if parts[-1] in ("base",) or len(parts) == 3:
                pass
        with open(path) as f:
            rec = json.load(f)
        if rec.get("variant", "base") != variant:
            continue
        recs[(rec["arch"], rec["shape"])] = rec
    return recs


def roofline_rows(recs):
    rows = []
    for aid in ARCH_IDS:
        for sh in SHAPES:
            rec = recs.get((aid, sh))
            if rec is None:
                continue
            if rec.get("skipped"):
                rows.append({"arch": aid, "shape": sh, "status": "SKIP",
                             "dominant": "-", "compute": "-", "memory": "-",
                             "collective": "-", "frac": "-", "mf_ratio": "-",
                             "hbm/dev": "-"})
                continue
            if "error" in rec:
                rows.append({"arch": aid, "shape": sh, "status": "FAIL",
                             "dominant": "-", "compute": "-", "memory": "-",
                             "collective": "-", "frac": "-", "mf_ratio": "-",
                             "hbm/dev": "-"})
                continue
            rows.append({
                "arch": aid, "shape": sh, "status": "ok",
                "compute": _fmt_s(rec["compute_s"]),
                "memory": _fmt_s(rec["memory_s"]),
                "collective": _fmt_s(rec["collective_s"]),
                "dominant": rec["dominant"],
                "frac": f"{rec['roofline_fraction']:.3f}",
                "mf_ratio": f"{rec.get('model_flops_ratio', 0):.3f}",
                "hbm/dev": _fmt_b(rec.get("bytes_per_device")),
            })
    return rows


def main():
    ap = argparse.ArgumentParser()
    default_dir = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                               "artifacts", "dryrun")
    ap.add_argument("--dir", default=os.path.abspath(default_dir))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default="base")
    args = ap.parse_args()

    recs = load(args.dir, args.mesh, args.variant)
    rows = roofline_rows(recs)
    keys = ["arch", "shape", "status", "compute", "memory", "collective",
            "dominant", "frac", "mf_ratio", "hbm/dev"]
    print(f"### Roofline — mesh={args.mesh}, variant={args.variant}\n")
    print(format_table(rows, keys))
    ok = [r for r in rows if r["status"] == "ok"]
    print(f"\ncells: {len(rows)} total, {len(ok)} compiled, "
          f"{sum(1 for r in rows if r['status'] == 'SKIP')} skipped, "
          f"{sum(1 for r in rows if r['status'] == 'FAIL')} failed")


if __name__ == "__main__":
    main()

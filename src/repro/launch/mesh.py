"""Production mesh construction.

Axes:
  * ``pod``   — cross-pod data parallelism (only gradient/histogram psums
                cross this axis; DCI-friendly)
  * ``data``  — in-pod data parallelism (records / batch)
  * ``model`` — tensor / expert / field parallelism

Functions, not module-level constants: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import numpy as np

SINGLE_POD_SHAPE: Tuple[int, int] = (16, 16)          # 256 chips / pod
MULTI_POD_SHAPE: Tuple[int, int, int] = (2, 16, 16)   # 2 pods = 512 chips


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = MULTI_POD_SHAPE if multi_pod else SINGLE_POD_SHAPE
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape: Sequence[int], axes: Sequence[str],
              devices: Optional[Sequence] = None) -> jax.sharding.Mesh:
    """Arbitrary mesh over an explicit device list (elastic re-meshing)."""
    if devices is None:
        return jax.make_mesh(tuple(shape), tuple(axes))
    devs = np.asarray(devices).reshape(tuple(shape))
    return jax.sharding.Mesh(devs, tuple(axes))


def data_axes(mesh: jax.sharding.Mesh) -> Tuple[str, ...]:
    """Axes carrying record/batch parallelism (everything but 'model')."""
    return tuple(a for a in mesh.axis_names if a != "model")


def model_axis(mesh: jax.sharding.Mesh) -> str:
    return "model"


def n_data_shards(mesh: jax.sharding.Mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))

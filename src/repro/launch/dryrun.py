import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("REPRO_EXTRA_XLA_FLAGS", ""))
# ^^ MUST precede every other import: jax locks the device count on first
# init, and the production meshes below need 512 placeholder devices.

import argparse      # noqa: E402
import contextlib    # noqa: E402
import dataclasses   # noqa: E402
import gzip          # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import SHAPES, ARCH_IDS, get_arch, cell_is_runnable  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import lm, optim  # noqa: E402

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, then dump per-cell roofline artifacts.

No arrays are ever allocated: parameters, optimizer state, caches and
batches are ShapeDtypeStructs; ``.lower().compile()`` exercises the full
XLA SPMD pipeline (sharding propagation, collective insertion, memory
assignment) — sharding mismatches, compile-time OOM and unsupported
collectives surface here exactly as they would on hardware.

Variants (--variant) apply the §Perf hillclimb changes; "base" is the
paper-faithful/default configuration recorded in the roofline table.
"""

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


# --------------------------------------------------------------------------
# per-variant config/step transforms (§Perf hillclimbing hooks)
# --------------------------------------------------------------------------
def _variant_base(cfg, shape):
    return cfg, {}


def _variant_no_remat(cfg, shape):
    return dataclasses.replace(cfg, remat=False), {}


def _variant_blocked_xent(cfg, shape):
    # vocab-blocked cross entropy: no (B,S,V) logits materialization
    return cfg, {"vocab_blocks": 8}


def _variant_ssd_chunk64(cfg, shape):
    # SSD intra-chunk L matrix bytes scale with S*chunk: 256 -> 64 quarters
    # the mamba memory-term transient
    return dataclasses.replace(cfg, ssm_chunk=64), {}


def _variant_ssd_chunk128(cfg, shape):
    return dataclasses.replace(cfg, ssm_chunk=128), {}


def _variant_kv_shard_seq(cfg, shape):
    # shard the decode cache on its sequence dim instead of head_dim
    return cfg, {"kv_shard": "seq"}


def _variant_kv_shard_kv(cfg, shape):
    return cfg, {"kv_shard": "kv"}


def _variant_blocked_xent_chunk64(cfg, shape):
    return dataclasses.replace(cfg, ssm_chunk=64), {"vocab_blocks": 8}


def _variant_remat_dots(cfg, shape):
    # save matmul outputs in remat: backward skips recompute (and its FSDP
    # parameter re-gathers) at the cost of more resident activations
    return dataclasses.replace(cfg, remat_policy="dots"), {}


def _variant_remat_dots_blocked_xent(cfg, shape):
    return dataclasses.replace(cfg, remat_policy="dots"), {"vocab_blocks": 8}


def _variant_flash_attn(cfg, shape):
    # chunked online-softmax attention: O(Sq*Sk) logits never materialize
    return dataclasses.replace(cfg, attn_chunk=2048), {}


def _variant_flash_attn_blocked_xent(cfg, shape):
    return dataclasses.replace(cfg, attn_chunk=2048), {"vocab_blocks": 8}


def _variant_act_pin(cfg, shape):
    # pin block-boundary activations batch-sharded: GSPMD must all-gather
    # weights instead of all-reducing activations (MaxText-style)
    return cfg, {"act_pin": True}


def _variant_act_pin_flash(cfg, shape):
    return dataclasses.replace(cfg, attn_chunk=2048), {"act_pin": True}


def _variant_act_pin_remat_dots(cfg, shape):
    return dataclasses.replace(cfg, remat_policy="dots"), {"act_pin": True}


def _variant_act_pin_all(cfg, shape):
    # everything: pin + flash attention + blocked xent
    return dataclasses.replace(cfg, attn_chunk=2048), \
        {"act_pin": True, "vocab_blocks": 8}


def _variant_head_pin_flash(cfg, shape):
    # head-sharded q/k/v (padded) keeps per-head attention shard-local
    return dataclasses.replace(cfg, attn_chunk=2048), \
        {"act_pin": True, "head_pin": True}


def _variant_head_pin_all(cfg, shape):
    return dataclasses.replace(cfg, attn_chunk=2048), \
        {"act_pin": True, "head_pin": True, "vocab_blocks": 8}


def _variant_head_pin_flash4k(cfg, shape):
    # double the KV chunk: halves per-chunk Q re-reads in the chunk scan
    return dataclasses.replace(cfg, attn_chunk=4096), \
        {"act_pin": True, "head_pin": True}


def _variant_moe_ff_fsdp_all(cfg, shape):
    # TP-MoE fix: shard expert ff over data x model so expert matmuls
    # never contract a sharded d (mixtral's collective driver)
    return dataclasses.replace(cfg, attn_chunk=2048, moe_ff_fsdp=True), \
        {"act_pin": True, "head_pin": True, "vocab_blocks": 8}


VARIANTS = {
    "base": _variant_base,
    "no_remat": _variant_no_remat,
    "blocked_xent": _variant_blocked_xent,
    "ssd_chunk64": _variant_ssd_chunk64,
    "ssd_chunk128": _variant_ssd_chunk128,
    "kv_shard_seq": _variant_kv_shard_seq,
    "kv_shard_kv": _variant_kv_shard_kv,
    "blocked_xent_chunk64": _variant_blocked_xent_chunk64,
    "remat_dots": _variant_remat_dots,
    "remat_dots_blocked_xent": _variant_remat_dots_blocked_xent,
    "flash_attn": _variant_flash_attn,
    "flash_attn_blocked_xent": _variant_flash_attn_blocked_xent,
    "act_pin": _variant_act_pin,
    "act_pin_flash": _variant_act_pin_flash,
    "act_pin_remat_dots": _variant_act_pin_remat_dots,
    "act_pin_all": _variant_act_pin_all,
    "head_pin_flash": _variant_head_pin_flash,
    "head_pin_all": _variant_head_pin_all,
    "head_pin_flash4k": _variant_head_pin_flash4k,
    "moe_ff_fsdp_all": _variant_moe_ff_fsdp_all,
}


def _data_axes(mesh):
    da = tuple(a for a in mesh.axis_names if a != "model")
    return da if len(da) > 1 else da[0]


def _batch_specs(cfg, shape, mesh, opts):
    """ShapeDtypeStructs + shardings for the cell's inputs."""
    da = _data_axes(mesh)
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    f32 = jnp.float32
    sds = jax.ShapeDtypeStruct
    seq_spec = "model" if opts.get("seq_shard") else None

    if shape.kind == "train":
        batch = {"tokens": sds((B, S), i32), "labels": sds((B, S), i32)}
        specs = {"tokens": P(da, None), "labels": P(da, None)}
    elif shape.kind == "prefill":
        batch = {"tokens": sds((B, S), i32)}
        specs = {"tokens": P(da, seq_spec)}
    else:  # decode
        batch = {"token": sds((B, 1), i32)}
        specs = {"token": P(da if B > 1 else None, None)}

    if cfg.mrope and shape.kind != "decode":
        batch["positions"] = sds((3, B, S), i32)
        specs["positions"] = P(None, da, None)
    if cfg.family == "vlm" and shape.kind != "decode":
        batch["patch_embeds"] = sds((B, 256, cfg.d_model), f32)
        specs["patch_embeds"] = P(da, None, None)
    if cfg.family == "encdec" and shape.kind != "decode":
        batch["audio_embeds"] = sds((B, cfg.frontend_len, cfg.d_model), f32)
        specs["audio_embeds"] = P(da, None, None)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, P))
    return batch, shardings


def input_specs(arch_id: str, shape_name: str, mesh, variant: str = "base"):
    """Public helper: the cell's abstract inputs (ShapeDtypeStructs)."""
    cfg, opts = VARIANTS[variant](get_arch(arch_id), SHAPES[shape_name])
    return _batch_specs(cfg, SHAPES[shape_name], mesh, opts)[0]


def _with_groups(cfg, k: int):
    """Reduced-depth clone: k layer-pattern groups, unrolled (cost probe)."""
    period = cfg.scan_period()
    kw = dict(n_layers=period * k, scan_unroll=True)
    if cfg.family == "encdec":
        kw["encoder_layers"] = k
    return dataclasses.replace(cfg, **kw)


def lower_cell(arch_id: str, shape_name: str, mesh, variant: str = "base",
               groups: int | None = None):
    """Build + lower one (arch, shape) cell on ``mesh``.  Returns lowered.

    ``groups=k`` lowers a reduced-depth unrolled clone (k pattern groups)
    used by the two-point cost probe: XLA cost_analysis counts a while
    body once regardless of trip count, so the honest full-depth numbers
    are extrapolated linearly from unrolled k=1 and k=2 compiles (every
    cost term is constant or exactly linear in the group count)."""
    shape = SHAPES[shape_name]
    cfg, opts = VARIANTS[variant](get_arch(arch_id), shape)
    if groups is not None:
        cfg = _with_groups(cfg, groups)
    params_abs = lm.abstract_params(cfg)
    pshard = lm.param_shardings(cfg, mesh)
    da = _data_axes(mesh)
    batch_abs, bshard = _batch_specs(cfg, shape, mesh, opts)
    repl = NamedSharding(mesh, P())
    if opts.get("act_pin") or opts.get("head_pin"):
        hidden = (NamedSharding(mesh, P(da, None, None))
                  if opts.get("act_pin") else None)
        heads = (NamedSharding(mesh, P(da, None, "model", None))
                 if opts.get("head_pin") else None)
        act_ctx = lm.activation_pins(hidden=hidden, heads=heads)
    else:
        act_ctx = contextlib.nullcontext()

    if shape.kind == "train":
        opt_abs = jax.eval_shape(optim.adamw_init, params_abs)
        oshard = optim.AdamWState(step=repl,
                                  m=jax.tree.map(lambda s: s, pshard),
                                  v=jax.tree.map(lambda s: s, pshard))
        step = lm.make_train_step(cfg,
                                  vocab_blocks=opts.get("vocab_blocks", 0))
        fn = jax.jit(step, in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, repl),
                     donate_argnums=(0, 1))
        with act_ctx:
            return fn.lower(params_abs, opt_abs, batch_abs), cfg

    if shape.kind == "prefill":
        cache_dtype = jnp.bfloat16

        def run_prefill(p, b):
            return lm.prefill(cfg, p, b, cache_dtype=cache_dtype,
                              max_len=shape.seq_len)

        _, cshard = lm.cache_specs(cfg, mesh, shape.global_batch,
                                   shape.seq_len, cache_dtype,
                                   kv_shard=opts.get("kv_shard", "hd"))
        fn = jax.jit(run_prefill, in_shardings=(pshard, bshard),
                     out_shardings=(NamedSharding(mesh, P(da, "model")),
                                    cshard))
        with act_ctx:
            return fn.lower(params_abs, batch_abs), cfg

    # decode: one new token against a seq_len KV cache
    cache_abs, cshard = lm.cache_specs(cfg, mesh, shape.global_batch,
                                       shape.seq_len, jnp.bfloat16,
                                       kv_shard=opts.get("kv_shard", "hd"))

    def run_decode(p, c, t, pos):
        return lm.decode_step(cfg, p, c, t, pos)

    B = shape.global_batch
    logit_shard = NamedSharding(mesh, P(da if B > 1 else None, "model"))
    fn = jax.jit(run_decode,
                 in_shardings=(pshard, cshard, bshard["token"], repl),
                 out_shardings=(logit_shard, cshard),
                 donate_argnums=(1,))
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    with act_ctx:
        return fn.lower(params_abs, cache_abs, batch_abs["token"],
                        pos_abs), cfg


def _probe_costs(arch_id, shape_name, mesh, variant, k):
    """Compile the k-group unrolled clone; return (flops, bytes, coll)."""
    lowered, _ = lower_cell(arch_id, shape_name, mesh, variant, groups=k)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    hlo = compiled.as_text()
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)),
            rl.parse_collectives(hlo))


def _extrapolate(c1, c2, g):
    """linear-in-groups: cost(G) = c1 + (G-1) * (c2 - c1)."""
    return c1 + (g - 1) * (c2 - c1)


def run_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
             variant: str = "base", save_hlo: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    shape = SHAPES[shape_name]
    rec = {"arch": arch_id, "shape": shape_name, "variant": variant,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names),
           "chips": n_chips}
    t0 = time.time()
    with mesh:
        # 1) the production (scan) program: compile feasibility + memory
        lowered, cfg = lower_cell(arch_id, shape_name, mesh, variant)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

        # 2) two-point unrolled cost probe (see lower_cell docstring)
        g_full = cfg.n_layers // cfg.scan_period()
        t2 = time.time()
        f1, b1, coll1 = _probe_costs(arch_id, shape_name, mesh, variant, 1)
        if g_full > 1:
            f2, b2, coll2 = _probe_costs(arch_id, shape_name, mesh,
                                         variant, 2)
        else:
            f2, b2, coll2 = f1, b1, coll1
        rec["probe_s"] = round(time.time() - t2, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                rec[k] = int(v)
        rec["bytes_per_device"] = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))

    rec["layer_groups"] = g_full
    rec["flops_per_chip"] = _extrapolate(f1, f2, g_full)
    rec["bytes_per_chip"] = _extrapolate(b1, b2, g_full)
    coll = {}
    for kind in coll1:
        coll[kind] = {
            "count": int(_extrapolate(coll1[kind]["count"],
                                      coll2[kind]["count"], g_full)),
            "bytes": int(_extrapolate(coll1[kind]["bytes"],
                                      coll2[kind]["bytes"], g_full))}
    rec["collectives"] = coll
    rec["collective_bytes_per_chip"] = float(
        sum(v["bytes"] for v in coll.values()))
    hlo = compiled.as_text()
    rec.update(rl.roofline_terms(rec["flops_per_chip"],
                                 rec["bytes_per_chip"],
                                 rec["collective_bytes_per_chip"]))
    n_active = lm.active_param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind != "decode" else 1)
    rec["model_flops"] = rl.model_flops(shape.kind, n_active, tokens)
    total_hlo_flops = rec["flops_per_chip"] * n_chips
    rec["model_flops_ratio"] = (rec["model_flops"] / total_hlo_flops
                                if total_hlo_flops else 0.0)
    rec["params_total"] = lm.param_count(cfg)
    rec["params_active"] = n_active

    if save_hlo:
        os.makedirs(os.path.join(ARTIFACT_DIR, "hlo"), exist_ok=True)
        fn = os.path.join(ARTIFACT_DIR, "hlo",
                          f"{rec['mesh']}_{arch_id}_{shape_name}_"
                          f"{variant}.hlo.gz")
        with gzip.open(fn, "wt") as f:
            f.write(hlo)
        rec["hlo_path"] = fn
    return rec


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all",
                    help=f"one of {ARCH_IDS} or 'all'")
    ap.add_argument("--shape", default="all",
                    help=f"one of {list(SHAPES)} or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base", choices=list(VARIANTS))
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--out", default=None, help="artifact directory")
    args = ap.parse_args()

    out_dir = args.out or os.path.abspath(ARTIFACT_DIR)
    os.makedirs(out_dir, exist_ok=True)
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = 0
    for multi in meshes:
        for aid in archs:
            cfg = get_arch(aid)
            for sh in shapes:
                ok, why = cell_is_runnable(cfg, SHAPES[sh])
                tag = f"{'multi' if multi else 'single'}_{aid}_{sh}"
                if args.variant != "base":
                    tag += f"_{args.variant}"
                path = os.path.join(out_dir, tag + ".json")
                if not ok:
                    rec = {"arch": aid, "shape": sh, "variant": args.variant,
                           "mesh": "2x16x16" if multi else "16x16",
                           "skipped": True, "reason": why}
                    print(f"[dryrun] SKIP  {tag}: {why}")
                else:
                    print(f"[dryrun] CELL  {tag} ...", flush=True)
                    try:
                        rec = run_cell(aid, sh, multi_pod=multi,
                                       variant=args.variant,
                                       save_hlo=args.save_hlo)
                        print(f"[dryrun]   ok  lower={rec['lower_s']}s "
                              f"compile={rec['compile_s']}s "
                              f"flops/chip={rec['flops_per_chip']:.3e} "
                              f"coll B/chip="
                              f"{rec['collective_bytes_per_chip']:.3e} "
                              f"dominant={rec['dominant']}", flush=True)
                    except Exception as e:  # noqa: BLE001
                        failures += 1
                        rec = {"arch": aid, "shape": sh, "mesh": tag,
                               "variant": args.variant, "error": str(e),
                               "traceback": traceback.format_exc()}
                        print(f"[dryrun]   FAIL {e}", flush=True)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"[dryrun] done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()

"""Batched serving drivers.

Two entry modes:
  * ``--mode gbdt`` (default) — a thin CLI over the serving daemon
    (``repro.serving``): it publishes ``--models`` demo tenants into a
    :class:`ModelRegistry`, warms every reachable power-of-two flush
    bucket, then drives a mixed multi-model load of ragged request sizes
    through :class:`Server.submit` — with a mid-run hot-swap republishing
    tenant 0 at a new version.  All queueing, deadline batching, metric
    and retrace accounting lives in the daemon; the driver only
    generates traffic and prints the final ``stats()`` snapshot.  A warm
    server must show ZERO predict-cache retraces and ZERO dropped
    requests across the swap.  When no bundles exist under
    ``--model-dir`` small demo models are trained and saved first, so
    the driver is self-contained.
  * ``--mode lm --arch <id>`` — the assigned-architecture LM stack at
    smoke scale: one prefill, then jit'd single-token decode steps against
    the (ring-buffered where SWA) KV/SSM caches.  ``--no-greedy`` samples
    from the softmax at ``--temperature`` instead of argmax decoding.

    PYTHONPATH=src python -m repro.launch.serve --mode gbdt --batch 4096
    PYTHONPATH=src python -m repro.launch.serve --mode lm \
        --arch mixtral-8x22b --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import os
import time

import numpy as np
import jax
import jax.numpy as jnp


def request_sizes(batch: int):
    """The ragged request-size mix (real traffic) — ONE definition shared
    by the measured loop and the warmup-coverage check below, so the two
    can never drift apart (the pre-daemon driver derived them separately
    and the zero-retrace check could pass vacuously)."""
    return [max(1, batch), max(1, batch // 2), max(1, (3 * batch) // 4),
            max(1, batch // 3)]


def _demo_bundle(path: str, plan, task: str, seed: int, n_trees: int = 100,
                 learning_rate: float = 0.2) -> str:
    """Train + save a small demo tenant at ``path`` unless one exists."""
    from repro.api import BoosterClassifier, BoosterRegressor, make_tabular

    if os.path.isdir(path):
        return path
    print(f"[serve] no bundle at {path}; training demo model ({task})")
    X, y, cats = make_tabular(20_000, 20, 8, n_cats=12, task=task,
                              seed=seed)
    cls = BoosterClassifier if task == "binary" else BoosterRegressor
    est = cls(n_trees=n_trees, max_depth=6, learning_rate=learning_rate,
              max_bins=64, categorical_fields=cats, seed=seed)
    est.fit(X, y, plan=plan)
    est.save(path)
    return path


def run_gbdt(args):
    from repro.api import (ExecutionPlan, ModelRegistry, Server, load,
                           warmup_buckets)
    from repro.core.inference import ROW_BUCKET_FLOOR, bucket_pow2
    from repro.serving import (DeadlineExceededError, DispatcherCrashError,
                               QueueFullError)

    plan = ExecutionPlan.auto()
    registry = ModelRegistry(plan)
    tasks = ["binary", "regression"]
    names = []
    for i in range(max(1, args.models)):
        task = tasks[i % len(tasks)]
        name = f"m{i}_{task}"
        path = _demo_bundle(os.path.join(args.model_dir, name), plan,
                            task, seed=i)
        registry.publish(name, path)
        est = load(path)
        print(f"[serve] published {name} v1: {type(est).__name__} with "
              f"{est.n_trees_} trees")
        names.append(name)
    n_fields = registry.pipeline(names[0]).model.n_fields
    print(f"[serve] {plan.describe()}")

    sizes = request_sizes(args.batch)
    mb = args.microbatch or max(sizes)
    bounded = (args.max_queue_rows is not None
               or args.timeout_ms is not None)
    server = Server(registry, max_batch=mb,
                    default_slack_ms=args.slack_ms,
                    log_every_s=args.log_every_s,
                    max_queue_rows=args.max_queue_rows,
                    timeout_ms=args.timeout_ms)

    # every flush the daemon can assemble holds <= max_batch rows, so the
    # warmup bucket set is a strict SUPERSET of what the measured mix can
    # reach — assert that from the same helpers rather than trusting it
    reachable = {bucket_pow2(min(s, mb) if lo + mb >= s else mb,
                             ROW_BUCKET_FLOOR)
                 for s in sizes for lo in range(0, s, mb)}
    assert reachable <= set(warmup_buckets(mb)), (reachable, mb)
    for name in names:
        traces = server.warmup(name)
        print(f"[serve] warmed {name}: buckets {warmup_buckets(mb)} "
              f"({traces} traces)")
    warm_traces = {name: server.stats()[name]["traces"] for name in names}

    # the mixed multi-model measured loop, with a mid-run hot-swap: a new
    # version of tenant 0 (same tree count -> same shape buckets) lands
    # while requests are in flight; the daemon must drop nothing and
    # retrace nothing
    rng = np.random.default_rng(0)
    swap_at = args.requests // 2
    pending = []
    t_loop = time.perf_counter()
    for i in range(args.requests):
        if i == swap_at:
            v2 = _demo_bundle(os.path.join(args.model_dir,
                                           names[0] + "_v2"), plan,
                              tasks[0], seed=100, learning_rate=0.15)
            version = registry.publish(names[0], v2)
            print(f"[serve] hot-swapped {names[0]} -> v{version} mid-run")
        n_rows = sizes[i % len(sizes)]
        Xb = rng.normal(size=(n_rows, n_fields))
        Xb[rng.random(Xb.shape) < 0.02] = np.nan     # missing values
        pending.append(server.submit(names[i % len(names)], Xb))
    # zero SILENT drops: every submitted request must resolve — either
    # with rows or with one of the typed overload/crash failures
    served = total = 0
    typed = {"shed": 0, "deadline": 0, "crash": 0}
    for req in pending:
        try:
            req.result(timeout=600)
            served += 1
            total += req.n_rows
        except QueueFullError:
            typed["shed"] += 1
        except DeadlineExceededError:
            typed["deadline"] += 1
        except DispatcherCrashError:
            typed["crash"] += 1
    wall = time.perf_counter() - t_loop

    stats = server.stats()
    health = server.health()
    server.stop()
    print(f"[serve] sustained: {total / wall:.0f} records/s over "
          f"{args.requests} requests, {len(names)} models "
          f"(max_batch {mb}, slack {args.slack_ms} ms)")
    ok = True
    for name in names:
        s = stats[name]
        print(f"[serve]   {name} v{s['version']}: {s['requests']} req, "
              f"p50 {s['p50_ms']:.1f} ms, p99 {s['p99_ms']:.1f} ms, "
              f"fill {s['batch_fill']:.2f}, dropped {s['dropped']}, "
              f"shed {s['shed']}, expired {s['deadline_failures']}, "
              f"retraces after warmup {s['traces'] - warm_traces[name]}")
        ok &= s["traces"] == warm_traces[name]
        if not bounded:
            ok &= s["dropped"] == 0
    accounted = served + sum(typed.values())
    ok &= accounted == len(pending)
    print(f"[serve] health: alive={health.alive} ready={health.ready} "
          f"restarts={health.dispatcher_restarts} "
          f"typed_failures={health.failed_requests}")
    print(f"[serve] accounting: {served} served + {typed['shed']} shed + "
          f"{typed['deadline']} expired + {typed['crash']} crash-failed "
          f"= {accounted}/{len(pending)} (zero silent drops: "
          f"{'OK' if accounted == len(pending) else 'VIOLATED'})")
    print(f"[serve] zero retraces across hot-swap: "
          f"{'OK' if ok else 'UNEXPECTED'}")


def run_lm(args):
    from repro.configs import get_smoke
    from repro.models import lm

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, 4, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    logits, cache = lm.prefill(cfg, params, batch,
                               max_len=S + args.gen,
                               cache_dtype=jnp.float32)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    decode = jax.jit(functools.partial(lm.decode_step, cfg))
    key = jax.random.PRNGKey(args.seed)

    def pick(logits, key):
        """Greedy argmax, or temperature sampling with --no-greedy."""
        if args.greedy:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        scaled = logits / max(args.temperature, 1e-6)
        return jax.random.categorical(key, scaled, axis=-1).astype(
            jnp.int32)[:, None]

    key, sub = jax.random.split(key)
    tok = pick(logits, sub)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        key, sub = jax.random.split(key)
        tok = pick(logits, sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    mode = ("greedy" if args.greedy
            else f"sampled@T={args.temperature:g}")
    print(f"[serve] decoded {args.gen - 1} steps x {B} seqs ({mode}): "
          f"{t_dec*1e3:.1f} ms ({B*(args.gen-1)/t_dec:.0f} tok/s)")
    print(f"[serve] first sequence: {gen[0][:16].tolist()} ...")


def main():
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gbdt", choices=["gbdt", "lm"])
    # gbdt serving
    ap.add_argument("--model-dir", default="/tmp/repro_serve_bundle")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=0,
                    help="daemon flush capacity in rows (0 = the largest "
                         "request size)")
    ap.add_argument("--models", type=int, default=2,
                    help="demo tenants published into the registry")
    ap.add_argument("--max-queue-rows", type=int, default=None,
                    help="per-model queue bound; overload is shed with "
                         "typed QueueFullError futures (default unbounded)")
    ap.add_argument("--timeout-ms", type=float, default=None,
                    help="hard queue deadline; expired requests fail with "
                         "DeadlineExceededError (default none)")
    ap.add_argument("--slack-ms", type=float, default=20.0,
                    help="per-request deadline slack (queue-wait budget)")
    ap.add_argument("--log-every-s", type=float, default=None,
                    help="daemon stats log-line cadence (default: silent)")
    # lm serving
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=None,
                    help="records per request (gbdt, default 4096) or "
                         "sequences (lm, default 4)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    # BooleanOptionalAction: the old action="store_true", default=True
    # combination made --greedy a no-op (it could never be False)
    ap.add_argument("--greedy", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="argmax decoding; --no-greedy samples at "
                         "--temperature")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.batch is None:
        args.batch = 4096 if args.mode == "gbdt" else 4
    (run_gbdt if args.mode == "gbdt" else run_lm)(args)


if __name__ == "__main__":
    main()

"""Batched serving driver: prefill a batch of prompts, then decode.

Runs any --arch at smoke scale on CPU (full scale is exercised through
launch.dryrun's prefill/decode cells).  Demonstrates the production
serving loop: one prefill, then jit'd single-token decode steps against
the (ring-buffered where SWA) KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --arch mixtral-8x22b \
        --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import ARCH_IDS, get_smoke
from repro.models import lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, 4, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    logits, cache = lm.prefill(cfg, params, batch,
                               max_len=S + args.gen,
                               cache_dtype=jnp.float32)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    decode = jax.jit(functools.partial(lm.decode_step, cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] decoded {args.gen - 1} steps x {B} seqs: "
          f"{t_dec*1e3:.1f} ms ({B*(args.gen-1)/t_dec:.0f} tok/s)")
    print(f"[serve] first sequence: {gen[0][:16].tolist()} ...")


if __name__ == "__main__":
    main()

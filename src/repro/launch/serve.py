"""Batched serving drivers.

Two entry modes:
  * ``--mode gbdt`` (default) — the paper's workload: load a trained GBDT
    bundle through the unified ``repro.api`` serialization and stream
    record batches through ensemble inference (§III-D).  When no bundle
    exists at ``--model-dir`` a small demo model is trained and saved
    first, so the driver is self-contained.
  * ``--mode lm --arch <id>`` — the assigned-architecture LM stack at
    smoke scale: one prefill, then jit'd single-token decode steps against
    the (ring-buffered where SWA) KV/SSM caches.

    PYTHONPATH=src python -m repro.launch.serve --mode gbdt --batch 4096
    PYTHONPATH=src python -m repro.launch.serve --mode lm \
        --arch mixtral-8x22b --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import os
import time

import numpy as np
import jax
import jax.numpy as jnp


def run_gbdt(args):
    from repro.api import (BoosterClassifier, ExecutionPlan, load,
                           make_tabular)

    plan = ExecutionPlan.auto()
    if not os.path.isdir(args.model_dir):
        print(f"[serve] no bundle at {args.model_dir}; training demo model")
        X, y, cats = make_tabular(20_000, 20, 8, n_cats=12, task="binary",
                                  seed=0)
        est = BoosterClassifier(n_trees=100, max_depth=6, learning_rate=0.2,
                                max_bins=64, categorical_fields=cats)
        est.fit(X, y, plan=plan)
        est.save(args.model_dir)
    est = load(args.model_dir)
    print(f"[serve] loaded {type(est).__name__} with {est.n_trees_} trees "
          f"({plan.describe()})")

    # serving loop: raw NaN-carrying batches in, predictions out
    n_fields = est.model_.n_fields
    rng = np.random.default_rng(0)
    warm = rng.normal(size=(args.batch, n_fields))
    jax.block_until_ready(est.predict_margin(warm, plan=plan))  # compile

    total, t_total = 0, 0.0
    for i in range(args.requests):
        Xb = rng.normal(size=(args.batch, n_fields))
        Xb[rng.random(Xb.shape) < 0.02] = np.nan     # missing values
        t0 = time.perf_counter()
        out = np.asarray(est.predict(Xb, plan=plan))  # blocks: host labels
        dt = time.perf_counter() - t0
        total += args.batch
        t_total += dt
        print(f"[serve] request {i}: {args.batch} records in {dt*1e3:.1f} ms"
              f" ({args.batch/dt:.0f} rec/s)")
    print(f"[serve] sustained: {total/t_total:.0f} records/s "
          f"over {args.requests} requests")


def run_lm(args):
    from repro.configs import get_smoke
    from repro.models import lm

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, 4, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    logits, cache = lm.prefill(cfg, params, batch,
                               max_len=S + args.gen,
                               cache_dtype=jnp.float32)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    decode = jax.jit(functools.partial(lm.decode_step, cfg))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    print(f"[serve] decoded {args.gen - 1} steps x {B} seqs: "
          f"{t_dec*1e3:.1f} ms ({B*(args.gen-1)/t_dec:.0f} tok/s)")
    print(f"[serve] first sequence: {gen[0][:16].tolist()} ...")


def main():
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gbdt", choices=["gbdt", "lm"])
    # gbdt serving
    ap.add_argument("--model-dir", default="/tmp/repro_serve_bundle")
    ap.add_argument("--requests", type=int, default=8)
    # lm serving
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=None,
                    help="records per request (gbdt, default 4096) or "
                         "sequences (lm, default 4)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--greedy", action="store_true", default=True)
    args = ap.parse_args()
    if args.batch is None:
        args.batch = 4096 if args.mode == "gbdt" else 4
    (run_gbdt if args.mode == "gbdt" else run_lm)(args)


if __name__ == "__main__":
    main()

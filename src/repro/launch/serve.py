"""Batched serving drivers.

Two entry modes:
  * ``--mode gbdt`` (default) — the paper's workload: load a trained GBDT
    bundle through the unified ``repro.api`` serialization and stream
    record batches through the compile-once inference engine (§III-D).
    Request sizes VARY across the loop (real traffic is ragged) to
    exercise the engine's power-of-two shape buckets; requests larger
    than ``--microbatch`` are chopped into micro-batches so tail latency
    stays bounded.  The driver reports p50/p99 request latency alongside
    sustained rows/sec, plus the predict-cache retrace count — a warm
    server must show ZERO retraces after the first request per bucket.
    When no bundle exists at ``--model-dir`` a small demo model is
    trained and saved first, so the driver is self-contained.
  * ``--mode lm --arch <id>`` — the assigned-architecture LM stack at
    smoke scale: one prefill, then jit'd single-token decode steps against
    the (ring-buffered where SWA) KV/SSM caches.  ``--no-greedy`` samples
    from the softmax at ``--temperature`` instead of argmax decoding.

    PYTHONPATH=src python -m repro.launch.serve --mode gbdt --batch 4096
    PYTHONPATH=src python -m repro.launch.serve --mode lm \
        --arch mixtral-8x22b --batch 4 --prompt-len 32 --gen 32
"""
from __future__ import annotations

import argparse
import functools
import os
import time

import numpy as np
import jax
import jax.numpy as jnp


def run_gbdt(args):
    from repro.api import (BoosterClassifier, ExecutionPlan, load,
                           make_tabular)
    from repro.core.inference import predict_cache_stats

    plan = ExecutionPlan.auto()
    if not os.path.isdir(args.model_dir):
        print(f"[serve] no bundle at {args.model_dir}; training demo model")
        X, y, cats = make_tabular(20_000, 20, 8, n_cats=12, task="binary",
                                  seed=0)
        est = BoosterClassifier(n_trees=100, max_depth=6, learning_rate=0.2,
                                max_bins=64, categorical_fields=cats)
        est.fit(X, y, plan=plan)
        est.save(args.model_dir)
    est = load(args.model_dir)
    print(f"[serve] loaded {type(est).__name__} with {est.n_trees_} trees "
          f"({plan.describe()})")

    # ragged request sizes (real traffic) — the engine's power-of-two
    # buckets mean each DISTINCT bucket compiles once, then never again
    n_fields = est.model_.n_fields
    rng = np.random.default_rng(0)
    sizes = [max(1, args.batch), max(1, args.batch // 2),
             max(1, (3 * args.batch) // 4), max(1, args.batch // 3)]
    mb = args.microbatch or max(sizes)

    def request(n_rows):
        """One request, served in <= --microbatch slices."""
        Xb = rng.normal(size=(n_rows, n_fields))
        Xb[rng.random(Xb.shape) < 0.02] = np.nan     # missing values
        t0 = time.perf_counter()
        parts = [np.asarray(est.predict(Xb[lo:lo + mb], plan=plan))
                 for lo in range(0, n_rows, mb)]      # blocks: host labels
        np.concatenate(parts)
        return time.perf_counter() - t0

    # warm every micro-batch slice length once (micro-batching chops a
    # request into mb-sized slices plus a ragged tail — each lands in its
    # own pad bucket), then the measured loop must not trace anything new
    for sl in sorted({min(mb, s - lo)
                      for s in sizes for lo in range(0, s, mb)}):
        request(sl)
    warm_traces = predict_cache_stats()["traces"]

    lat, total = [], 0
    for i in range(args.requests):
        n_rows = sizes[i % len(sizes)]
        dt = request(n_rows)
        lat.append(dt)
        total += n_rows
        print(f"[serve] request {i}: {n_rows} records in {dt*1e3:.1f} ms"
              f" ({n_rows/dt:.0f} rec/s)")
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    retraces = predict_cache_stats()["traces"] - warm_traces
    print(f"[serve] sustained: {total/sum(lat):.0f} records/s over "
          f"{args.requests} requests (micro-batch {mb}); "
          f"p50 {p50:.1f} ms, p99 {p99:.1f} ms")
    print(f"[serve] predict-cache retraces after warmup: {retraces}"
          f" {'(OK)' if retraces == 0 else '(UNEXPECTED)'}")


def run_lm(args):
    from repro.configs import get_smoke
    from repro.models import lm

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    B, S = args.batch, args.prompt_len

    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)),
                                   jnp.int32)}
    if cfg.mrope:
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S)).astype(jnp.int32)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.zeros((B, 4, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["audio_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.d_model)), jnp.float32)

    t0 = time.perf_counter()
    logits, cache = lm.prefill(cfg, params, batch,
                               max_len=S + args.gen,
                               cache_dtype=jnp.float32)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0
    print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f} ms "
          f"({B*S/t_prefill:.0f} tok/s)")

    decode = jax.jit(functools.partial(lm.decode_step, cfg))
    key = jax.random.PRNGKey(args.seed)

    def pick(logits, key):
        """Greedy argmax, or temperature sampling with --no-greedy."""
        if args.greedy:
            return jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        scaled = logits / max(args.temperature, 1e-6)
        return jax.random.categorical(key, scaled, axis=-1).astype(
            jnp.int32)[:, None]

    key, sub = jax.random.split(key)
    tok = pick(logits, sub)
    out_tokens = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen - 1):
        logits, cache = decode(params, cache, tok,
                               jnp.asarray(S + i, jnp.int32))
        key, sub = jax.random.split(key)
        tok = pick(logits, sub)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.perf_counter() - t0
    gen = np.concatenate([np.asarray(t) for t in out_tokens], axis=1)
    mode = ("greedy" if args.greedy
            else f"sampled@T={args.temperature:g}")
    print(f"[serve] decoded {args.gen - 1} steps x {B} seqs ({mode}): "
          f"{t_dec*1e3:.1f} ms ({B*(args.gen-1)/t_dec:.0f} tok/s)")
    print(f"[serve] first sequence: {gen[0][:16].tolist()} ...")


def main():
    from repro.configs import ARCH_IDS

    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gbdt", choices=["gbdt", "lm"])
    # gbdt serving
    ap.add_argument("--model-dir", default="/tmp/repro_serve_bundle")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--microbatch", type=int, default=0,
                    help="rows per inference micro-batch (0 = whole "
                         "request in one dispatch)")
    # lm serving
    ap.add_argument("--arch", default="qwen3-14b", choices=ARCH_IDS)
    ap.add_argument("--batch", type=int, default=None,
                    help="records per request (gbdt, default 4096) or "
                         "sequences (lm, default 4)")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    # BooleanOptionalAction: the old action="store_true", default=True
    # combination made --greedy a no-op (it could never be False)
    ap.add_argument("--greedy", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="argmax decoding; --no-greedy samples at "
                         "--temperature")
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.batch is None:
        args.batch = 4096 if args.mode == "gbdt" else 4
    (run_gbdt if args.mode == "gbdt" else run_lm)(args)


if __name__ == "__main__":
    main()

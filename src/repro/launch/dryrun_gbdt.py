import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")
# ^^ first lines: jax locks the device count on first init.

import argparse      # noqa: E402
import json          # noqa: E402
import time          # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np   # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.distributed.sharding import (distributed_fit_tree,  # noqa: E402
                                        gbdt_shardings)
from repro.core import tree as tree_mod  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402

"""GBDT-at-scale dry-run — the paper's own workload on the production mesh.

Lowers one full level-wise tree build (steps ①–④ over depth 6) for a
Terabyte-Click-Log-scale dataset (200M records x 64 fields, the paper's
motivating scale, §IV) across 256/512 chips: records sharded over the data
axes, fields + histogram slabs over "model" (group-by-field at chip
granularity).  The only cross-chip traffic is the per-level histogram psum
+ the tiny step-② argmax combine — exactly the paper's cluster reduction.

Variants:
  base          — unmodified grower; GSPMD infers the collectives
  explicit      — shard_map schedule: local hist -> psum(data axes) with
                  field-sharded (group-by-field) outputs + tiny step-②
                  argmax combine
  explicit_bf16 — explicit schedule with the histogram reduction cast to
                  bf16 (gradient compression: halves the only cross-pod
                  collective; split agreement 100% on test data,
                  leaf values to ~1e-7 — EXPERIMENTS.md §Perf).
"""

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                            "artifacts", "dryrun")


def lower_gbdt(mesh, *, n_records: int, n_fields: int, n_bins: int,
               depth: int, variant: str):
    sh = gbdt_shardings(mesh)

    def build(codes, codes_cm, g, h, iscat, fmask):
        if variant == "base":       # GSPMD-inferred schedule
            return tree_mod.fit_tree(
                codes, codes_cm, g, h, depth=depth, n_bins=n_bins,
                missing_bin=n_bins - 1, is_cat_field=iscat,
                field_mask=fmask, lambda_=1.0, gamma=0.0,
                min_child_weight=1.0, hist_strategy="scatter",
                partition_strategy="reference")
        # explicit shard_map schedule (group-by-field psum); optional bf16
        # compression of the histogram reduction
        hd = jnp.bfloat16 if "bf16" in variant else None
        bits = "bits" in variant
        return distributed_fit_tree(
            mesh, codes, codes_cm, g, h, depth=depth, n_bins=n_bins,
            missing_bin=n_bins - 1, is_cat_field=iscat, field_mask=fmask,
            lambda_=1.0, gamma=0.0, min_child_weight=1.0,
            hist_strategy="scatter", hist_dtype=hd, partition_bits=bits)

    sds = jax.ShapeDtypeStruct
    args = (sds((n_records, n_fields), jnp.uint8),
            sds((n_fields, n_records), jnp.uint8),
            sds((n_records,), jnp.float32),
            sds((n_records,), jnp.float32),
            sds((n_fields,), jnp.bool_),
            sds((n_fields,), jnp.bool_))
    fn = jax.jit(build,
                 in_shardings=(sh["codes"], sh["codes_cm"],
                               sh["per_record"], sh["per_record"],
                               sh["replicated"], sh["replicated"]),
                 out_shardings=NamedSharding(mesh, P()))
    return fn.lower(*args)


def run(multi_pod: bool, variant: str, n_records: int, n_fields: int,
        n_bins: int, depth: int) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = int(np.prod(list(mesh.shape.values())))
    rec = {"arch": "gbdt-booster", "shape": f"fit_tree_{n_records}x{n_fields}",
           "variant": variant, "chips": n_chips,
           "mesh": "x".join(str(mesh.shape[a]) for a in mesh.axis_names)}
    t0 = time.time()
    with mesh:
        lowered = lower_gbdt(mesh, n_records=n_records, n_fields=n_fields,
                             n_bins=n_bins, depth=depth, variant=variant)
        rec["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)
    mem = compiled.memory_analysis()
    if mem is not None:
        rec["bytes_per_device"] = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))
    cost = compiled.cost_analysis() or {}
    rec["flops_per_chip"] = float(cost.get("flops", 0.0))
    rec["bytes_per_chip"] = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    rec["collectives"] = rl.parse_collectives(hlo)
    rec["collective_bytes_per_chip"] = rl.collective_bytes(hlo)
    rec.update(rl.roofline_terms(rec["flops_per_chip"],
                                 rec["bytes_per_chip"],
                                 rec["collective_bytes_per_chip"]))
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--records", type=int, default=200_000_000)
    ap.add_argument("--fields", type=int, default=64)
    ap.add_argument("--bins", type=int, default=256)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--variant", default="base",
                    choices=["base", "explicit", "explicit_bf16",
                             "explicit_bits", "explicit_bits_bf16"])
    args = ap.parse_args()
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    for multi in meshes:
        tag = f"{'multi' if multi else 'single'}_gbdt_{args.variant}"
        print(f"[gbdt-dryrun] {tag} ...", flush=True)
        rec = run(multi, args.variant, args.records, args.fields,
                  args.bins, args.depth)
        with open(os.path.join(ARTIFACT_DIR, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        print(f"[gbdt-dryrun]   ok compile={rec['compile_s']}s "
              f"dominant={rec['dominant']} "
              f"coll/chip={rec['collective_bytes_per_chip']:.3e}B "
              f"mem={rec['memory_s']:.3f}s", flush=True)


if __name__ == "__main__":
    main()

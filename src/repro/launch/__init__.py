"""Entry points: training/serving launchers, dry-run + roofline reports."""

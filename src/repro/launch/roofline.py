"""Roofline-term extraction from compiled dry-run artifacts.

Targets TPU v5e:  197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI.  The compiled program produced by the SPMD partitioner is the
*per-chip* program, so cost_analysis() FLOPs/bytes and the collective
operand sizes parsed from the optimized HLO are per-chip quantities:

  compute term    = flops_per_chip / PEAK_FLOPS
  memory term     = bytes_per_chip / HBM_BW
  collective term = collective_bytes_per_chip / LINK_BW
                    (== total_collective_bytes / (chips x link_bw))

Per-op traffic convention: bytes of the op *result* (per-chip shapes),
doubled for all-reduce (reduce + broadcast phases of a ring).  Async
``-start``/``-done`` pairs are counted once.
"""
from __future__ import annotations

import re
from typing import Dict, List

PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
LINK_BW = 50e9             # bytes/s / chip (ICI)

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s+(?P<result>\(.*?\)|\S+?)\s+"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<async>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(result: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(result):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def parse_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, bytes} from optimized (post-SPMD) HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        if m.group("async") == "-done":
            continue  # paired with its -start
        kind = m.group("op")
        b = _shape_bytes(m.group("result"))
        if kind == "all-reduce":
            b *= 2  # reduce + broadcast phases
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
    return out


def collective_bytes(hlo_text: str) -> int:
    return int(sum(v["bytes"] for v in parse_collectives(hlo_text).values()))


def roofline_terms(flops_per_chip: float, bytes_per_chip: float,
                   coll_bytes_per_chip: float) -> Dict[str, float]:
    terms = {
        "compute_s": flops_per_chip / PEAK_FLOPS,
        "memory_s": bytes_per_chip / HBM_BW,
        "collective_s": coll_bytes_per_chip / LINK_BW,
    }
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    terms["dominant"] = dom.replace("_s", "")
    # roofline fraction: how much of the bound is the useful compute term
    terms["roofline_fraction"] = (terms["compute_s"] / bound
                                  if bound > 0 else 0.0)
    return terms


def model_flops(kind: str, n_params_active: int, tokens: int) -> float:
    """6ND for training (fwd+bwd), 2ND for inference passes."""
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_params_active * tokens


def format_table(rows: List[Dict], keys: List[str]) -> str:
    widths = [max(len(k), *(len(str(r.get(k, ""))) for r in rows))
              for k in keys]
    lines = [" | ".join(k.ljust(w) for k, w in zip(keys, widths)),
             "-|-".join("-" * w for w in widths)]
    for r in rows:
        lines.append(" | ".join(str(r.get(k, "")).ljust(w)
                                for k, w in zip(keys, widths)))
    return "\n".join(lines)

"""Production training launcher.

Two entry modes:
  * ``--mode gbdt``  (default) — the paper's workload: distributed GBDT
    training with checkpoint/restart and journaling.
  * ``--mode lm --arch <id>``  — the assigned-architecture LM stack at
    smoke scale (full scale is exercised via launch.dryrun).

Run under a real multi-host TPU runtime this driver would be started once
per host (jax.distributed.initialize); on this container it runs single
process.  Mesh construction, shardings, checkpoint cadence and recovery
are identical in both settings.
"""
from __future__ import annotations

import argparse
import os

import numpy as np
import jax
import jax.numpy as jnp


def run_gbdt(args):
    from repro.api import (BoosterClassifier, BoosterRegressor,
                           ExecutionPlan, GracefulShutdown, RecoveryPolicy,
                           TrainingInterrupted, paper_dataset)
    from repro.api import serialize
    from repro.distributed.fault import StepJournal
    from repro.launch.mesh import make_mesh

    if args.resume and not serialize.has_checkpoint(args.ckpt_dir):
        raise SystemExit(f"--resume: no checkpoint found under "
                         f"{args.ckpt_dir!r} — nothing to resume from")

    X, y, cats, spec = paper_dataset(args.dataset,
                                     n_override=args.records)
    klass = BoosterClassifier if spec.task == "binary" else BoosterRegressor
    est = klass(n_trees=args.trees, max_depth=args.depth,
                learning_rate=args.lr, max_bins=args.max_bins,
                categorical_fields=cats, seed=args.seed)
    journal = StepJournal(os.path.join(args.ckpt_dir, "journal.jsonl"))

    def cb(t_idx, model):
        if (t_idx + 1) % args.ckpt_every == 0:
            journal.append(t_idx, {})

    # --data-shards N shards records over an N-way ("data",) mesh and the
    # fit runs through the distributed engine (per-shard histograms + one
    # psum per level); N must divide the available device count
    mesh = None
    if args.data_shards > 1:
        if args.stream:
            raise SystemExit("--stream (out-of-core) and --data-shards "
                             "(in-memory distributed) cannot combine")
        n_dev = len(jax.devices())
        if args.data_shards > n_dev:
            raise SystemExit(
                f"--data-shards {args.data_shards} exceeds the "
                f"{n_dev} visible devices (set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N to emulate)")
        mesh = make_mesh((args.data_shards,), ("data",),
                         devices=jax.devices()[:args.data_shards])

    plan = ExecutionPlan.auto(hist_strategy=args.strategy)
    recovery = RecoveryPolicy(checkpoint_dir=args.ckpt_dir,
                              checkpoint_every=args.ckpt_every)
    source = None
    # SIGTERM/SIGINT finish the in-flight round, commit it atomically and
    # surface a typed, resumable TrainingInterrupted; a later run with
    # --resume restores from the committed checkpoint and grows only the
    # remaining trees — identical final ensemble (deterministic replay)
    try:
        with GracefulShutdown() as sd:
            if args.stream:
                # resilient out-of-core path: stage the dataset once as
                # crc32-manifested npz shards, stream it back through a
                # self-healing RetryingSource, and fit under a
                # RecoveryPolicy — transient mid-round failures replay
                # from the newest checkpoint, device OOM degrades the
                # chunk size instead of dying
                from repro.api import (ArraySource, NpzShardSource,
                                       RetryPolicy, RetryingSource,
                                       write_npz_shards)
                shard_dir = os.path.join(args.ckpt_dir, "shards")
                write_npz_shards(shard_dir, ArraySource(X, y),
                                 rows_per_shard=max(1024,
                                                    args.records // 8))
                source = RetryingSource(NpzShardSource(shard_dir),
                                        RetryPolicy(chunk_timeout_s=60.0))
                est.fit(data=source, plan=plan,
                        checkpoint_dir=args.ckpt_dir,
                        checkpoint_every=args.ckpt_every, callback=cb,
                        verbose=True, recovery=recovery, shutdown=sd)
            else:
                # checkpoint_dir resumes from the newest valid step and
                # keeps writing atomic, sha-verified bundles every
                # --ckpt-every trees; the recovery policy arms divergence
                # sentinels and (with mesh) preemption/OOM self-healing
                est.fit(X, y, plan=plan, mesh=mesh,
                        checkpoint_dir=args.ckpt_dir,
                        checkpoint_every=args.ckpt_every,
                        callback=cb, verbose=True, recovery=recovery,
                        shutdown=sd)
    except TrainingInterrupted as stop:
        print(f"[train] interrupted ({stop.signal_name}) after "
              f"{stop.rounds_done} committed rounds; checkpoint in "
              f"{stop.checkpoint_dir or args.ckpt_dir} — rerun with "
              f"--resume to finish the remaining trees")
        raise SystemExit(75)  # EX_TEMPFAIL: resumable, not a failure
    loss = est.history_.get("train_loss") or [float("nan")]
    shards = est.stats_.get("n_shards", 1)
    print(f"[train] done: {est.n_trees_} trees, loss {loss[-1]:.5f}, "
          f"shards {shards}")
    if args.stream:
        st = est.stats_
        print(f"[train] resilience: {st.get('recoveries', 0)} recoveries, "
              f"{st.get('oom_halvings', 0)} OOM halvings, "
              f"{source.stats['retries']} source retries "
              f"(chunk_rows {st.get('chunk_rows')})")


def run_lm(args):
    from repro.configs import get_smoke
    from repro.models import lm, optim

    cfg = get_smoke(args.arch)
    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = optim.adamw_init(params)
    step = jax.jit(lm.make_train_step(cfg, base_lr=args.lr or 3e-3,
                                      warmup=20, total_steps=args.trees))
    rng = np.random.default_rng(args.seed)
    for i in range(args.trees):
        seqs = rng.integers(0, cfg.vocab, (8, 33))
        batch = {"tokens": jnp.asarray(seqs[:, :-1], jnp.int32),
                 "labels": jnp.asarray(seqs[:, 1:], jnp.int32)}
        if cfg.mrope:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(32)[None, None], (3, 8, 32)).astype(jnp.int32)
        if cfg.family == "vlm":
            batch["patch_embeds"] = jnp.zeros((8, 4, cfg.d_model))
        if cfg.family == "encdec":
            batch["audio_embeds"] = jnp.zeros(
                (8, cfg.frontend_len, cfg.d_model))
        params, opt, m = step(params, opt, batch)
        if i % 20 == 0:
            print(f"[lm] step {i} loss {float(m['loss']):.4f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="gbdt", choices=["gbdt", "lm"])
    ap.add_argument("--dataset", default="higgs")
    ap.add_argument("--arch", default="qwen3-14b")
    ap.add_argument("--records", type=int, default=20_000)
    ap.add_argument("--trees", type=int, default=100,
                    help="boosting rounds (gbdt) or steps (lm)")
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--lr", type=float, default=0.1)
    ap.add_argument("--max-bins", type=int, default=128)
    ap.add_argument("--strategy", default="auto")
    ap.add_argument("--data-shards", type=int, default=1,
                    help="data-parallel shards for distributed GBDT "
                         "training (1 = single device)")
    ap.add_argument("--stream", action="store_true",
                    help="resilient out-of-core path: stage checksummed "
                         "npz shards, stream through RetryingSource and "
                         "auto-recover mid-round failures from checkpoints")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true",
                    help="continue an interrupted fit from the newest "
                         "checkpoint under --ckpt-dir (fails if none "
                         "exists); the finished ensemble is identical to "
                         "an uninterrupted run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    (run_gbdt if args.mode == "gbdt" else run_lm)(args)


if __name__ == "__main__":
    main()

"""Version-compat shims over moving JAX APIs.

``shard_map`` graduated from ``jax.experimental.shard_map`` (kwarg
``check_rep``) to ``jax.shard_map`` (kwarg ``check_vma``); this repo runs
on either.  All callers import :func:`shard_map` from here and use the
*new* keyword name ``check_vma`` — the shim translates for the
experimental signature.
"""
from __future__ import annotations

import functools

import jax

__all__ = ["shard_map"]

if hasattr(jax, "shard_map"):
    shard_map = jax.shard_map
else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    @functools.wraps(_shard_map_exp)
    def shard_map(f, /, *, mesh, in_specs, out_specs, check_vma=True,
                  **kwargs):
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma,
                              **kwargs)

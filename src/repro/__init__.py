"""repro — a JAX/Pallas reproduction of the Booster GBDT accelerator.

Regular package marker (required for ``pip install .`` discovery); the
public entry point is :mod:`repro.api`.
"""

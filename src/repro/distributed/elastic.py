"""Elastic scaling: re-mesh live training state onto a changed device set.

A shrink (node loss) or grow (capacity arrival) event produces a new device
list; we rebuild the largest usable (data x model) mesh and re-place both
the dataset shards and the model state with ``device_put`` — JAX global
arrays make the re-shard a single collective-free relayout (host-mediated
here, ICI/DCN-mediated on real hardware).  Checkpoints are mesh-agnostic
(see ``checkpoint.py``), so shrink→restore→grow round-trips are exact.
"""
from __future__ import annotations

from typing import Any, List, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch.mesh import make_mesh


def largest_mesh_shape(n_devices: int, model_parallel: int
                       ) -> Tuple[int, int]:
    """Largest (data, model) grid using ≤ n_devices with fixed model width.

    Model parallelism is dictated by the workload (field/TP sharding), so
    elasticity moves along the data axis — drop to the largest multiple.
    """
    if n_devices < model_parallel:
        raise ValueError(
            f"need ≥ {model_parallel} devices for model_parallel="
            f"{model_parallel}, have {n_devices}")
    return n_devices // model_parallel, model_parallel


def remesh(devices: Sequence, model_parallel: int) -> Mesh:
    """Build the largest (data, model) mesh from the surviving devices."""
    d, m = largest_mesh_shape(len(devices), model_parallel)
    return make_mesh((d, m), ("data", "model"), devices=list(devices)[: d * m])


def reshard_tree(state: Any, shardings: Any) -> Any:
    """Relayout a pytree onto new shardings (same structure or single)."""
    if jax.tree_util.tree_structure(shardings) == \
            jax.tree_util.tree_structure(state):
        return jax.tree.map(jax.device_put, state, shardings)
    return jax.tree.map(lambda x: jax.device_put(x, shardings), state)


class ElasticContext:
    """Tracks the live mesh; ``resize`` re-places registered state.

    Usage:
        ctx = ElasticContext(model_parallel=2)
        mesh = ctx.mesh
        ...
        mesh = ctx.resize(surviving_devices)      # after a failure
        data = ctx.reshard_dataset(data)          # re-place inputs
    """

    def __init__(self, model_parallel: int,
                 devices: Optional[List] = None):
        self.model_parallel = model_parallel
        self.devices = list(devices) if devices else list(jax.devices())
        self.mesh = remesh(self.devices, model_parallel)

    def resize(self, devices: Sequence) -> Mesh:
        self.devices = list(devices)
        self.mesh = remesh(self.devices, self.model_parallel)
        return self.mesh

    def sharding(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

"""Data-parallel distributed GBDT training with elastic fault tolerance.

The paper's §III-B decomposition, wired into an actual fit: records are
partitioned across the mesh's data axes, each shard runs the class-batched
histogram kernel over its local records, and the per-shard histograms are
reduced with ONE psum at the end of step ① per level — O(nodes·F·bins)
bytes per level crossing the interconnect instead of the record stream.
Everything downstream of the reduction (step ② split decisions, the tree
tables) is replicated math on the psum'd histogram, so every shard grows
the *same* tree; step ③ partitions each shard's records locally.

A whole boosting round stays on-device per host (the ``fused_rounds``
semantics): gradients, the per-round stochastic filters, the sharded
grower, leaf shrinkage, the margin refresh and the loss reduction compile
into one jitted step dispatched once per round.

Determinism contract (see docs/api.md "Distributed training"):

  * the per-round RNG stream is ``fold_in(PRNGKey(seed), round)`` and all
    stochastic filters (GOSS, subsample, colsample) are computed on the
    GLOBAL statistics before sharding — the draws are identical for any
    shard count, so tree *structure* differences across meshes can only
    come from float reassociation in the histogram psum;
  * D=1 is bit-equal to the single-device trainer (padding rows carry
    zero statistics, contributing exactly +0.0);
  * for D>1 every histogram cell is a psum of per-shard partial sums —
    exact whenever the per-cell sums are exactly representable (integer
    counts always; dyadic gradient values too), otherwise within the
    documented float tolerance.

Elasticity and fault tolerance (``DistributedConfig``): a worker failure
mid-round surfaces as an exception from the round dispatch; recovery
re-meshes onto the surviving devices, restores the newest
``checkpoint.save_named`` step and deterministically replays the in-flight
tree — the fit never restarts.  A grow event (devices arriving) re-meshes
back up between rounds; training state is mesh-agnostic so a re-mesh is a
re-placement, not a restore.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api.plan import ExecutionPlan
from repro.compat import shard_map
from repro.core import gbdt as gbdt_mod
from repro.core import losses as losses_mod
from repro.core import splits as splits_mod
from repro.core import tree as tree_mod
from repro.core.binning import BinnedDataset, PackedCodes
from repro.core.gbdt import (GBDTConfig, GBDTModel, TrainResult, _as_model,
                             _round_stats, _unstack_forests,
                             model_from_meta)
from repro.distributed import checkpoint as ckpt
from repro.distributed.sharding import padded_record_count
from repro.kernels import ops
from repro.kernels.ref import TreeArrays
from repro.launch.mesh import data_axes, make_mesh, n_data_shards
from repro.resilience import metrics as _metrics
from repro.resilience.errors import (NumericalDivergenceError, Preemption,
                                     TrainingInterrupted)
from repro.resilience.recovery import RecoveryPolicy, classify
from repro.resilience.shutdown import GracefulShutdown


@dataclasses.dataclass
class DistributedConfig:
    """Elasticity / fault-tolerance policy for :func:`train_distributed`.

    checkpoint_dir:     where ``checkpoint.save_named`` steps land; None
                        disables checkpointing (a failure then replays the
                        whole fit from round 0 on the surviving devices)
    checkpoint_every:   save cadence in completed rounds
    keep_last:          checkpoint GC horizon
    max_restarts:       failures tolerated before the exception propagates
    fault_injector:     any object with ``check(round)`` raising to
                        simulate a worker loss (``fault.FaultInjector``);
                        checked after the round dispatch, before commit —
                        the in-flight tree is the one replayed
    fault_schedule:     a :class:`repro.resilience.FaultSchedule` driving
                        chaos at the trainer's named sites: ``"round"``
                        fires after the round dispatch before commit
                        (same spot as ``fault_injector``, which it
                        generalizes), ``"elastic"`` fires just before the
                        between-round device poll
    available_devices:  optional ``round -> device list`` callable polled
                        between rounds; a changed list re-meshes the fit
                        up or down (elastic grow/shrink without failure)
    survivors:          maps the failed mesh's device list to the
                        surviving one; default drops the last device
                        (keeps the mesh when only one device remains)
    """

    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 25
    keep_last: int = 3
    max_restarts: int = 2
    fault_injector: Optional[object] = None
    fault_schedule: Optional[object] = None
    available_devices: Optional[Callable[[int], Sequence]] = None
    survivors: Optional[Callable[[Sequence], Sequence]] = None


def data_parallel_mesh(devices: Optional[Sequence] = None) -> Mesh:
    """A ``("data",)`` mesh over ``devices`` (default: every device)."""
    devs = list(devices) if devices is not None else list(jax.devices())
    return make_mesh((len(devs),), ("data",), devices=devs)


def _check_data_parallel(mesh: Mesh) -> Tuple[str, ...]:
    """The trainer shards records only; a real model axis is not supported."""
    da = data_axes(mesh)
    if "model" in mesh.axis_names and mesh.shape["model"] != 1:
        raise ValueError(
            "train_distributed is data-parallel: the mesh's 'model' axis "
            f"must have size 1, got {mesh.shape['model']} (use "
            "distributed_fit_tree for field sharding)")
    if not da:
        raise ValueError("mesh has no data axes to shard records over")
    return da


def _trainer_kernel_plan(plan: ExecutionPlan) -> ExecutionPlan:
    """The plan the kernels inside the sharded step see: mesh/data-axis
    routing stripped (the step IS the mesh program), chunking dropped from
    the jit key, partition pinned to the reference kernel (the Pallas
    partition path is untested inside shard_map), and the step-② host
    offload disabled (a host round-trip cannot live inside one jit)."""
    return plan.replace(mesh=None, data_axes=None, chunk_bytes=None,
                        partition_strategy="reference",
                        host_offload_split=False).resolved()


# --------------------------------------------------------------------------
# the sharded grower: per-shard histograms + one psum per level
# --------------------------------------------------------------------------
def _grow_forest_sharded(mesh: Mesh, da: Tuple[str, ...], *, depth: int,
                         n_bins: int, lambda_: float, gamma: float,
                         min_child_weight: float, plan: ExecutionPlan,
                         cm_packed: bool = False, hist_slices: int = 1):
    """Build the shard_map'd level-wise grower for ``mesh``.

    Returns ``fn(codes, codes_cm, g2, h2, is_cat_field, field_mask) ->
    (TreeArrays with (K, ...) axes, node_ids (K, n_pad))`` where codes is
    (n_pad, F) sharded over the data axes — a plain uint8 matrix or a
    :class:`PackedCodes` (its record axis shards cleanly; the histogram
    dispatch unpacks or consumes nibbles per strategy) — codes_cm its
    (F, n_pad) column-major copy, and g2/h2 the (K, n_pad) per-class
    statistics (padding rows MUST carry zero stats).  With
    ``cm_packed`` the column-major operand arrives as RAW nibble-packed
    bytes (F, n_pad // 2): the record axis is the packed axis, so it is
    shipped as bytes (half the cross-shard placement traffic), sharded
    on whole bytes (``_place_dataset`` pads records so every shard gets
    an even count), and only the <= 2^level gathered split rows are
    unpacked per level inside the local function.  The returned node ids
    are the records' final bottom-leaf slots — step ⑤ is a leaf-value
    lookup, no traversal pass (the streaming trainer's trick, reused
    verbatim).

    ``hist_slices`` is the device-OOM degradation knob: each shard's
    step-① accumulation is split into that many record sub-batches,
    accumulated sequentially so only one sub-batch's scatter
    intermediates are live at a time (the distributed analog of the
    streaming trainer's chunk-rows halving).  Zero-stat padding rows
    contribute exactly +0.0 per cell, so a degraded round reproduces the
    undegraded histogram by the same split-invariance argument the
    streaming accumulation relies on.
    """
    missing_bin = n_bins - 1
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth

    def local(codes_l, codes_cm_l, g_l, h_l, is_cat_field, field_mask):
        K, n_loc = g_l.shape
        state = (jnp.full((K, n_int), -1, jnp.int32),      # feature
                 jnp.zeros((K, n_int), jnp.int32),         # threshold
                 jnp.zeros((K, n_int), jnp.int32),         # is_cat
                 jnp.zeros((K, n_int), jnp.int32),         # default_left
                 jnp.zeros((K, n_leaf), jnp.float32),      # value_bottom
                 jnp.zeros((K, n_leaf), bool))             # value_set
        node_ids = jnp.zeros((K, n_loc), jnp.int32)
        part = jax.vmap(functools.partial(ops.partition_level,
                                          missing_bin=missing_bin,
                                          plan=plan))

        def acc_hist(nn, g_a, h_a, nid):
            """Per-shard step-① accumulation, split into ``hist_slices``
            record sub-batches when OOM degradation demands it (zero-stat
            padding keeps every sub-batching bit-for-bit aligned with the
            monolithic accumulation)."""
            zero = jnp.zeros((K, nn, is_cat_field.shape[0], n_bins, 2),
                             jnp.float32)
            if hist_slices <= 1:
                return ops.accumulate_histogram(zero, codes_l, g_a, h_a,
                                                nid, n_nodes=nn,
                                                n_bins=n_bins, plan=plan)
            sz = -(-n_loc // hist_slices)
            pad = sz * hist_slices - n_loc
            if isinstance(codes_l, PackedCodes):
                cd = jnp.pad(codes_l.data, ((0, pad), (0, 0)))
                parts = [PackedCodes(cd[s * sz:(s + 1) * sz], codes_l.n)
                         for s in range(hist_slices)]
            else:
                cd = jnp.pad(codes_l, ((0, pad), (0, 0)))
                parts = [cd[s * sz:(s + 1) * sz]
                         for s in range(hist_slices)]
            g_p = jnp.pad(g_a, ((0, 0), (0, pad)))
            h_p = jnp.pad(h_a, ((0, 0), (0, pad)))
            nid_p = jnp.pad(nid, ((0, 0), (0, pad)))
            acc = zero
            for s in range(hist_slices):
                sl = slice(s * sz, (s + 1) * sz)
                acc = ops.accumulate_histogram(
                    acc, parts[s], g_p[:, sl], h_p[:, sl], nid_p[:, sl],
                    n_nodes=nn, n_bins=n_bins, plan=plan)
            return acc

        prev_hist = None
        for level in range(depth):
            nn = 2 ** level
            # step ① — local class-batched accumulation, then the paper's
            # end-of-step-① reduction across record partitions.  The local
            # pass reuses ``accumulate_histogram`` (the chunked trainers'
            # reduction unit), so every step-① entry point in the repo
            # dispatches through one jit.
            if plan.hist_subtraction and level > 0:
                # smaller-child masking per shard (paper §II-A): selection
                # uses psum'd *record counts* — integer sums are exact, so
                # every shard (and every shard count) picks the same child
                ones = jnp.ones((n_loc,), jnp.int32)
                counts = jax.lax.psum(
                    jax.vmap(lambda nid: jax.ops.segment_sum(
                        ones, nid, nn))(node_ids), da)
                is_small = tree_mod._child_is_smaller(
                    counts[:, 0::2] <= counts[:, 1::2])        # (K, nn)
                w = jax.vmap(lambda m, nid: m[nid])(
                    is_small, node_ids).astype(jnp.float32)
                small = jax.lax.psum(
                    acc_hist(nn, g_l * w, h_l * w, node_ids), da)
                hist = tree_mod._combine_sibling_hist(prev_hist, small,
                                                      is_small)
            else:
                hist = jax.lax.psum(acc_hist(nn, g_l, h_l, node_ids), da)
            prev_hist = hist
            # step ② — replicated math on the reduced histogram: every
            # shard takes the same decisions and grows the same tree
            state, best, do_split = tree_mod._decide_level(
                hist, level, depth, state, is_cat_field, field_mask,
                lambda_, gamma, min_child_weight,
                splits_mod.find_best_splits)
            # step ③ — route the local records only
            codes_lvl = codes_cm_l[jnp.where(do_split, best.feature, 0)]
            if cm_packed:      # unpack just the gathered rows, in-shard
                b = codes_lvl
                codes_lvl = jnp.stack([b & 0xF, b >> 4], axis=-1).reshape(
                    b.shape[0], b.shape[1], -1)
            node_ids = part(
                node_ids, codes_lvl.transpose(0, 2, 1),
                jnp.where(do_split,
                          jnp.broadcast_to(jnp.arange(nn, dtype=jnp.int32),
                                           (K, nn)), -1),
                best.threshold, best.is_cat, best.default_left)

        feature, threshold, is_cat, default_left, value_bottom, value_set \
            = state
        # step ④ — bottom-leaf weights from psum'd per-shard G/H sums
        Gb = jax.lax.psum(jax.vmap(lambda gg, nid: jax.ops.segment_sum(
            gg.astype(jnp.float32), nid, n_leaf))(g_l, node_ids), da)
        Hb = jax.lax.psum(jax.vmap(lambda hh, nid: jax.ops.segment_sum(
            hh.astype(jnp.float32), nid, n_leaf))(h_l, node_ids), da)
        wb = splits_mod.leaf_weight(Gb, Hb, lambda_)
        value_bottom = jnp.where(value_set, value_bottom, wb)
        return (feature, threshold, is_cat, default_left, value_bottom,
                node_ids)

    # tree tables are replicated by VALUE (identical psum'd inputs on every
    # shard), which varying-manual-axes inference cannot prove — turn the
    # static check off, as the other shard_map paths in sharding.py do
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(da), P(None, da), P(None, da), P(None, da), P(), P()),
        out_specs=(P(), P(), P(), P(), P(), P(None, da)),
        check_vma=False)

    def grow(codes, codes_cm, g2, h2, is_cat_field, field_mask):
        feature, threshold, is_cat, default_left, leaf_value, node_ids = fn(
            codes, codes_cm, g2, h2, is_cat_field, field_mask)
        tree = TreeArrays(feature=feature, threshold=threshold,
                          is_cat=is_cat, default_left=default_left,
                          leaf_value=leaf_value)
        return tree, node_ids

    return grow


# --------------------------------------------------------------------------
# one boosting round as a single jitted dispatch (fused_rounds semantics)
# --------------------------------------------------------------------------
@functools.lru_cache(maxsize=32)
def _distributed_round_step(config: GBDTConfig, plan: ExecutionPlan,
                            mesh: Mesh, da: Tuple[str, ...], n: int,
                            n_pad: int, F: int, n_bins: int,
                            n_eval: Optional[int],
                            cm_packed: bool = False,
                            hist_slices: int = 1):
    """Compile one distributed boosting round: global gradients + RNG
    filters (shard-count invariant), the sharded grower, leaf shrinkage,
    the leaf-lookup margin refresh and the loss reduction — one dispatch
    per round per host.  Cached per (fused-style config key, kernel plan,
    mesh, shapes, hist_slices): an elastic re-mesh or an OOM degradation
    compiles a new step, a replay on the same mesh reuses the old one.
    """
    loss = losses_mod.get_loss(config.objective, config.n_classes)
    K = loss.n_outputs
    Kb = K or 1
    grow = _grow_forest_sharded(
        mesh, da, depth=config.max_depth, n_bins=n_bins,
        lambda_=config.lambda_, gamma=config.gamma,
        min_child_weight=config.min_child_weight, plan=plan,
        cm_packed=cm_packed, hist_slices=hist_slices)

    def body(margins, y, tkey, codes, codes_cm, is_cat_field):
        g, h = loss.grad_hess(margins, y)
        g, h, field_mask = _round_stats(config, tkey, g, h, n, F, K)
        g2 = g.T if K is not None else g[None]                  # (Kb, n)
        h2 = h.T if K is not None else h[None]
        # padding rows carry zero statistics: exactly +0.0 per histogram
        # cell and leaf sum, so D=1 stays bit-equal to the monolithic path
        g2 = jnp.pad(g2, ((0, 0), (0, n_pad - n)))
        h2 = jnp.pad(h2, ((0, 0), (0, n_pad - n)))
        forest, node_ids = grow(codes, codes_cm, g2, h2, is_cat_field,
                                field_mask)
        forest = forest._replace(
            leaf_value=forest.leaf_value * config.learning_rate)
        # step ⑤ for free: final node ids ARE bottom-leaf slots
        delta = jax.vmap(lambda v, i: v[i])(forest.leaf_value,
                                            node_ids)[:, :n]   # (Kb, n)
        margins = margins + (delta.T if K is not None else delta[0])
        tree = (forest if K is not None
                else TreeArrays(*[a[0] for a in forest]))
        return margins, tree, jnp.mean(loss.value(margins, y))

    if n_eval is None:
        step = body
    else:
        def step(margins, ev_margins, y, y_ev, tkey, codes, codes_cm,
                 ev_codes, ev_codes_cm, is_cat_field):
            margins, tree, train_loss = body(margins, y, tkey, codes,
                                             codes_cm, is_cat_field)
            ev_data = BinnedDataset(ev_codes, ev_codes_cm, is_cat_field,
                                    n_bins, None, None)
            ev_delta = (gbdt_mod._predict_forest(tree, ev_data, plan)
                        if K is not None
                        else gbdt_mod._predict_one_tree(tree, ev_data,
                                                        plan))
            ev_margins = ev_margins + ev_delta
            return (margins, ev_margins, tree, train_loss,
                    jnp.mean(loss.value(ev_margins, y_ev)))

    return jax.jit(step)


# --------------------------------------------------------------------------
# placement + checkpoint plumbing
# --------------------------------------------------------------------------
def _place_dataset(data: BinnedDataset, mesh: Mesh, da: Tuple[str, ...]):
    """Pad records to divide the data axes and device_put both layouts.
    Pad rows replicate the edge record; training neutralizes them with
    zero gradient statistics inside the round step.

    Nibble-packed datasets (``n_bins <= 16``) ship packed: the row-major
    layout stays a :class:`PackedCodes` (records shard on axis 0, the
    packed field axis is shard-local), the column-major layout ships as
    RAW packed bytes (F, n_pad // 2) — half the placement traffic of the
    uint8 twin.  The packed cm form requires an even per-shard record
    count (a byte must not straddle shards); ``n_pad`` is NEVER adjusted
    for it — that would change the psum reduction shapes and cost the
    bit-equality guarantee against the uint8 path — so when the count
    comes out odd the cm copy falls back to plain uint8.  Pad-row code
    values are immaterial (only their zero statistics matter), so
    byte-level edge replication is as good as record-level.  Returns
    ``(codes, codes_cm, n_pad, cm_packed)``.
    """
    n = data.codes.shape[0]
    n_pad = padded_record_count(n, mesh)
    rm_packed = isinstance(data.codes, PackedCodes)
    cm_packed = isinstance(data.codes_cm, PackedCodes)
    if cm_packed:
        shards = int(np.prod([mesh.shape[a] for a in da])) if da else 1
        cm_packed = (n_pad // shards) % 2 == 0
    if rm_packed:
        d = jnp.pad(data.codes.data, ((0, n_pad - n), (0, 0)), mode="edge")
        codes = jax.device_put(PackedCodes(d, data.codes.n),
                               NamedSharding(mesh, P(da)))
    else:
        codes = jnp.pad(data.codes, ((0, n_pad - n), (0, 0)), mode="edge")
        codes = jax.device_put(codes, NamedSharding(mesh, P(da)))
    if cm_packed:
        d = data.codes_cm.data                       # (F, ceil(n / 2))
        codes_cm = jnp.pad(d, ((0, 0), (0, n_pad // 2 - d.shape[1])),
                           mode="edge")
    else:
        cm = data.codes_cm
        cm = cm.unpack() if isinstance(cm, PackedCodes) else cm
        codes_cm = jnp.pad(cm, ((0, 0), (0, n_pad - n)), mode="edge")
    codes_cm = jax.device_put(codes_cm, NamedSharding(mesh, P(None, da)))
    return codes, codes_cm, n_pad, cm_packed


def _replicate(mesh: Mesh, *arrays):
    sh = NamedSharding(mesh, P())
    out = tuple(None if a is None else jax.device_put(a, sh)
                for a in arrays)
    return out if len(out) > 1 else out[0]


def _save_round_checkpoint(dist: DistributedConfig, config: GBDTConfig,
                           trees, base_margin, margins, eval_margins,
                           history, missing_bin: int, F: int,
                           rounds_done: int) -> None:
    model = _as_model(trees, base_margin, config, missing_bin, F)
    arrays = {f"trees/{f}": np.asarray(getattr(model.trees, f))
              for f in TreeArrays._fields}
    arrays["margins"] = np.asarray(margins)
    arrays["train_loss"] = np.asarray(history["train_loss"], np.float32)
    if eval_margins is not None:
        arrays["eval_margins"] = np.asarray(eval_margins)
        arrays["eval_loss"] = np.asarray(history["eval_loss"], np.float32)
    ckpt.save_named(_round_ckpt_dir(dist), arrays, step=rounds_done,
                    keep_last=dist.keep_last,
                    extra_meta={"round": rounds_done,
                                "model": model.meta()})


def _round_ckpt_dir(dist: DistributedConfig) -> str:
    # namespaced under checkpoint_dir so the estimator's serialized
    # bundles (which share the step_<k> layout) never collide with the
    # trainer's round snapshots in the same directory
    return os.path.join(dist.checkpoint_dir, "rounds")


def _restore_round_checkpoint(dist: DistributedConfig, K: Optional[int]):
    """Newest valid step -> (trees list, margins, eval_margins, history
    arrays, rounds_done); None when no checkpoint exists (replay from 0)."""
    if dist.checkpoint_dir is None:
        return None
    try:
        arrays, step, meta = ckpt.restore_named(_round_ckpt_dir(dist))
    except FileNotFoundError:
        return None
    stacked = TreeArrays(*[np.asarray(arrays[f"trees/{f}"])
                           for f in TreeArrays._fields])
    model = model_from_meta(stacked, meta["model"])
    if K is not None:
        trees = _unstack_forests(model.trees, model.n_rounds, K)
    else:
        trees = [TreeArrays(*[a[i] for a in model.trees])
                 for i in range(model.n_trees)]
    margins = jnp.asarray(arrays["margins"])
    eval_margins = (jnp.asarray(arrays["eval_margins"])
                    if "eval_margins" in arrays else None)
    history = {"train_loss": [float(v) for v in arrays["train_loss"]]}
    if "eval_loss" in arrays:
        history["eval_loss"] = [float(v) for v in arrays["eval_loss"]]
    return trees, margins, eval_margins, history, int(meta["round"])


# --------------------------------------------------------------------------
# the engine
# --------------------------------------------------------------------------
def train_distributed(config: GBDTConfig, data: BinnedDataset, y, *,
                      mesh: Optional[Mesh] = None,
                      dist: Optional[DistributedConfig] = None,
                      eval_set: Optional[Tuple[BinnedDataset, jax.Array]]
                      = None,
                      init_model: Optional[GBDTModel] = None,
                      callback: Optional[Callable[[int, GBDTModel], None]]
                      = None,
                      verbose: bool = False,
                      plan: Optional[ExecutionPlan] = None,
                      recovery: Optional[RecoveryPolicy] = None,
                      shutdown: Optional[GracefulShutdown] = None
                      ) -> TrainResult:
    """Fit a GBDT ensemble data-parallel across ``mesh`` (see module doc).

    ``mesh`` defaults to ``plan.mesh``; one of the two must be set.  The
    result's ``stats`` records the distributed evidence: final shard
    count, restarts survived, and every re-mesh event as
    ``(kind, round, n_shards)`` tuples.

    ``recovery`` (a :class:`repro.resilience.RecoveryPolicy`) arms typed
    recovery on the round dispatch — the same policy object the streaming
    trainer takes, with the distributed semantics:

      * :class:`Preemption` re-meshes onto the survivors, restores the
        newest ``checkpoint.save_named`` step, and deterministically
        replays (the legacy catch-all path, now reserved for actual
        preemptions);
      * other transient failures retry the round on the SAME mesh after
        ``retry_delay_s`` (state is uncommitted and valid — no restore
        needed), bounded by ``max_recoveries``;
      * a device OOM doubles the per-shard histogram sub-batch count
        (``hist_slices``) and retries bit-equally, bounded by
        ``max_oom_halvings``;
      * a :class:`NumericalDivergenceError` — raised by the per-round
        finiteness sentinel on (loss, margins), which costs nothing extra
        because the loop syncs the loss scalar at commit anyway — replays
        the uncommitted round at the original learning rate first,
        backing off by ``divergence_backoff`` only when the SAME round
        diverges twice, bounded by ``max_divergence_rollbacks``.

    Without a policy the legacy behavior is preserved exactly: ANY
    dispatch failure re-meshes and restores, ``dist.max_restarts`` times.
    ``shutdown`` (a :class:`repro.resilience.GracefulShutdown`) finishes
    the in-flight round on a delivered signal, commits it plus a final
    checkpoint, and raises :class:`TrainingInterrupted` carrying the
    partial result.
    """
    if plan is None:
        plan = ExecutionPlan.from_config(config)
    if mesh is None:
        mesh = plan.mesh
    if mesh is None:
        raise ValueError("train_distributed needs a mesh (argument or "
                         "plan.mesh)")
    _check_data_parallel(mesh)
    kernel_plan = _trainer_kernel_plan(plan)
    dist = dist or DistributedConfig()
    if (recovery is not None and recovery.checkpoint_dir is not None
            and dist.checkpoint_dir is None):
        # one policy object drives both trainers: its checkpoint knobs
        # map onto the distributed trainer's save_named plumbing
        dist = dataclasses.replace(dist,
                                   checkpoint_dir=recovery.checkpoint_dir,
                                   checkpoint_every=recovery.checkpoint_every)
    if config.grow_policy != "depthwise":
        raise ValueError("distributed training supports only the depthwise "
                         "grow_policy")

    loss = losses_mod.get_loss(config.objective, config.n_classes)
    K = loss.n_outputs
    y = jnp.asarray(y, jnp.float32)
    if K is not None:
        gbdt_mod._validate_multiclass_labels(
            K, y, eval_set[1] if eval_set is not None else None)
    n, F = data.codes.shape
    n_eval = None if eval_set is None else int(eval_set[1].shape[0])
    y_ev = (jnp.asarray(eval_set[1], jnp.float32)
            if eval_set is not None else None)
    cfg_key = gbdt_mod._fused_step_key(config)

    # -- initial state (identical to core.gbdt.train) ----------------------
    trees: List[TreeArrays] = []
    if init_model is not None:
        if K is not None:
            trees = _unstack_forests(init_model.trees, init_model.n_rounds,
                                     K)
        else:
            trees = [TreeArrays(*[a[i] for a in init_model.trees])
                     for i in range(init_model.n_trees)]
        base_margin = init_model.base_margin
        # per-round sequential seeding (not one batched predict) so a
        # checkpoint resume replays the interrupted fit bit-exactly on a
        # single shard; when a matching named round checkpoint exists it
        # carries the EXACT live margins (the sharded step's fused
        # scale-and-add can differ from any host recomputation in the
        # last ulp), so that wins
        margins = eval_margins = None
        snap = _restore_round_checkpoint(dist, K)
        if snap is not None and snap[4] == init_model.n_rounds and all(
                np.array_equal(np.asarray(u), np.asarray(v))
                for a, b in zip(snap[0], trees) for u, v in zip(a, b)):
            margins, eval_margins = snap[1], snap[2]
        if margins is None:
            margins = gbdt_mod._replay_margins(init_model, data,
                                               kernel_plan)
        if eval_set is not None and eval_margins is None:
            eval_margins = gbdt_mod._replay_margins(init_model,
                                                    eval_set[0],
                                                    kernel_plan)
        if eval_set is None:
            eval_margins = None
    elif K is not None:
        base_margin = np.asarray(loss.base_margin(y), np.float32)
        margins = jnp.broadcast_to(jnp.asarray(base_margin), (n, K))
        eval_margins = (jnp.broadcast_to(jnp.asarray(base_margin),
                                         (n_eval, K))
                        if eval_set is not None else None)
    else:
        base_margin = float(loss.base_margin(y))
        margins = jnp.full((n,), base_margin, jnp.float32)
        eval_margins = (jnp.full((n_eval,), base_margin)
                        if eval_set is not None else None)
    init_margins, init_eval_margins = margins, eval_margins

    history: Dict[str, List[float]] = {"train_loss": []}
    if eval_set is not None:
        history["eval_loss"] = []
    step_times = {"fused_rounds": 0.0}
    key = jax.random.PRNGKey(config.seed)
    start = len(trees)
    end = start + config.n_trees

    devices = list(mesh.devices.flat)
    events: List[Tuple[str, int, int]] = []
    restarts = 0
    hist_slices = 1                    # OOM degradation state (doubles)
    diverged_at = -1                   # round of the last sentinel trip
    rstats = {"recoveries": 0, "oom_halvings": 0, "replayed_rounds": 0,
              "divergence_rollbacks": 0}

    def _mkstats(**extra):
        return {"n_rows": n, "distributed": True,
                "n_shards": n_data_shards(mesh), "restarts": restarts,
                "remesh_events": events, "hist_slices": hist_slices,
                **rstats, **extra}

    def place(new_mesh):
        nonlocal mesh, da, codes, codes_cm, n_pad, margins, eval_margins
        nonlocal y, y_ev, is_cat, ev_codes, ev_codes_cm, cm_packed
        mesh = new_mesh
        # the plan's data-axis spec wins while it matches the live mesh;
        # an elastic re-mesh always lands on a plain ("data",) topology
        if (plan.data_axes
                and set(plan.data_axes) <= set(mesh.axis_names)):
            da = tuple(plan.data_axes)
        else:
            da = data_axes(mesh)
        codes, codes_cm, n_pad, cm_packed = _place_dataset(data, mesh, da)
        y = _replicate(mesh, y)
        margins = _replicate(mesh, margins)
        is_cat = _replicate(mesh, data.is_categorical)
        if eval_set is not None:
            ev_codes, ev_codes_cm = _replicate(mesh, eval_set[0].codes,
                                               eval_set[0].codes_cm)
            y_ev = _replicate(mesh, y_ev)
            eval_margins = _replicate(mesh, eval_margins)

    codes = codes_cm = is_cat = ev_codes = ev_codes_cm = None
    n_pad, da, cm_packed = 0, (), False
    place(mesh)

    t_loop = time.perf_counter()
    t_idx = start
    while t_idx < end:
        try:
            # elastic grow/shrink between rounds: a changed device list
            # re-places the (mesh-agnostic) training state, no restore
            if dist.fault_schedule is not None:
                dist.fault_schedule.apply("elastic", t_idx)
            if dist.available_devices is not None:
                want = list(dist.available_devices(t_idx))
                if [d.id for d in want] != [d.id for d in devices]:
                    kind = "grow" if len(want) > len(devices) else "shrink"
                    devices = want
                    place(data_parallel_mesh(devices))
                    events.append((kind, t_idx, n_data_shards(mesh)))
                    if verbose:
                        print(f"[dist] {kind} -> {n_data_shards(mesh)} "
                              f"shards at round {t_idx}")
            step = _distributed_round_step(cfg_key, kernel_plan, mesh,
                                           tuple(da), n, n_pad, F,
                                           data.n_bins, n_eval, cm_packed,
                                           hist_slices)
            tkey = jax.random.fold_in(key, t_idx)  # mesh-invariant stream
            if eval_set is None:
                new_margins, tree, tl = step(margins, y, tkey, codes,
                                             codes_cm, is_cat)
                new_eval = ev = None
            else:
                new_margins, new_eval, tree, tl, ev = step(
                    margins, eval_margins, y, y_ev, tkey, codes, codes_cm,
                    ev_codes, ev_codes_cm, is_cat)
            jax.block_until_ready(new_margins)
            if dist.fault_injector is not None:
                dist.fault_injector.check(t_idx)   # worker dies mid-round
            if dist.fault_schedule is not None:
                dist.fault_schedule.apply("round", t_idx)
            # numerical divergence sentinel at log_every cadence (same
            # as the fused engine): a NaN-max reduction over the new
            # margins — max |x| propagates NaN and saturates at inf, so
            # the single fused reduction is an exact finiteness probe
            if (recovery is not None
                    and (t_idx % config.log_every == 0
                         or t_idx == end - 1)
                    and not bool(jnp.isfinite(
                        jnp.maximum(jnp.max(jnp.abs(new_margins)),
                                    jnp.abs(tl))))):
                raise NumericalDivergenceError(
                    f"non-finite loss/margins at round {t_idx}",
                    round_index=t_idx, what="loss/margins")
        except Exception as e:  # noqa: BLE001 — classified below
            action = classify(e) if recovery is not None else "remesh"
            if action == "transient" and isinstance(e, Preemption):
                action = "remesh"      # preemptions re-mesh; others retry
            if action == "divergence":
                if (rstats["divergence_rollbacks"]
                        >= recovery.max_divergence_rollbacks):
                    raise
                rstats["divergence_rollbacks"] += 1
                _metrics.record("recoveries")
                if diverged_at == t_idx:
                    # the same round diverged on its replay: genuine
                    # divergence — shrink the steps (recompiles the round)
                    cfg_key = dataclasses.replace(
                        cfg_key,
                        learning_rate=(cfg_key.learning_rate
                                       * recovery.divergence_backoff))
                    if verbose:
                        print(f"[dist] round {t_idx} diverged twice; "
                              f"learning_rate -> "
                              f"{cfg_key.learning_rate:g}")
                elif verbose:
                    print(f"[dist] divergence at round {t_idx}; replaying "
                          "from the last finite round")
                diverged_at = t_idx
                continue   # the round is uncommitted: replay = rollback
            if action == "oom":
                if rstats["oom_halvings"] >= recovery.max_oom_halvings:
                    raise
                rstats["oom_halvings"] += 1
                _metrics.record("recoveries")
                hist_slices *= 2
                if verbose:
                    print(f"[dist] device OOM at round {t_idx}: "
                          f"hist_slices -> {hist_slices}; retrying round")
                continue
            if action == "transient":
                if rstats["recoveries"] >= recovery.max_recoveries:
                    raise
                rstats["recoveries"] += 1
                _metrics.record("recoveries")
                if recovery.retry_delay_s:
                    time.sleep(recovery.retry_delay_s)
                if verbose:
                    print(f"[dist] transient failure at round {t_idx} "
                          f"({type(e).__name__}: {e}); retrying on the "
                          "same mesh")
                continue
            if action == "fatal":
                raise
            # preemption (or any failure under the legacy no-policy
            # contract): re-mesh onto the survivors, restore the newest
            # checkpoint, deterministically replay
            restarts += 1
            if restarts > dist.max_restarts:
                raise
            if recovery is not None:
                _metrics.record("recoveries")
            surv = (dist.survivors(devices) if dist.survivors is not None
                    else (devices[:-1] if len(devices) > 1 else devices))
            devices = list(surv)
            place(data_parallel_mesh(devices))
            events.append(("shrink", t_idx, n_data_shards(mesh)))
            if verbose:
                print(f"[dist] fault at round {t_idx} ({e}); resuming on "
                      f"{n_data_shards(mesh)} shards")
            t_before = t_idx
            restored = _restore_round_checkpoint(dist, K)
            if restored is None:       # no checkpoint yet: replay the fit
                trees = list(trees[:start])
                margins, eval_margins = init_margins, init_eval_margins
                history = {k: [] for k in history}
                t_idx = start
            else:
                trees, margins, eval_margins, history, t_idx = restored
            rstats["replayed_rounds"] += max(0, t_before - t_idx)
            margins = _replicate(mesh, margins)
            if eval_margins is not None:
                eval_margins = _replicate(mesh, eval_margins)
            continue                    # deterministic replay from t_idx

        # -- commit the round ---------------------------------------------
        margins, eval_margins = new_margins, new_eval
        # committed trees go to host memory: the ensemble must outlive any
        # mesh (an elastic re-mesh would otherwise mix device assemblies
        # when the final model stacks rounds from different meshes)
        trees.append(TreeArrays(*[np.asarray(a) for a in tree]))
        history["train_loss"].append(float(tl))
        if eval_set is not None:
            history["eval_loss"].append(float(ev))
        rounds_done = t_idx + 1
        if (dist.checkpoint_dir is not None
                and rounds_done % dist.checkpoint_every == 0):
            _save_round_checkpoint(dist, config, trees, base_margin,
                                   margins, eval_margins, history,
                                   data.missing_bin, F, rounds_done)
        if verbose and (t_idx % config.log_every == 0 or t_idx == end - 1):
            print(f"[dist] round {t_idx:4d}  "
                  f"train_loss={history['train_loss'][-1]:.6f}  "
                  f"shards={n_data_shards(mesh)}")
        if callback is not None:
            callback(t_idx, _as_model(trees, base_margin, config,
                                      data.missing_bin, F))
        if shutdown is not None and shutdown.requested:
            # the in-flight round is committed; persist the exact
            # resumable state, then exit with a typed status
            if (dist.checkpoint_dir is not None
                    and rounds_done % dist.checkpoint_every):
                _save_round_checkpoint(dist, config, trees, base_margin,
                                       margins, eval_margins, history,
                                       data.missing_bin, F, rounds_done)
            step_times["fused_rounds"] = time.perf_counter() - t_loop
            partial = TrainResult(
                model=_as_model(trees, base_margin, config,
                                data.missing_bin, F),
                history=history, step_times=step_times,
                stats=_mkstats(interrupted=True))
            raise TrainingInterrupted(
                f"shutdown ({shutdown.signal_name}) after round {t_idx}",
                rounds_done=len(trees), signal_name=shutdown.signal_name,
                checkpoint_dir=dist.checkpoint_dir, result=partial)
        t_idx += 1

    step_times["fused_rounds"] = time.perf_counter() - t_loop
    return TrainResult(
        model=_as_model(trees, base_margin, config, data.missing_bin, F),
        history=history, step_times=step_times, stats=_mkstats())

"""Fault tolerance: checkpoint/restart, failure injection, step journal.

Model: synchronous SPMD training on a fixed mesh.  A node failure surfaces
as a raised exception (device error / collective timeout at the framework
level).  Recovery = rebuild a mesh from surviving devices (see
``elastic.py``) + restore the newest valid checkpoint + deterministic
replay.  GBDT makes replay exact: the per-tree RNG stream is keyed by
(seed, tree_index) (see ``core.gbdt.train``), so re-growing tree k after a
restart reproduces the pre-failure tree bit-for-bit.

Straggler posture (documented, since a 1-core container cannot exhibit
real stragglers): the level-wise grower is *fixed-shape* — every data shard
scans exactly n/D records and every field shard owns F/M histogram slabs
per level, so compute imbalance from data skew is zero by construction;
residual stragglers are hardware-speed outliers, mitigated by the
checkpoint cadence + the journal's per-step wall-time record which flags
slow shards for operator rotation.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

# The round-level injector moved to the generalized, multi-site fault
# harness in ``repro.resilience.faults``.  Importing it through this module
# still works for one release but emits a DeprecationWarning — update
# imports to ``from repro.resilience.faults import ...``.
_MOVED = ("Fault", "FaultInjector", "FaultSchedule")


def __getattr__(name: str) -> Any:
    if name in _MOVED:
        import warnings

        warnings.warn(
            f"repro.distributed.fault.{name} is deprecated; import it from "
            f"repro.resilience.faults instead (this shim will be removed "
            f"next release)", DeprecationWarning, stacklevel=2)
        from repro.resilience import faults as _faults
        return getattr(_faults, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


class StepJournal:
    """Append-only jsonl journal of completed steps (fsync'd).

    Survives crashes; on restart the trainer resumes after the last
    journaled step that also has a checkpoint ≤ it.
    """

    def __init__(self, path: str):
        self.path = path
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)

    def append(self, step: int, record: Dict[str, Any]) -> None:
        entry = dict(step=step, time=time.time(), **record)
        with open(self.path, "a") as f:
            f.write(json.dumps(entry) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def entries(self) -> List[Dict[str, Any]]:
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    out.append(json.loads(line))
                except json.JSONDecodeError:
                    break  # torn tail write — ignore the rest
        return out

    def last_step(self) -> Optional[int]:
        e = self.entries()
        return e[-1]["step"] if e else None


def run_with_restarts(make_trainer: Callable[[int], Iterator[int]],
                      *, max_restarts: int = 3,
                      on_restart: Optional[Callable[[int, Exception], None]]
                      = None) -> int:
    """Drive a restartable trainer through failures.

    ``make_trainer(start_step)`` returns an iterator that yields completed
    step indices (checkpointing internally) and may raise mid-flight.
    Returns the last completed step.  Raises after ``max_restarts``.
    """
    start, last, restarts = 0, -1, 0
    while True:
        try:
            for step in make_trainer(start):
                last = step
            return last
        except Exception as e:  # noqa: BLE001 — any node fault
            restarts += 1
            if restarts > max_restarts:
                raise
            if on_restart is not None:
                on_restart(restarts, e)
            start = last + 1

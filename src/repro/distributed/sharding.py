"""Distributed GBDT: the paper's parallel decomposition at pod scale.

Paper §III-B: "the records can be partitioned among the clusters so that
each cluster generates a set of histograms which are reduced at the end of
the step" — inter-record parallelism → the ``("pod", "data")`` mesh axes.
The group-by-field mapping (§III-A) lifts to the chip level: fields (and
their histogram slabs) are sharded across ``"model"`` — intra-record
parallelism.  Cross-shard traffic per level is then

  * one histogram psum over the data axes (O(nodes·local_fields·bins), ≪
    record traffic — the paper's cluster reduction), and
  * one tiny per-node argmax combine across field shards (step ②).

``distributed_histogram`` / ``distributed_fit_tree_shardmap`` make these
collectives *explicit* with shard_map; ``pjit_fit_tree`` lowers the whole
unmodified ``core.tree.fit_tree`` under GSPMD and lets XLA place the same
collectives (the two paths are tested equal).
"""
from __future__ import annotations

import functools
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.api.plan import ExecutionPlan
from repro.core import splits as splits_mod
from repro.core import tree as tree_mod
from repro.core.binning import PackedCodes, as_unpacked
from repro.kernels import ops
from repro.kernels.ref import TreeArrays
from repro.launch.mesh import data_axes


def _warn_loose_strategy(hist_strategy: Optional[str]) -> None:
    """One release path for the distributed growers' loose hist kwarg —
    the defaulting itself now lives in ``ExecutionPlan.from_config``."""
    if hist_strategy is not None and hist_strategy != "auto":
        warnings.warn(
            "legacy strategy-string kwargs are deprecated; pass "
            "plan=ExecutionPlan(hist_strategy=...) instead",
            DeprecationWarning, stacklevel=3)


def gbdt_shardings(mesh: Mesh):
    """NamedShardings for the GBDT training inputs on ``mesh``."""
    da = data_axes(mesh)
    return {
        "codes": NamedSharding(mesh, P(da, "model")),     # records x fields
        "codes_cm": NamedSharding(mesh, P("model", da)),  # fields x records
        "per_record": NamedSharding(mesh, P(da)),         # g, h, node_ids, y
        "per_field": NamedSharding(mesh, P(None, "model")),
        "replicated": NamedSharding(mesh, P()),
    }


def padded_record_count(n: int, mesh: Mesh) -> int:
    """Records padded up to a multiple of the data-axis product (elastic
    re-meshing can land on shard counts that do not divide n)."""
    n_da = int(np.prod([mesh.shape[a] for a in data_axes(mesh)]))
    return -(-n // n_da) * n_da


def shard_dataset(data, mesh: Mesh):
    """device_put the binned dataset onto the mesh (records x fields grid).

    Records are padded (edge-replicated) to divide the data axes; padded
    rows must carry g = h = 0 in training (no histogram contribution) and
    their predictions are sliced off by callers.
    """
    sh = gbdt_shardings(mesh)
    n = data.codes.shape[0]
    n_pad = padded_record_count(n, mesh) - n
    # the mesh grid shards BOTH axes of each layout; a nibble-packed axis
    # cannot be split mid-byte, so distributed placement uses the plain
    # uint8 layouts (single-device training keeps the packed halving)
    codes = jnp.pad(as_unpacked(data.codes), ((0, n_pad), (0, 0)),
                    mode="edge")
    codes_cm = jnp.pad(as_unpacked(data.codes_cm), ((0, 0), (0, n_pad)),
                       mode="edge")
    return data.__class__(
        codes=jax.device_put(codes, sh["codes"]),
        codes_cm=jax.device_put(codes_cm, sh["codes_cm"]),
        is_categorical=jax.device_put(data.is_categorical, sh["replicated"]),
        n_bins=data.n_bins, bin_edges=data.bin_edges,
        n_value_bins=data.n_value_bins)


# --------------------------------------------------------------------------
# explicit shard_map path — the paper's communication schedule, verbatim
# --------------------------------------------------------------------------
def distributed_histogram(mesh: Mesh, codes, g, h, node_ids, *,
                          n_nodes: int, n_bins: int,
                          plan: Optional[ExecutionPlan] = None,
                          strategy: Optional[str] = None):
    """Step ① with explicit collectives.

    Local kernel on (records/D, fields/M) shard, then one psum over the data
    axes.  Returns the histogram sharded over fields on "model"
    (group-by-field at chip granularity): (n_nodes, F, n_bins, 2).
    """
    da = data_axes(mesh)
    _warn_loose_strategy(strategy)
    plan = ExecutionPlan.from_config(base=plan, hist_strategy=strategy)
    if isinstance(codes, PackedCodes):
        codes = codes.unpack()     # the field axis is sharded mid-byte

    def local(codes_l, g_l, h_l, node_l):
        hist_l = ops.build_histogram(codes_l, g_l, h_l, node_l,
                                     n_nodes=n_nodes, n_bins=n_bins,
                                     plan=plan)
        # the paper's end-of-step-① reduction across record partitions
        return jax.lax.psum(hist_l, da)

    fn = shard_map(local, mesh=mesh,
                       in_specs=(P(da, "model"), P(da), P(da), P(da)),
                       out_specs=P(None, "model"))
    return fn(codes, g, h, node_ids)


def distributed_split_combine(mesh: Mesh, hist, is_cat_field, field_mask,
                              lambda_, gamma, min_child_weight, n_fields: int):
    """Step ② across field shards: local best per shard, tiny global argmax.

    hist is field-sharded (model); each shard evaluates its own fields and
    contributes one candidate per node; the cross-shard combine moves only
    O(nodes x shards x 6) scalars — the paper's 'bins ≪ records' argument.
    """
    m_size = mesh.shape["model"]
    f_local = n_fields // m_size

    def local(hist_l, cat_l, mask_l):
        best = splits_mod.find_best_splits(hist_l, cat_l, mask_l, lambda_,
                                           gamma, min_child_weight)
        shard = jax.lax.axis_index("model")
        cand = jnp.stack([
            best.gain,
            (best.feature + shard * f_local).astype(jnp.float32),
            best.threshold.astype(jnp.float32),
            best.is_cat.astype(jnp.float32),
            best.default_left.astype(jnp.float32),
            best.node_g, best.node_h, best.left_h], axis=-1)  # (NN, 8)
        allc = jax.lax.all_gather(cand, "model")              # (M, NN, 8)
        win = jnp.argmax(allc[..., 0], axis=0)                # (NN,)
        sel = jnp.take_along_axis(allc, win[None, :, None], axis=0)[0]
        return sel

    # the post-all_gather argmax is replicated across "model" by value, which
    # varying-manual-axes inference cannot prove — disable the static check
    fn = shard_map(local, mesh=mesh,
                       in_specs=(P(None, "model"), P("model"), P("model")),
                       out_specs=P(), check_vma=False)
    sel = fn(hist, is_cat_field, field_mask)
    return splits_mod.SplitDecision(
        gain=sel[:, 0], feature=sel[:, 1].astype(jnp.int32),
        threshold=sel[:, 2].astype(jnp.int32),
        is_cat=sel[:, 3].astype(jnp.int32),
        default_left=sel[:, 4].astype(jnp.int32),
        node_g=sel[:, 5], node_h=sel[:, 6], left_h=sel[:, 7])


def distributed_partition_bits(mesh: Mesh, node_ids, codes_cm, feat, thr,
                               cat, dl, *, missing_bin: int, n_fields: int):
    """Step ③ with owner-evaluates semantics (paper §III-B adapted).

    Instead of gathering each level's predicate columns to every data
    shard (O(nn x records) cross-chip bytes), the model shard that OWNS a
    node's split field evaluates the predicate locally and contributes a
    2-bit verdict; one int8 psum over "model" (O(records) bytes) routes
    every record — the TPU analog of Booster streaming pointer lists
    instead of record fields.
    """
    import jax.numpy as jnp
    da = data_axes(mesh)
    m_size = mesh.shape["model"]
    f_local = n_fields // m_size
    if isinstance(codes_cm, PackedCodes):
        codes_cm = codes_cm.unpack()   # the record axis is sharded mid-byte

    def local(codes_cm_l, node_l):
        rank = jax.lax.axis_index("model")
        owns = (feat >= 0) & (feat // f_local == rank)          # (nn,)
        local_idx = jnp.clip(feat - rank * f_local, 0, f_local - 1)
        codes_sel = codes_cm_l[local_idx]                       # (nn, n_l)
        n_l = codes_sel.shape[1]
        code = codes_sel[node_l, jnp.arange(n_l)].astype(jnp.int32)
        t, c, d = thr[node_l], cat[node_l], dl[node_l]
        left = jnp.where(c == 1, code == t, code <= t)
        left = jnp.where(code == missing_bin, d == 1, left)
        verdict = jnp.where(owns[node_l],
                            jnp.where(left, 2, 1), 0).astype(jnp.int8)
        # psum stays int8: exactly one owner contributes, max total == 2
        total = jax.lax.psum(verdict, "model")
        go_left = total != 1          # 0 == pass-through -> left
        return 2 * node_l + (1 - go_left.astype(jnp.int32))

    return shard_map(local, mesh=mesh,
                         in_specs=(P("model", da), P(da)),
                         out_specs=P(da), check_vma=False)(codes_cm, node_ids)


def distributed_fit_tree(mesh: Mesh, codes, codes_cm, g, h, *, depth: int,
                         n_bins: int, missing_bin: int, is_cat_field,
                         field_mask, lambda_: float, gamma: float,
                         min_child_weight: float,
                         plan: Optional[ExecutionPlan] = None,
                         hist_strategy: Optional[str] = None,
                         hist_dtype=None, partition_bits: bool = False):
    """Level-wise grower with the paper's EXPLICIT communication schedule.

    Per level: local histograms -> one psum over the data axes (cast to
    ``hist_dtype`` first when set — bf16 halves the only cross-pod
    collective, the gradient-compression knob of DESIGN.md §6) -> per-shard
    split finding on local fields -> tiny cross-shard argmax -> partition.
    Returns the same TreeArrays as ``core.tree.fit_tree``.
    """
    import jax.numpy as jnp
    from repro.kernels.ref import TreeArrays
    from repro.core.splits import leaf_weight

    _warn_loose_strategy(hist_strategy)
    plan = ExecutionPlan.from_config(base=plan, hist_strategy=hist_strategy,
                                     distributed=True)
    da = data_axes(mesh)
    codes = as_unpacked(codes)         # both shard grids split mid-byte
    codes_cm = as_unpacked(codes_cm)
    F = codes.shape[1]
    n = codes.shape[0]
    n_int, n_leaf = 2 ** depth - 1, 2 ** depth

    feature = jnp.full((n_int,), -1, jnp.int32)
    threshold = jnp.zeros((n_int,), jnp.int32)
    is_cat = jnp.zeros((n_int,), jnp.int32)
    default_left = jnp.zeros((n_int,), jnp.int32)
    value_bottom = jnp.zeros((n_leaf,), jnp.float32)
    value_set = jnp.zeros((n_leaf,), bool)
    node_ids = jnp.zeros((n,), jnp.int32)

    def local_hist(codes_l, g_l, h_l, node_l, nn):
        hist_l = ops.build_histogram(codes_l, g_l, h_l, node_l, n_nodes=nn,
                                     n_bins=n_bins, plan=plan)
        if hist_dtype is not None:      # compress the cross-shard reduction
            hist_l = hist_l.astype(hist_dtype)
        return jax.lax.psum(hist_l, da).astype(jnp.float32)

    for level in range(depth):
        nn = 2 ** level
        off = nn - 1
        reps = 2 ** (depth - level)
        hist = shard_map(
            functools.partial(local_hist, nn=nn), mesh=mesh,
            in_specs=(P(da, "model"), P(da), P(da), P(da)),
            out_specs=P(None, "model"))(codes, g, h, node_ids)
        best = distributed_split_combine(mesh, hist, is_cat_field,
                                         field_mask, lambda_, gamma,
                                         min_child_weight, F)
        resolved = value_set[jnp.arange(nn) * reps]
        do_split = (best.gain > 0.0) & (~resolved)
        w = leaf_weight(best.node_g, best.node_h, lambda_)
        newly = (~do_split) & (~resolved)
        mask_b = jnp.repeat(newly, reps)
        value_bottom = jnp.where(mask_b & (~value_set),
                                 jnp.repeat(w, reps), value_bottom)
        value_set = value_set | mask_b
        feature = jax.lax.dynamic_update_slice(
            feature, jnp.where(do_split, best.feature, -1), (off,))
        threshold = jax.lax.dynamic_update_slice(threshold, best.threshold,
                                                 (off,))
        is_cat = jax.lax.dynamic_update_slice(is_cat, best.is_cat, (off,))
        default_left = jax.lax.dynamic_update_slice(
            default_left, best.default_left, (off,))
        if partition_bits:
            node_ids = distributed_partition_bits(
                mesh, node_ids, codes_cm,
                jnp.where(do_split, best.feature, -1), best.threshold,
                best.is_cat, best.default_left,
                missing_bin=missing_bin, n_fields=F)
        else:
            codes_lvl = codes_cm[jnp.where(do_split, best.feature, 0)]
            node_ids = ops.partition_level(
                node_ids, codes_lvl.T,
                jnp.where(do_split, jnp.arange(nn, dtype=jnp.int32), -1),
                best.threshold, best.is_cat, best.default_left,
                missing_bin=missing_bin, plan=plan)

    Gb = jax.ops.segment_sum(g.astype(jnp.float32), node_ids, n_leaf)
    Hb = jax.ops.segment_sum(h.astype(jnp.float32), node_ids, n_leaf)
    wb = leaf_weight(Gb, Hb, lambda_)
    value_bottom = jnp.where(value_set, value_bottom, wb)
    return TreeArrays(feature=feature, threshold=threshold, is_cat=is_cat,
                      default_left=default_left, leaf_value=value_bottom)


# --------------------------------------------------------------------------
# GSPMD path — unmodified core grower under pjit
# --------------------------------------------------------------------------
def pjit_fit_tree(mesh: Mesh, *, depth: int, n_bins: int, missing_bin: int,
                  lambda_: float, gamma: float, min_child_weight: float,
                  plan: Optional[ExecutionPlan] = None,
                  hist_strategy: Optional[str] = None,
                  donate: bool = False):
    """jit the unmodified level-wise grower with mesh shardings.

    Works on any mesh (including the 512-chip production mesh in the
    dry-run); GSPMD inserts the same psum/all-gather schedule the explicit
    path spells out.
    """
    sh = gbdt_shardings(mesh)
    _warn_loose_strategy(hist_strategy)
    plan = ExecutionPlan.from_config(base=plan, hist_strategy=hist_strategy,
                                     distributed=True)

    fn = functools.partial(
        tree_mod.fit_tree, depth=depth, n_bins=n_bins,
        missing_bin=missing_bin, lambda_=lambda_, gamma=gamma,
        min_child_weight=min_child_weight, plan=plan)

    def wrapped(codes, codes_cm, g, h, is_cat_field, field_mask):
        return fn(codes, codes_cm, g, h, is_cat_field=is_cat_field,
                  field_mask=field_mask)

    return jax.jit(
        wrapped,
        in_shardings=(sh["codes"], sh["codes_cm"], sh["per_record"],
                      sh["per_record"], sh["replicated"], sh["replicated"]),
        out_shardings=NamedSharding(mesh, P()),
    )

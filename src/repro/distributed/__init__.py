from repro.distributed import (checkpoint, elastic, fault, sharding,
                               trainer)
from repro.distributed.trainer import (DistributedConfig,
                                       data_parallel_mesh,
                                       train_distributed)

from repro.distributed import checkpoint, elastic, fault, sharding

"""Atomic, content-verified checkpointing for fault-tolerant training.

Layout per step:  <dir>/step_<k>/arrays.npz + manifest.json
  * two-phase commit: write into ``step_<k>.tmp``, fsync, atomic rename —
    a crash mid-write never corrupts the latest valid checkpoint;
  * the manifest stores a sha256 of the array payload; restore verifies it
    (a half-written or bit-rotted checkpoint is skipped, falling back to
    the previous one);
  * ``keep_last`` bounds disk usage; restore picks the newest *valid* step.

State is any pytree of arrays; restore reshapes it onto the caller's target
sharding (``like=`` gives structure, ``mesh_sharding`` gives placement), so
the same checkpoint restores onto a different mesh — the elastic-scaling
path (see ``repro.distributed.elastic``).
"""
from __future__ import annotations

import hashlib
import io
import json
import os
import re
import shutil
import warnings
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

_STEP_RE = re.compile(r"^step_(\d+)$")


def _flatten(state: Any) -> Tuple[Dict[str, np.ndarray], Any]:
    leaves, treedef = jax.tree_util.tree_flatten(state)
    arrays = {f"leaf_{i:05d}": np.asarray(x) for i, x in enumerate(leaves)}
    return arrays, treedef


def write_payload_dir(path: str, arrays: Dict[str, np.ndarray],
                      manifest: Dict) -> str:
    """Two-phase atomic write of ``arrays.npz`` + ``manifest.json`` at
    ``path``: write into ``path.tmp``, fsync, atomic rename.  The payload
    sha256 is stamped into the manifest.  Shared by step checkpoints and
    the ``repro.api`` model bundles."""
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    payload = buf.getvalue()
    manifest = dict(manifest, sha256=hashlib.sha256(payload).hexdigest())

    tmp = path + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    with open(os.path.join(tmp, "arrays.npz"), "wb") as f:
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)  # atomic commit
    return path


def save(directory: str, state: Any, step: int, *,
         keep_last: int = 3, extra_meta: Optional[Dict] = None) -> str:
    """Two-phase atomic checkpoint write; returns the final path."""
    arrays, _ = _flatten(state)
    return save_named(directory, arrays, step, keep_last=keep_last,
                      extra_meta=extra_meta)


def save_named(directory: str, arrays: Dict[str, np.ndarray], step: int, *,
               keep_last: int = 3, extra_meta: Optional[Dict] = None) -> str:
    """Checkpoint a flat ``{name: array}`` dict with its names preserved.

    Unlike :func:`save` (whose positional leaf naming forces restore
    callers to supply a ``like`` pytree), named payloads restore
    self-describing — the unified ``repro.api`` serialization rides on
    this."""
    os.makedirs(directory, exist_ok=True)
    final = write_payload_dir(
        os.path.join(directory, f"step_{step}"), arrays,
        {"step": step, "n_leaves": len(arrays),
         "names": sorted(arrays), "meta": extra_meta or {}})
    _gc(directory, keep_last)
    return final


def _gc(directory: str, keep_last: int) -> None:
    steps = list_steps(directory)
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(directory, f"step_{s}"),
                      ignore_errors=True)


def list_steps(directory: str) -> List[int]:
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = _STEP_RE.match(name)
        if m:
            out.append(int(m.group(1)))
    return sorted(out)


def _validate(path: str) -> Optional[Dict]:
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with open(os.path.join(path, "arrays.npz"), "rb") as f:
            payload = f.read()
        if hashlib.sha256(payload).hexdigest() != manifest["sha256"]:
            return None
        return manifest
    except (OSError, json.JSONDecodeError, KeyError):
        return None


def validate_payload_dir(path: str) -> Optional[Dict]:
    """Public alias of the manifest/sha256 validation (api.serialize)."""
    return _validate(path)


def restore_named(directory: str, *, step: Optional[int] = None
                  ) -> Tuple[Dict[str, np.ndarray], int, Dict]:
    """Restore the newest valid *named* checkpoint as a ``{name: array}``
    dict (no ``like`` pytree needed — names travel in the payload)."""
    steps = list_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    for s in reversed(steps):
        path = os.path.join(directory, f"step_{s}")
        manifest = _validate(path)
        if manifest is None or "names" not in manifest:
            continue  # corrupt/partial/legacy — fall back to older
        try:
            with np.load(os.path.join(path, "arrays.npz")) as z:
                arrays = {k: z[k] for k in manifest["names"]}
        except Exception as e:  # noqa: BLE001 — torn step, use next-newest
            warnings.warn(
                f"checkpoint step_{s} under {directory!r} passed sha "
                f"validation but failed to load ({type(e).__name__}: {e}); "
                "falling back to the next-newest step", RuntimeWarning)
            continue
        return arrays, s, manifest["meta"]
    raise FileNotFoundError(f"no valid named checkpoint under {directory!r}")


def restore(directory: str, like: Any, *,
            step: Optional[int] = None, shardings: Any = None
            ) -> Tuple[Any, int, Dict]:
    """Restore the newest valid checkpoint (or an explicit ``step``).

    ``like`` provides the pytree structure; ``shardings`` (optional, same
    structure or a single sharding) places leaves on a (possibly different)
    mesh — elastic restarts restore onto whatever mesh is alive.
    """
    steps = list_steps(directory)
    if step is not None:
        steps = [s for s in steps if s == step]
    for s in reversed(steps):
        path = os.path.join(directory, f"step_{s}")
        manifest = _validate(path)
        if manifest is None:
            continue  # corrupt/partial — fall back to an older checkpoint
        try:
            with np.load(os.path.join(path, "arrays.npz")) as z:
                arrays = [z[f"leaf_{i:05d}"]
                          for i in range(manifest["n_leaves"])]
        except Exception as e:  # noqa: BLE001 — torn step, use next-newest
            warnings.warn(
                f"checkpoint step_{s} under {directory!r} passed sha "
                f"validation but failed to load ({type(e).__name__}: {e}); "
                "falling back to the next-newest step", RuntimeWarning)
            continue
        treedef = jax.tree_util.tree_structure(like)
        state = jax.tree_util.tree_unflatten(treedef, arrays)
        if shardings is not None:
            if jax.tree_util.tree_structure(shardings) == treedef:
                state = jax.tree.map(jax.device_put, state, shardings)
            else:
                state = jax.tree.map(
                    lambda x: jax.device_put(x, shardings), state)
        return state, s, manifest["meta"]
    raise FileNotFoundError(f"no valid checkpoint under {directory!r}")

"""Sharded input pipeline: chunked data sources + host-side prefetch.

The paper's Booster hides the record stream behind double-buffered DMA
(§III-B); at the framework level the analog is a background host thread
that materializes and device_puts the next global batch while the current
step runs.  Works for the GBDT record stream and the LM token stream.

The :class:`DataSource` protocol is the out-of-core entry point: anything
that can re-iterate ``(X_chunk, y_chunk)`` numpy pairs can feed the
streaming trainer (``core.gbdt.train_streaming``) and the sketch binner
(``core.binning.StreamingBinner``) without ever materializing the full
matrix.  Three implementations ship here / in ``data.synthetic``:
in-memory arrays, a directory of npz shards, and a deterministic
synthetic generator.
"""
from __future__ import annotations

import dataclasses
import glob
import io
import json
import os
import queue
import threading
import zlib
from typing import (Iterable, Iterator, Optional, Protocol, Tuple,
                    runtime_checkable)

import jax
import numpy as np

from repro.resilience.errors import ShardCorruptionError

MANIFEST_NAME = "manifest.json"


# --------------------------------------------------------------------------
# shard integrity: crc32 sidecar manifest
# --------------------------------------------------------------------------
def write_shard_manifest(directory: str, paths: Iterable[str]) -> str:
    """Write ``manifest.json`` next to the shards: per-shard crc32 + byte
    count, keyed by basename.  Both shard writers call this; the shard
    sources verify against it on every read so bit-rot or torn writes
    surface as :class:`ShardCorruptionError` instead of silently feeding
    garbage into a fit."""
    shards = {}
    for path in paths:
        with open(path, "rb") as f:
            data = f.read()
        shards[os.path.basename(path)] = {
            "crc32": zlib.crc32(data) & 0xFFFFFFFF,
            "bytes": len(data),
        }
    manifest_path = os.path.join(directory, MANIFEST_NAME)
    tmp = manifest_path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"version": 1, "shards": shards}, f, indent=1)
    os.replace(tmp, manifest_path)
    return manifest_path


def _load_manifest(directory: str) -> Optional[dict]:
    """The shard table from ``manifest.json``, or None when the directory
    predates checksumming (verification is then skipped — back-compat)."""
    path = os.path.join(directory, MANIFEST_NAME)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)["shards"]
    except (OSError, json.JSONDecodeError, KeyError) as e:
        raise ShardCorruptionError(
            f"unreadable shard manifest {path!r}: {e}") from e


def _open_verified(path: str, manifest: Optional[dict]):
    """``np.load`` the shard, crc32-verified against the manifest when one
    exists.  Verification reads the file once into memory and loads from
    the verified bytes, so the checked bytes ARE the loaded bytes."""
    if manifest is None:
        return np.load(path)
    entry = manifest.get(os.path.basename(path))
    if entry is None:
        raise ShardCorruptionError(
            f"shard {path!r} is not in the directory manifest — stale or "
            "foreign file; re-export the shard directory")
    with open(path, "rb") as f:
        data = f.read()
    if len(data) != entry["bytes"] or \
            (zlib.crc32(data) & 0xFFFFFFFF) != entry["crc32"]:
        raise ShardCorruptionError(
            f"shard {path!r} failed crc32 verification "
            f"({len(data)} bytes vs {entry['bytes']} expected) — the file "
            "was corrupted after export; re-stage it")
    return np.load(io.BytesIO(data))


# --------------------------------------------------------------------------
# chunked data sources (the out-of-core record stream)
# --------------------------------------------------------------------------
@runtime_checkable
class DataSource(Protocol):
    """A re-iterable chunked dataset: raw float features + labels.

    ``chunks(rows)`` yields ``(X_chunk, y_chunk)`` numpy pairs with
    ``X_chunk`` of shape (<= rows, n_fields) float (NaN == missing) and
    ``y_chunk`` aligned labels (or ``None`` for unlabeled sources).  The
    iterator must be restartable — streaming training performs one pass
    per tree level — and successive passes must yield identical chunks in
    identical order.
    """

    @property
    def n_fields(self) -> int: ...

    def chunks(self, rows: int
               ) -> Iterator[Tuple[np.ndarray, Optional[np.ndarray]]]: ...


@dataclasses.dataclass
class ArraySource:
    """In-memory (X, y) pair presented through the DataSource protocol."""

    X: np.ndarray
    y: Optional[np.ndarray] = None

    def __post_init__(self):
        self.X = np.asarray(self.X)
        if self.X.ndim != 2:
            raise ValueError("ArraySource expects a 2-D feature matrix")
        if self.y is not None:
            self.y = np.asarray(self.y)
            if self.y.shape[0] != self.X.shape[0]:
                raise ValueError(
                    f"X has {self.X.shape[0]} rows but y has "
                    f"{self.y.shape[0]}")

    @property
    def n_rows(self) -> int:
        return self.X.shape[0]

    @property
    def n_fields(self) -> int:
        return self.X.shape[1]

    def chunks(self, rows: int):
        for lo in range(0, self.X.shape[0], rows):
            hi = min(lo + rows, self.X.shape[0])
            yield (self.X[lo:hi],
                   self.y[lo:hi] if self.y is not None else None)


class NpzShardSource:
    """A directory of ``*.npz`` shards, each holding ``X`` (+ optional
    ``y``) arrays.  One shard is resident at a time; shards are re-sliced
    to the requested chunk size, so shard and chunk boundaries need not
    align.  Write shards with :func:`write_npz_shards`."""

    def __init__(self, directory: str, x_key: str = "X", y_key: str = "y",
                 verify: bool = True):
        self.directory = str(directory)
        self.x_key, self.y_key = x_key, y_key
        self.paths = sorted(glob.glob(os.path.join(self.directory, "*.npz")))
        if not self.paths:
            raise FileNotFoundError(f"no .npz shards under {directory!r}")
        self.manifest = _load_manifest(self.directory) if verify else None
        with _open_verified(self.paths[0], self.manifest) as z:
            if x_key not in z:
                raise KeyError(f"shard {self.paths[0]!r} has no {x_key!r} "
                               f"array (found {sorted(z.files)})")
            self._n_fields = int(z[x_key].shape[1])

    @property
    def n_fields(self) -> int:
        return self._n_fields

    def chunks(self, rows: int):
        for path in self.paths:
            with _open_verified(path, self.manifest) as z:
                if self.x_key not in z:
                    raise KeyError(
                        f"shard {path!r} has no {self.x_key!r} array "
                        f"(found {sorted(z.files)})")
                X = z[self.x_key]
                y = z[self.y_key] if self.y_key in z.files else None
            if X.ndim != 2 or X.shape[1] != self._n_fields:
                raise ValueError(
                    f"shard {path!r} has X of shape {X.shape}; expected "
                    f"(*, {self._n_fields}) to match the first shard — "
                    "mixed-width shard directories cannot feed one model")
            if y is not None and y.shape[0] != X.shape[0]:
                raise ValueError(
                    f"shard {path!r} has {X.shape[0]} rows of X but "
                    f"{y.shape[0]} labels")
            for lo in range(0, X.shape[0], rows):
                hi = min(lo + rows, X.shape[0])
                yield X[lo:hi], (y[lo:hi] if y is not None else None)


def write_npz_shards(directory: str, source: "DataSource",
                     rows_per_shard: int = 65536) -> list:
    """Materialize a DataSource as a directory of npz shards; returns the
    shard paths.  The inverse of :class:`NpzShardSource` — used to stage a
    generator-backed dataset onto disk once, then train out-of-core.

    Pre-existing ``*.npz`` files in the directory are removed first: the
    directory IS the dataset (``NpzShardSource`` globs every shard), so a
    shorter re-export must not leave stale shards mixed in.  A crc32
    ``manifest.json`` sidecar is written last; readers verify every shard
    against it.
    """
    os.makedirs(directory, exist_ok=True)
    for stale in glob.glob(os.path.join(directory, "*.npz")):
        os.remove(stale)
    paths = []
    for i, (X, y) in enumerate(source.chunks(rows_per_shard)):
        path = os.path.join(directory, f"shard_{i:05d}.npz")
        arrays = {"X": np.asarray(X)}
        if y is not None:
            arrays["y"] = np.asarray(y)
        np.savez(path, **arrays)
        paths.append(path)
    write_shard_manifest(directory, paths)
    return paths


def write_binned_shards(directory: str, source: "DataSource", binner,
                        rows_per_shard: int = 65536,
                        packed: Optional[bool] = None) -> list:
    """Bin a DataSource through a *fitted* binner and stage the code
    matrix as npz shards — the compressed working set staged to disk
    once, then re-streamed per level/round without re-binning the raw
    floats (paper §III-B: the binned representation IS the record
    stream).

    When ``packed`` (default: auto — ``binner.max_bins <= 16``) the
    codes are 4-bit nibble-packed on the host, so each shard holds HALF
    the bytes of the plain uint8 codes.  Shard keys: ``codes`` (uint8,
    possibly packed), ``rows`` (logical record count), ``n_fields``,
    ``packed`` flags, and optional ``y``.  Read back with
    :class:`BinnedShardSource`.
    """
    from repro.core.binning import PACK_MAX_BINS, pack_nibbles_np
    if packed is None:
        packed = binner.max_bins <= PACK_MAX_BINS
    elif packed and binner.max_bins > PACK_MAX_BINS:
        raise ValueError(
            f"4-bit packing requires max_bins <= {PACK_MAX_BINS}; "
            f"binner has {binner.max_bins}")
    os.makedirs(directory, exist_ok=True)
    for stale in glob.glob(os.path.join(directory, "*.npz")):
        os.remove(stale)
    paths = []
    for i, (X, y) in enumerate(source.chunks(rows_per_shard)):
        codes = binner.transform_codes(np.asarray(X))
        arrays = {
            "codes": pack_nibbles_np(codes) if packed else codes,
            "rows": np.int64(codes.shape[0]),
            "n_fields": np.int64(codes.shape[1]),
            "packed": np.bool_(packed),
        }
        if y is not None:
            arrays["y"] = np.asarray(y)
        path = os.path.join(directory, f"binned_{i:05d}.npz")
        np.savez(path, **arrays)
        paths.append(path)
    write_shard_manifest(directory, paths)
    return paths


class BinnedShardSource:
    """Chunked stream over shards written by :func:`write_binned_shards`.

    ``chunks(rows)`` yields ``(codes, y)`` with ``codes`` a
    :class:`repro.core.binning.PackedCodes` (host-resident) when the
    shards were written packed, else a plain uint8 array.  Packed shards
    are sliced *without unpacking* — packing is row-major, so a row
    slice of the logical matrix is a row slice of the packed bytes.
    """

    def __init__(self, directory: str, verify: bool = True):
        self.directory = str(directory)
        self.paths = sorted(glob.glob(
            os.path.join(self.directory, "binned_*.npz")))
        if not self.paths:
            raise FileNotFoundError(
                f"no binned_*.npz shards under {directory!r}")
        self.manifest = _load_manifest(self.directory) if verify else None
        with _open_verified(self.paths[0], self.manifest) as z:
            self._n_fields = int(z["n_fields"])
            self.packed = bool(z["packed"])

    @property
    def n_fields(self) -> int:
        return self._n_fields

    def chunks(self, rows: int):
        from repro.core.binning import PackedCodes
        for path in self.paths:
            with _open_verified(path, self.manifest) as z:
                if int(z["n_fields"]) != self._n_fields or \
                        bool(z["packed"]) != self.packed:
                    raise ValueError(
                        f"shard {path!r} has n_fields={int(z['n_fields'])} "
                        f"packed={bool(z['packed'])}; expected "
                        f"n_fields={self._n_fields} packed={self.packed}")
                codes = z["codes"]
                n = int(z["rows"])
                y = z["y"] if "y" in z.files else None
            for lo in range(0, n, rows):
                hi = min(lo + rows, n)
                chunk = (PackedCodes(codes[lo:hi], self._n_fields)
                         if self.packed else codes[lo:hi])
                yield chunk, (y[lo:hi] if y is not None else None)


def as_source(data) -> "DataSource":
    """Coerce ``fit(data=...)`` inputs: a DataSource passes through, an
    ``(X, y)`` tuple wraps as :class:`ArraySource`, a string/path opens an
    :class:`NpzShardSource` directory."""
    if isinstance(data, (str, os.PathLike)):
        return NpzShardSource(data)
    if isinstance(data, tuple) and len(data) == 2:
        return ArraySource(*data)
    if isinstance(data, DataSource):
        return data
    raise TypeError(
        f"cannot build a DataSource from {type(data).__name__}; pass a "
        "DataSource, an (X, y) tuple, or an npz-shard directory path")


class PrefetchIterator:
    """Wrap a host batch generator; keep ``depth`` batches in flight.

    The worker thread blocks on ``queue.put`` once ``depth`` batches are
    staged, so a consumer that abandons the iterator early (exception,
    ``break``) would otherwise leave the thread parked forever holding
    device buffers.  Call :meth:`close` — or use the iterator as a
    context manager — on every early-exit path: it stops the worker,
    drains staged batches, and closes the underlying generator so its
    ``finally`` blocks run.
    """

    def __init__(self, gen: Iterator, shardings=None, depth: int = 2):
        self._gen = gen
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for batch in self._gen:
                if self._stop.is_set():
                    break
                if self._shardings is not None:
                    batch = jax.tree.map(jax.device_put, batch,
                                         self._shardings)
                else:
                    batch = jax.tree.map(jax.device_put, batch)
                self._q.put(batch)
                if self._stop.is_set():
                    break
        except BaseException as e:  # noqa: BLE001 — surfaced on next()
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item

    def close(self):
        """Stop the worker and release staged batches.  Idempotent; safe
        after normal exhaustion too."""
        self._stop.set()
        # drain so a put-blocked worker wakes, sees the stop flag, exits
        while self._thread.is_alive():
            try:
                self._q.get(timeout=0.1)
            except queue.Empty:
                continue
        # empty any leftovers (incl. the _done sentinel) so buffers free
        while True:
            try:
                self._q.get_nowait()
            except queue.Empty:
                break
        close = getattr(self._gen, "close", None)
        if close is not None:
            close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def token_batches(rng: np.random.Generator, vocab: int, batch: int,
                  seq: int, n_batches: int) -> Iterator[dict]:
    """Synthetic LM token stream (tokens/labels shifted by one)."""
    for _ in range(n_batches):
        seqs = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
        yield {"tokens": seqs[:, :-1].astype(np.int32),
               "labels": seqs[:, 1:].astype(np.int32)}


def record_shards(codes: np.ndarray, g: np.ndarray, h: np.ndarray,
                  shard_size: int) -> Iterator[dict]:
    """Stream record blocks of a GBDT dataset (step-① input stream)."""
    n = codes.shape[0]
    for lo in range(0, n, shard_size):
        hi = min(lo + shard_size, n)
        yield {"codes": codes[lo:hi], "g": g[lo:hi], "h": h[lo:hi]}

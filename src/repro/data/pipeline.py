"""Sharded input pipeline with host-side prefetch (double buffering).

The paper's Booster hides the record stream behind double-buffered DMA
(§III-B); at the framework level the analog is a background host thread
that materializes and device_puts the next global batch while the current
step runs.  Works for the GBDT record stream and the LM token stream.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding


class PrefetchIterator:
    """Wrap a host batch generator; keep ``depth`` batches in flight."""

    def __init__(self, gen: Iterator, shardings=None, depth: int = 2):
        self._gen = gen
        self._shardings = shardings
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._done = object()
        self._err: Optional[BaseException] = None
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        try:
            for batch in self._gen:
                if self._shardings is not None:
                    batch = jax.tree.map(jax.device_put, batch,
                                         self._shardings)
                else:
                    batch = jax.tree.map(jax.device_put, batch)
                self._q.put(batch)
        except BaseException as e:  # noqa: BLE001 — surfaced on next()
            self._err = e
        finally:
            self._q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            if self._err is not None:
                raise self._err
            raise StopIteration
        return item


def token_batches(rng: np.random.Generator, vocab: int, batch: int,
                  seq: int, n_batches: int) -> Iterator[dict]:
    """Synthetic LM token stream (tokens/labels shifted by one)."""
    for _ in range(n_batches):
        seqs = rng.integers(0, vocab, (batch, seq + 1), dtype=np.int64)
        yield {"tokens": seqs[:, :-1].astype(np.int32),
               "labels": seqs[:, 1:].astype(np.int32)}


def record_shards(codes: np.ndarray, g: np.ndarray, h: np.ndarray,
                  shard_size: int) -> Iterator[dict]:
    """Stream record blocks of a GBDT dataset (step-① input stream)."""
    n = codes.shape[0]
    for lo in range(0, n, shard_size):
        hi = min(lo + shard_size, n)
        yield {"codes": codes[lo:hi], "g": g[lo:hi], "h": h[lo:hi]}

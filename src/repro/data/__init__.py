from repro.data.synthetic import make_tabular, paper_dataset, PAPER_DATASETS

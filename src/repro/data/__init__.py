from repro.data.pipeline import (ArraySource, DataSource, NpzShardSource,
                                 PrefetchIterator, as_source,
                                 write_npz_shards)
from repro.data.synthetic import (make_tabular, paper_dataset,
                                  PAPER_DATASETS, SyntheticSource)

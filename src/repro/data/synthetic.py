"""Synthetic tabular datasets shaped like the paper's five benchmarks.

The paper's datasets (Table III) are public but not bundled offline, so the
benchmark harness regenerates *shape-faithful* analogs: same field mix
(numeric vs categorical), missing values, and a planted tree-structured
target so GBDT accuracy is meaningfully measurable.  ``scale`` lets the
Fig-12 experiment grow the record count (the paper replicates 10x).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_records: int           # scaled-down default (paper sizes in comments)
    n_numeric: int
    n_categorical: int
    n_cats: int              # categories per categorical field
    task: str                # "binary" | "regression"
    missing_rate: float
    comment: str


# paper Table III, record counts scaled 1000x down for the CPU container;
# benchmarks scale back up via the ``scale`` argument.
PAPER_DATASETS = {
    "iot": DatasetSpec("iot", 7_000, 115, 0, 0, "binary", 0.0,
                       "Botnet attack detection (7M records full-scale)"),
    "higgs": DatasetSpec("higgs", 10_000, 28, 0, 0, "binary", 0.0,
                         "Exotic particle collider data (10M full-scale)"),
    "allstate": DatasetSpec("allstate", 10_000, 16, 16, 40, "regression",
                            0.05, "Insurance claims (10M; 16 categorical)"),
    "mq2008": DatasetSpec("mq2008", 1_000, 46, 0, 0, "regression", 0.0,
                          "Supervised ranking (1M full-scale)"),
    "flight": DatasetSpec("flight", 10_000, 1, 7, 95, "binary", 0.02,
                          "Flight delay prediction (10M; 7 categorical)"),
}


def make_tabular(n: int, n_numeric: int, n_categorical: int = 0,
                 n_cats: int = 8, task: str = "regression",
                 missing_rate: float = 0.0, seed: int = 0,
                 n_classes: int = 4,
                 ) -> Tuple[np.ndarray, np.ndarray, list]:
    """Returns (X, y, categorical_field_ids); NaN marks missing values.

    The target is a random shallow-tree function of a feature subset plus
    noise — learnable by GBDT, so accuracy assertions are meaningful.
    ``task="multiclass"`` draws integer labels 0..n_classes-1 from a
    per-class margin softmax (roughly balanced classes, so the
    majority-class baseline sits near 1/n_classes).
    """
    rng = np.random.default_rng(seed)
    F = n_numeric + n_categorical
    X = np.empty((n, F), dtype=np.float64)
    X[:, :n_numeric] = rng.normal(size=(n, n_numeric))
    cat_ids = list(range(n_numeric, F))
    for f in cat_ids:
        X[:, f] = rng.integers(0, n_cats, size=n)

    # planted piecewise-constant target over a handful of fields
    margin = np.zeros(n)
    k = min(F, 6)
    picks = rng.choice(F, size=k, replace=False)
    for f in picks:
        if f in cat_ids:
            vals = rng.normal(size=n_cats)
            margin += vals[X[:, f].astype(int)]
        else:
            thr = rng.normal()
            margin += np.where(X[:, f] > thr, rng.normal(), rng.normal())
        # second-order interaction with the previous field
    margin += 0.5 * np.sin(X[:, picks[0]] * 2.0) * (X[:, picks[-1]] > 0)
    margin += 0.1 * rng.normal(size=n)

    if task == "binary":
        p = 1.0 / (1.0 + np.exp(-margin))
        y = (rng.uniform(size=n) < p).astype(np.float64)
    elif task == "multiclass":
        # per-class planted margins over the same field subset
        m = np.zeros((n, n_classes))
        for c in range(n_classes):
            for f in picks:
                if f in cat_ids:
                    vals = rng.normal(size=n_cats)
                    m[:, c] += vals[np.nan_to_num(X[:, f]).astype(int)]
                else:
                    thr = rng.normal()
                    m[:, c] += np.where(X[:, f] > thr, rng.normal(),
                                        rng.normal())
        m = 2.0 * (m - m.mean(axis=0, keepdims=True))
        z = np.exp(m - m.max(axis=1, keepdims=True))
        p = z / z.sum(axis=1, keepdims=True)
        y = (p.cumsum(axis=1) < rng.uniform(size=(n, 1))).sum(
            axis=1).astype(np.float64)
    else:
        y = margin

    if missing_rate > 0:
        miss = rng.uniform(size=X.shape) < missing_rate
        X[miss] = np.nan
    return X, y, cat_ids


class SyntheticSource:
    """Deterministic larger-than-memory synthetic stream (DataSource).

    A planted piecewise-constant target is drawn ONCE at construction;
    feature rows are then (re)generated per fixed-size internal block from
    counter-based RNG streams, so every pass — and every chunking — yields
    bit-identical data without ever materializing the (n_rows, n_fields)
    matrix.  This is the ``data=`` source the out-of-core benchmarks use
    to exceed device memory at will.
    """

    _BLOCK = 4096        # internal generation granularity (chunk-invariant)

    def __init__(self, n_rows: int, n_fields: int, task: str = "regression",
                 noise: float = 0.1, missing_rate: float = 0.0,
                 seed: int = 0):
        if task not in ("regression", "binary"):
            raise ValueError(f"unknown task {task!r}")
        self.n_rows, self._n_fields = int(n_rows), int(n_fields)
        self.task, self.noise, self.missing_rate = task, noise, missing_rate
        self.seed = seed
        rng = np.random.default_rng(seed)
        k = min(n_fields, 6)
        self._picks = rng.choice(n_fields, size=k, replace=False)
        self._thr = rng.normal(size=k)
        self._w_left = rng.normal(size=k)
        self._w_right = rng.normal(size=k)

    @property
    def n_fields(self) -> int:
        return self._n_fields

    def _block(self, b: int) -> Tuple[np.ndarray, np.ndarray]:
        lo = b * self._BLOCK
        rows = min(self._BLOCK, self.n_rows - lo)
        rng = np.random.default_rng([self.seed, 7919, b])
        X = rng.normal(size=(rows, self._n_fields))
        margin = np.zeros(rows)
        for j, f in enumerate(self._picks):
            margin += np.where(X[:, f] > self._thr[j], self._w_right[j],
                               self._w_left[j])
        margin += 0.5 * np.sin(2.0 * X[:, self._picks[0]]) * (
            X[:, self._picks[-1]] > 0)
        margin += self.noise * rng.normal(size=rows)
        if self.task == "binary":
            p = 1.0 / (1.0 + np.exp(-margin))
            y = (rng.uniform(size=rows) < p).astype(np.float64)
        else:
            y = margin
        if self.missing_rate > 0:
            miss = rng.uniform(size=X.shape) < self.missing_rate
            X[miss] = np.nan
        return X, y

    def chunks(self, rows: int):
        """Yield (X, y) chunks of ``rows`` rows, assembled from the fixed
        internal blocks so the stream is chunk-size invariant."""
        n_blocks = -(-self.n_rows // self._BLOCK)
        bx, by = [], []
        have = 0
        for b in range(n_blocks):
            X, y = self._block(b)
            bx.append(X)
            by.append(y)
            have += X.shape[0]
            while have >= rows:
                X_all = np.concatenate(bx) if len(bx) > 1 else bx[0]
                y_all = np.concatenate(by) if len(by) > 1 else by[0]
                yield X_all[:rows], y_all[:rows]
                bx, by = [X_all[rows:]], [y_all[rows:]]
                have -= rows
        if have > 0:
            yield (np.concatenate(bx) if len(bx) > 1 else bx[0],
                   np.concatenate(by) if len(by) > 1 else by[0])


def paper_dataset(name: str, scale: float = 1.0, seed: int = 0,
                  n_override: Optional[int] = None):
    """Instantiate a paper-benchmark analog; returns (X, y, cat_ids, spec)."""
    spec = PAPER_DATASETS[name]
    n = n_override if n_override is not None else int(spec.n_records * scale)
    X, y, cat_ids = make_tabular(
        n, spec.n_numeric, spec.n_categorical, max(spec.n_cats, 2),
        task=spec.task, missing_rate=spec.missing_rate, seed=seed)
    return X, y, cat_ids, spec

"""Synthetic tabular datasets shaped like the paper's five benchmarks.

The paper's datasets (Table III) are public but not bundled offline, so the
benchmark harness regenerates *shape-faithful* analogs: same field mix
(numeric vs categorical), missing values, and a planted tree-structured
target so GBDT accuracy is meaningfully measurable.  ``scale`` lets the
Fig-12 experiment grow the record count (the paper replicates 10x).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_records: int           # scaled-down default (paper sizes in comments)
    n_numeric: int
    n_categorical: int
    n_cats: int              # categories per categorical field
    task: str                # "binary" | "regression"
    missing_rate: float
    comment: str


# paper Table III, record counts scaled 1000x down for the CPU container;
# benchmarks scale back up via the ``scale`` argument.
PAPER_DATASETS = {
    "iot": DatasetSpec("iot", 7_000, 115, 0, 0, "binary", 0.0,
                       "Botnet attack detection (7M records full-scale)"),
    "higgs": DatasetSpec("higgs", 10_000, 28, 0, 0, "binary", 0.0,
                         "Exotic particle collider data (10M full-scale)"),
    "allstate": DatasetSpec("allstate", 10_000, 16, 16, 40, "regression",
                            0.05, "Insurance claims (10M; 16 categorical)"),
    "mq2008": DatasetSpec("mq2008", 1_000, 46, 0, 0, "regression", 0.0,
                          "Supervised ranking (1M full-scale)"),
    "flight": DatasetSpec("flight", 10_000, 1, 7, 95, "binary", 0.02,
                          "Flight delay prediction (10M; 7 categorical)"),
}


def make_tabular(n: int, n_numeric: int, n_categorical: int = 0,
                 n_cats: int = 8, task: str = "regression",
                 missing_rate: float = 0.0, seed: int = 0,
                 n_classes: int = 4,
                 ) -> Tuple[np.ndarray, np.ndarray, list]:
    """Returns (X, y, categorical_field_ids); NaN marks missing values.

    The target is a random shallow-tree function of a feature subset plus
    noise — learnable by GBDT, so accuracy assertions are meaningful.
    ``task="multiclass"`` draws integer labels 0..n_classes-1 from a
    per-class margin softmax (roughly balanced classes, so the
    majority-class baseline sits near 1/n_classes).
    """
    rng = np.random.default_rng(seed)
    F = n_numeric + n_categorical
    X = np.empty((n, F), dtype=np.float64)
    X[:, :n_numeric] = rng.normal(size=(n, n_numeric))
    cat_ids = list(range(n_numeric, F))
    for f in cat_ids:
        X[:, f] = rng.integers(0, n_cats, size=n)

    # planted piecewise-constant target over a handful of fields
    margin = np.zeros(n)
    k = min(F, 6)
    picks = rng.choice(F, size=k, replace=False)
    for f in picks:
        if f in cat_ids:
            vals = rng.normal(size=n_cats)
            margin += vals[X[:, f].astype(int)]
        else:
            thr = rng.normal()
            margin += np.where(X[:, f] > thr, rng.normal(), rng.normal())
        # second-order interaction with the previous field
    margin += 0.5 * np.sin(X[:, picks[0]] * 2.0) * (X[:, picks[-1]] > 0)
    margin += 0.1 * rng.normal(size=n)

    if task == "binary":
        p = 1.0 / (1.0 + np.exp(-margin))
        y = (rng.uniform(size=n) < p).astype(np.float64)
    elif task == "multiclass":
        # per-class planted margins over the same field subset
        m = np.zeros((n, n_classes))
        for c in range(n_classes):
            for f in picks:
                if f in cat_ids:
                    vals = rng.normal(size=n_cats)
                    m[:, c] += vals[np.nan_to_num(X[:, f]).astype(int)]
                else:
                    thr = rng.normal()
                    m[:, c] += np.where(X[:, f] > thr, rng.normal(),
                                        rng.normal())
        m = 2.0 * (m - m.mean(axis=0, keepdims=True))
        z = np.exp(m - m.max(axis=1, keepdims=True))
        p = z / z.sum(axis=1, keepdims=True)
        y = (p.cumsum(axis=1) < rng.uniform(size=(n, 1))).sum(
            axis=1).astype(np.float64)
    else:
        y = margin

    if missing_rate > 0:
        miss = rng.uniform(size=X.shape) < missing_rate
        X[miss] = np.nan
    return X, y, cat_ids


def paper_dataset(name: str, scale: float = 1.0, seed: int = 0,
                  n_override: Optional[int] = None):
    """Instantiate a paper-benchmark analog; returns (X, y, cat_ids, spec)."""
    spec = PAPER_DATASETS[name]
    n = n_override if n_override is not None else int(spec.n_records * scale)
    X, y, cat_ids = make_tabular(
        n, spec.n_numeric, spec.n_categorical, max(spec.n_cats, 2),
        task=spec.task, missing_rate=spec.missing_rate, seed=seed)
    return X, y, cat_ids, spec

"""Pallas TPU kernel for step ① — gradient-statistics histogram binning.

This is the TPU-native re-expression of Booster's sea-of-small-SRAMs +
group-by-field mapping (paper §III-A/B):

  * The paper gives every *field* its own 2-KB SRAM so that each streamed
    record performs exactly one read-modify-write per SRAM.  A TPU has no
    independently addressable small memories, but it has an MXU that performs
    a 128x128 systolic contraction per cycle.  We therefore turn the
    irregular ``hist[node, bin] += (g, h)`` scatter into a *dense* one-hot
    contraction per field:

        hist_f (NB, NN*2)  +=  one_hot(codes[:, f], NB)^T  @  stats_node

    where ``stats_node[r] = one_hot(node[r], NN) ⊗ (g[r], h[r])`` carries the
    per-record (g,h) pre-spread over the record's tree-node slot.  The MXU
    plays the role of the 3200 parallel FP adders.

  * Group-by-field becomes a *BlockSpec* statement: the grid tiles the field
    dimension so one grid cell owns ``FBLK`` whole fields, and the VMEM
    accumulator tile ``(FBLK, NB, NN*2)`` keeps *all bins of a field
    together* — one small matmul per field per record-block, never a bin tile
    shared between fields.

  * The record stream is the grid's fast axis; Pallas double-buffers the
    HBM→VMEM block DMA exactly like the paper's double-buffered record fetch
    (§III-B), so compute hides under the memory stream.

A ``packed`` variant reproduces the paper's *naive packing* baseline
(Fig 9 ablation): bins of all ``FBLK`` fields are packed into a single
``FBLK*NB``-wide one-hot tile.  MAC count is identical but the transient
one-hot tile is ``FBLK``× larger, which on real hardware forces smaller
record blocks / fewer resident fields — the VMEM-pressure analog of the
paper's serialized SRAM accesses.

When the codes arrive 4-bit packed (:class:`repro.core.binning.PackedCodes`
— paper §III-B's compressed representation), the grouped kernel streams
the packed *bytes* through the BlockSpec pipeline and unpacks the nibbles
in VMEM per block: the HBM→VMEM code traffic halves while the contraction
math — and therefore the histogram, bit for bit — is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.core.binning import PackedCodes


def _iota(shape, dim):
    return lax.broadcasted_iota(jnp.int32, shape, dim)


def _stats_node(node_ref, g_ref, h_ref, n_nodes: int):
    """(RBLK, K*NN*2) outer-product spread of (g, h) over node slots.

    The class axis K (multi-class boosting: one tree per class per round,
    each with its own node partition) widens the stats operand of the
    one-hot contraction — the record/code stream is read ONCE and a single
    K*NN*2-wide matmul accumulates every class's (g, h), preserving the
    paper's field→SRAM bandwidth mapping at K× arithmetic intensity."""
    rblk, K = node_ref.shape
    node = node_ref[...].astype(jnp.int32)                  # (RBLK, K)
    oh_node = (node[:, :, None] == _iota((rblk, K, n_nodes), 2)
               ).astype(jnp.float32)                        # (RBLK, K, NN)
    stats = jnp.stack(
        [g_ref[...].astype(jnp.float32), h_ref[...].astype(jnp.float32)],
        axis=2)                                             # (RBLK, K, 2)
    sn = oh_node[:, :, :, None] * stats[:, :, None, :]      # (RBLK, K, NN, 2)
    return sn.reshape(rblk, K * n_nodes * 2)


def _hist_kernel_grouped(codes_ref, node_ref, g_ref, h_ref, hist_ref, *,
                         n_bins: int, n_nodes: int, nibble_packed: bool):
    """Group-by-field: every field owns its own (RBLK, NB) one-hot tile
    and its own bin rows of the accumulator, contracted against the
    shared stats operand in ONE field-batched dot — not a Python-unrolled
    per-field matmul chain, which serialized the kernel into ``FBLK``
    dependent MXU issues per block.

    ``nibble_packed``: the code block arrives as packed bytes
    (RBLK, FBLK/2) and is unpacked to nibbles here, in VMEM — the block
    DMA from HBM moves half the bytes."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    raw = codes_ref[...]
    if nibble_packed:
        raw = jnp.stack([raw & 0xF, raw >> 4],
                        axis=-1).reshape(raw.shape[0], -1)  # (RBLK, FBLK)
    rblk, fblk = raw.shape
    codes = raw.astype(jnp.int32)                           # (RBLK, FBLK)
    sn = _stats_node(node_ref, g_ref, h_ref, n_nodes)       # (RBLK, NN*2)
    oh_bin = (codes[:, :, None] == _iota((rblk, fblk, n_bins), 2)
              ).astype(jnp.float32)                         # (RBLK, FBLK, NB)
    # contract the record axis once for all FBLK fields: (FBLK, NB, NN*2)
    contrib = lax.dot_general(oh_bin, sn, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    hist_ref[...] += contrib


def _hist_kernel_packed(codes_ref, node_ref, g_ref, h_ref, hist_ref, *,
                        n_bins: int, n_nodes: int):
    """Naive packing baseline: single FBLK*NB-wide one-hot tile (Fig 9)."""
    @pl.when(pl.program_id(1) == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    rblk, fblk = codes_ref.shape
    codes = codes_ref[...].astype(jnp.int32)
    sn = _stats_node(node_ref, g_ref, h_ref, n_nodes)
    oh = (codes[:, :, None] == _iota((rblk, fblk, n_bins), 2)
          ).astype(jnp.float32).reshape(rblk, fblk * n_bins)
    flat = lax.dot_general(oh, sn, (((0,), (0,)), ((), ())),
                           preferred_element_type=jnp.float32)
    hist_ref[...] += flat.reshape(fblk, n_bins, sn.shape[1])


@functools.partial(
    jax.jit,
    static_argnames=("n_nodes", "n_bins", "records_per_block",
                     "fields_per_block", "packed", "interpret"))
def histogram_pallas(codes, g, h, node_ids, *, n_nodes: int, n_bins: int,
                     records_per_block: int = 512, fields_per_block: int = 8,
                     packed: bool = False, interpret: bool = True):
    """Histogram binning via the one-hot MXU kernel.

    codes: (n, F) uint8, or a :class:`PackedCodes` carrying the same
    logical (n, F) as 4-bit nibbles (grouped kernel only — the packed
    bytes are streamed through the BlockSpec pipeline and unpacked in
    VMEM, halving the HBM code traffic); g, h: (n,) float; node_ids:
    (n,) int32.  Returns (n_nodes, F, n_bins, 2) float32.  Inputs are
    padded to block multiples here (padded records carry g = h = 0 → no
    contribution).

    Class-batched form: g, h, node_ids may carry a leading class axis
    (K, n) — one launch then reads codes once and accumulates all K
    classes' statistics through a K*NN*2-wide stats operand, returning
    (K, n_nodes, F, n_bins, 2).
    """
    nibble = isinstance(codes, PackedCodes)
    if nibble and packed:
        # the Fig-9 naive-packing ablation keeps its historical uint8 feed
        codes, nibble = codes.unpack(), False

    batched = g.ndim == 2
    K = g.shape[0] if batched else 1
    # kernel-facing layout: records major, classes minor — (n, K) columns
    g2 = g.T if batched else g[:, None]
    h2 = h.T if batched else h[:, None]
    node2 = node_ids.T if batched else node_ids[:, None]

    n, F = codes.shape
    rblk = min(records_per_block, max(8, n))
    fblk = min(fields_per_block, F)
    if nibble and fblk % 2:
        fblk += 1          # nibble blocks cover whole packed bytes
    n_pad = -n % rblk
    f_pad = -F % fblk
    g2 = jnp.pad(g2, ((0, n_pad), (0, 0)))
    h2 = jnp.pad(h2, ((0, n_pad), (0, 0)))
    node2 = jnp.pad(node2, ((0, n_pad), (0, 0)))
    Fp = F + f_pad
    np_ = n + n_pad
    grid = (Fp // fblk, np_ // rblk)  # fields outer, record stream inner

    if nibble:
        # pad the packed BYTES; pad fields unpack to code 0 and only feed
        # the sliced-off hist rows >= F, pad records carry zero stats
        data = codes.data
        code_op = jnp.pad(data, ((0, n_pad), (0, Fp // 2 - data.shape[1])))
        code_spec = pl.BlockSpec((rblk, fblk // 2), lambda fi, ri: (ri, fi))
    else:
        code_op = jnp.pad(codes, ((0, n_pad), (0, f_pad)))
        code_spec = pl.BlockSpec((rblk, fblk), lambda fi, ri: (ri, fi))

    if packed:
        kernel = functools.partial(_hist_kernel_packed, n_bins=n_bins,
                                   n_nodes=n_nodes)
    else:
        kernel = functools.partial(_hist_kernel_grouped, n_bins=n_bins,
                                   n_nodes=n_nodes, nibble_packed=nibble)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            code_spec,
            pl.BlockSpec((rblk, K), lambda fi, ri: (ri, 0)),
            pl.BlockSpec((rblk, K), lambda fi, ri: (ri, 0)),
            pl.BlockSpec((rblk, K), lambda fi, ri: (ri, 0)),
        ],
        out_specs=pl.BlockSpec((fblk, n_bins, K * n_nodes * 2),
                               lambda fi, ri: (fi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((Fp, n_bins, K * n_nodes * 2),
                                       jnp.float32),
        interpret=interpret,
    )(code_op, node2, g2, h2)

    hist = out[:F].reshape(F, n_bins, K, n_nodes, 2)
    hist = hist.transpose(2, 3, 0, 1, 4)            # (K, NN, F, NB, 2)
    return hist if batched else hist[0]

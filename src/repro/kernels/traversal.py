"""Pallas TPU kernels for step ⑤ (one-tree traversal) and batch inference.

Paper §III-B maps the grown tree to a table replicated in every BU's SRAM;
each record walks the table with data-dependent reads.  The walk here is
expressed over a *packed* node table:

  * the four per-node parameters are packed into ONE int32 word
    ``((feat+1) << 16) | (thr << 8) | (cat << 1) | dl`` (bin codes are
    uint8 and field counts < 2**15 — the repo's binning invariants — so
    the pack is lossless), and the whole packed table (≤ a few hundred
    bytes — the paper's own SRAM-residency argument) lives in VMEM,
    *replicated across grid steps* via a constant index_map, exactly like
    the paper replicates the tree per BU;
  * per hop, every record fetches its node word with one table gather and
    its field value with one code gather — two VMEM reads per level for a
    whole (RBLK, TBLK) node matrix, instead of the per-record one-hot MXU
    contractions the first kernel generation used (those serialized the
    walk into TBLK dependent matmul chains and lost to the jitted
    reference walk by an order of magnitude);
  * child pointers are implicit (node <- 2*node + 2 - go_left), so a D-hop
    walk is D dense vector steps, zero irregular HBM accesses.

Batch inference (§III-D) adds a tree grid dimension: record blocks stream
while each grid step holds a *block* of ``trees_per_block`` packed tables
resident, walking all of them simultaneously over one (RBLK, TBLK) node
matrix and accumulating the ensemble sum in the revisited output block —
the analog of Booster pinning one tree per BU and averaging load across
records.  Tree-blocking amortizes each record block fetched into VMEM
across ``trees_per_block`` walks, cutting the code-stream traffic from T
reads per record to ``T / trees_per_block``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.ref import TreeArrays


def _iota(shape, dim):
    return lax.broadcasted_iota(jnp.int32, shape, dim)


def pack_node_table(tree: TreeArrays) -> jax.Array:
    """(N_int,) int32 packed node words.

    ``((feature+1) << 16) | (threshold << 8) | (is_cat << 1) |
    default_left`` — one word per internal node, so each walk hop costs a
    single table gather instead of four.
    """
    return (((tree.feature.astype(jnp.int32) + 1) << 16)
            | (tree.threshold.astype(jnp.int32) << 8)
            | (tree.is_cat.astype(jnp.int32) << 1)
            | tree.default_left.astype(jnp.int32))


def _walk_levels(codes, table_t, depth: int, missing_bin: int):
    """Walk a (RBLK, TBLK) node matrix ``depth`` levels down.

    ``codes``: (RBLK, n_cols) int32; ``table_t``: (N_int, TBLK) packed
    node words, one column per resident tree.  Returns the final node
    matrix (values in [N_int, N_int + N_leaf)).  Decisions are
    integer-exact, so the walk agrees bit-for-bit with the reference.
    """
    rblk = codes.shape[0]
    tblk = table_t.shape[1]
    node = jnp.zeros((rblk, tblk), jnp.int32)
    for _ in range(depth):  # static: fixed-depth walk, paper §III-B
        p = jnp.take_along_axis(table_t, node, axis=0)        # (RBLK, TBLK)
        f = (p >> 16) - 1
        code = jnp.take_along_axis(codes, jnp.maximum(f, 0), axis=1)
        thr = (p >> 8) & 255
        go_left = jnp.where((p & 2) != 0, code == thr, code <= thr)
        go_left = jnp.where(code == missing_bin, (p & 1) == 1, go_left)
        go_left = jnp.where(f < 0, True, go_left)             # pass-through
        node = 2 * node + 2 - go_left.astype(jnp.int32)
    return node


def _traverse_kernel(codes_ref, table_ref, leaf_ref, out_ref, *,
                     depth: int, missing_bin: int):
    codes = codes_ref[...].astype(jnp.int32)
    table_t = table_ref[...]                                  # (N_int, 1)
    node = _walk_levels(codes, table_t, depth, missing_bin)
    leaf = node - table_t.shape[0]
    out_ref[...] = jnp.take_along_axis(leaf_ref[...], leaf, axis=0)


@functools.partial(jax.jit, static_argnames=("missing_bin",
                                             "records_per_block", "interpret"))
def traverse_pallas(tree: TreeArrays, codes, *, missing_bin: int,
                    records_per_block: int = 1024, interpret: bool = True):
    """One-tree traversal; codes (n, C) with C matching tree.feature ids.

    Returns (n,) float32 leaf values.
    """
    n, n_cols = codes.shape
    rblk = min(records_per_block, max(8, n))
    n_pad = -n % rblk
    codes = jnp.pad(codes, ((0, n_pad), (0, 0)))
    np_ = codes.shape[0]
    n_int = tree.feature.shape[0]
    n_leaf = tree.leaf_value.shape[0]
    out = pl.pallas_call(
        functools.partial(_traverse_kernel, depth=tree.depth,
                          missing_bin=missing_bin),
        grid=(np_ // rblk,),
        in_specs=[
            pl.BlockSpec((rblk, n_cols), lambda ri: (ri, 0)),
            pl.BlockSpec((n_int, 1), lambda ri: (0, 0)),      # replicated
            pl.BlockSpec((n_leaf, 1), lambda ri: (0, 0)),     # replicated
        ],
        out_specs=pl.BlockSpec((rblk, 1), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(codes, pack_node_table(tree)[:, None],
      tree.leaf_value.astype(jnp.float32)[:, None])
    return out[:n, 0]


def _ensemble_kernel(codes_ref, table_ref, leaf_ref, out_ref, *,
                     depth: int, missing_bin: int, n_classes: int,
                     trees_per_block: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    codes = codes_ref[...].astype(jnp.int32)
    # the codes block is fetched ONCE and walked by the whole resident
    # tree block at once (paper: one record stream shared by all BUs):
    # a (RBLK, TBLK) node matrix advances one level per hop, two gathers
    # per hop for every resident tree together
    table_t = table_ref[...].T                                # (N_int, TBLK)
    node = _walk_levels(codes, table_t, depth, missing_bin)
    leaf_t = leaf_ref[...].T                                  # (N_leaf, TBLK)
    vals = jnp.take_along_axis(leaf_t, node - table_t.shape[0],
                               axis=0)                        # (RBLK, TBLK)
    # multi-class: round-major tree order, tree t owns margin column
    # t % K; a one-hot class route folds the tree block into class
    # columns (K == 1: a plain row-sum).  Zero-leaf padding trees
    # contribute exactly 0.
    tblk = table_t.shape[1]
    t0 = pl.program_id(1) * trees_per_block
    cls = (t0 + _iota((tblk, n_classes), 0)) % n_classes
    oh_cls = (cls == _iota((tblk, n_classes), 1)).astype(jnp.float32)
    out_ref[...] += lax.dot_general(vals, oh_cls, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("missing_bin", "depth",
                                             "records_per_block", "interpret",
                                             "n_classes", "trees_per_block"))
def predict_ensemble_pallas(trees: TreeArrays, codes, *, missing_bin: int,
                            depth: int, records_per_block: int = 1024,
                            interpret: bool = True, n_classes: int = 1,
                            trees_per_block: int = 8):
    """Batch inference: trees hold stacked (T, ...) arrays; codes (n, F).

    Grid = (record blocks, T / trees_per_block): each step holds a block
    of ``trees_per_block`` packed int32 node tables resident in VMEM
    (paper: one tree per BU, here a BU block per grid step) and
    accumulates into the revisited output block — each record block read
    is amortized across the whole tree block, and the block walks as ONE
    (RBLK, TBLK) node matrix (two gathers per level) rather than
    ``trees_per_block`` serial per-tree chains.  The ensemble is
    zero-padded (pass-through trees with all-zero leaves) up to a
    multiple of ``trees_per_block``; padding contributes exactly +0.0.
    Requires fewer than 2**15 code columns (the int32 table pack — the
    repo's binning invariant; ``gbdt`` renumbers wider matrices before
    dispatching here).  Returns (n,) float32 ensemble sums — or (n, K)
    per-class margins when ``n_classes > 1`` (trees round-major; tree t
    feeds class t % K via a one-hot column route, so the walk itself is
    unchanged).
    """
    n, n_cols = codes.shape
    T = trees.feature.shape[0]
    tblk = min(trees_per_block, T)
    t_pad = -T % tblk
    if t_pad:
        trees = TreeArrays(
            feature=jnp.pad(trees.feature, ((0, t_pad), (0, 0)),
                            constant_values=-1),
            threshold=jnp.pad(trees.threshold, ((0, t_pad), (0, 0))),
            is_cat=jnp.pad(trees.is_cat, ((0, t_pad), (0, 0))),
            default_left=jnp.pad(trees.default_left, ((0, t_pad), (0, 0))),
            leaf_value=jnp.pad(trees.leaf_value, ((0, t_pad), (0, 0))))
    rblk = min(records_per_block, max(8, n))
    n_pad = -n % rblk
    codes = jnp.pad(codes, ((0, n_pad), (0, 0)))
    np_ = codes.shape[0]
    n_int = trees.feature.shape[1]
    n_leaf = trees.leaf_value.shape[1]
    tables = pack_node_table(trees)                           # (T', N_int)
    out = pl.pallas_call(
        functools.partial(_ensemble_kernel, depth=depth,
                          missing_bin=missing_bin, n_classes=n_classes,
                          trees_per_block=tblk),
        grid=(np_ // rblk, (T + t_pad) // tblk),
        in_specs=[
            pl.BlockSpec((rblk, n_cols), lambda ri, ti: (ri, 0)),
            pl.BlockSpec((tblk, n_int), lambda ri, ti: (ti, 0)),
            pl.BlockSpec((tblk, n_leaf), lambda ri, ti: (ti, 0)),
        ],
        out_specs=pl.BlockSpec((rblk, n_classes), lambda ri, ti: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, n_classes), jnp.float32),
        interpret=interpret,
    )(codes, tables, trees.leaf_value.astype(jnp.float32))
    return out[:n, 0] if n_classes == 1 else out[:n]

"""Pallas TPU kernels for step ⑤ (one-tree traversal) and batch inference.

Paper §III-B maps the grown tree to a table replicated in every BU's SRAM;
each record walks the table with data-dependent reads.  A TPU lane cannot do
independent VMEM gathers, so the walk is re-expressed gather-free:

  * the whole node table (≤ 2 KB — the paper's own SRAM-residency argument)
    lives in VMEM and is *replicated across grid steps* via a constant
    index_map, exactly like the paper replicates the tree per BU;
  * per hop, the record's node parameters are fetched with a one-hot MXU
    contraction ``one_hot(node) @ table`` and the record's field value with a
    one-hot row-reduction — the same renumbered-field trick as §III-B (the
    table stores *compacted* field indices into the fetched columns);
  * child pointers are implicit (node <- 2*node + 1 + go_right), so a D-hop
    walk is D dense vector steps, zero irregular accesses.

Batch inference (§III-D) adds a tree grid dimension: record blocks stream
while each grid step holds a *block* of ``trees_per_block`` tree tables
resident, accumulating the ensemble sum in the revisited output block —
the analog of Booster pinning one tree per BU and averaging load across
records.  Tree-blocking amortizes each record block fetched into VMEM
across ``trees_per_block`` walks (the same trick the histogram kernel
uses to class-batch stats), cutting the code-stream traffic from T reads
per record to ``T / trees_per_block``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.ref import TreeArrays


def _iota(shape, dim):
    return lax.broadcasted_iota(jnp.int32, shape, dim)


def _iota_f(shape, dim):
    return lax.broadcasted_iota(jnp.float32, shape, dim)


def pack_node_table(tree: TreeArrays) -> jax.Array:
    """(N_int, 4) float32 [feature, threshold, is_cat, default_left].

    All entries are small integers — exact in f32, which lets a single MXU
    matmul fetch all four per-record node parameters at once.
    """
    return jnp.stack(
        [tree.feature, tree.threshold, tree.is_cat, tree.default_left],
        axis=1).astype(jnp.float32)


def _walk_step(node, codes_f32, table, missing_bin: float):
    """One tree hop for a (RBLK, 1) vector of node indices (gather-free)."""
    rblk = node.shape[0]
    n_int = table.shape[0]
    n_cols = codes_f32.shape[1]
    oh_node = (node == _iota((rblk, n_int), 1)).astype(jnp.float32)
    params = lax.dot_general(oh_node, table, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (RBLK, 4)
    f = params[:, 0:1]
    thr = params[:, 1:2]
    cat = params[:, 2:3]
    dl = params[:, 3:4]
    oh_f = (f == _iota_f((rblk, n_cols), 1)).astype(jnp.float32)
    code = jnp.sum(oh_f * codes_f32, axis=1, keepdims=True)     # (RBLK, 1)
    go_left = jnp.where(cat == 1.0, code == thr, code <= thr)
    go_left = jnp.where(code == missing_bin, dl == 1.0, go_left)
    go_left = jnp.where(f < 0.0, True, go_left)
    return 2 * node + 2 - go_left.astype(jnp.int32)


def _traverse_kernel(codes_ref, table_ref, leaf_ref, out_ref, *,
                     depth: int, missing_bin: int):
    rblk = codes_ref.shape[0]
    codes = codes_ref[...].astype(jnp.float32)
    table = table_ref[...]
    node = jnp.zeros((rblk, 1), jnp.int32)
    for _ in range(depth):  # static: fixed-depth walk, paper §III-B
        node = _walk_step(node, codes, table, float(missing_bin))
    leaf = node - table.shape[0]
    n_leaf = leaf_ref.shape[0]
    oh_leaf = (leaf == _iota((rblk, n_leaf), 1)).astype(jnp.float32)
    out_ref[...] = lax.dot_general(oh_leaf, leaf_ref[...],
                                   (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("missing_bin",
                                             "records_per_block", "interpret"))
def traverse_pallas(tree: TreeArrays, codes, *, missing_bin: int,
                    records_per_block: int = 1024, interpret: bool = True):
    """One-tree traversal; codes (n, C) with C matching tree.feature ids.

    Returns (n,) float32 leaf values.
    """
    n, n_cols = codes.shape
    rblk = min(records_per_block, max(8, n))
    n_pad = -n % rblk
    codes = jnp.pad(codes, ((0, n_pad), (0, 0)))
    np_ = codes.shape[0]
    n_int = tree.feature.shape[0]
    n_leaf = tree.leaf_value.shape[0]
    out = pl.pallas_call(
        functools.partial(_traverse_kernel, depth=tree.depth,
                          missing_bin=missing_bin),
        grid=(np_ // rblk,),
        in_specs=[
            pl.BlockSpec((rblk, n_cols), lambda ri: (ri, 0)),
            pl.BlockSpec((n_int, 4), lambda ri: (0, 0)),      # replicated
            pl.BlockSpec((n_leaf, 1), lambda ri: (0, 0)),     # replicated
        ],
        out_specs=pl.BlockSpec((rblk, 1), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.float32),
        interpret=interpret,
    )(codes, pack_node_table(tree), tree.leaf_value[:, None])
    return out[:n, 0]


def _ensemble_kernel(codes_ref, table_ref, leaf_ref, out_ref, *,
                     depth: int, missing_bin: int, n_classes: int,
                     trees_per_block: int):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    rblk = codes_ref.shape[0]
    codes = codes_ref[...].astype(jnp.float32)
    n_leaf = leaf_ref.shape[1]
    acc = jnp.zeros((rblk, n_classes), jnp.float32)
    # the codes block is fetched ONCE and walked by every resident tree
    # table (paper: one record stream shared by all BUs); the tree loop is
    # static, so each walk is the same D dense vector steps as before
    for tb in range(trees_per_block):
        table = table_ref[tb]                                 # (N_int, 4)
        node = jnp.zeros((rblk, 1), jnp.int32)
        for _ in range(depth):
            node = _walk_step(node, codes, table, float(missing_bin))
        leaf = node - table.shape[0]
        oh_leaf = (leaf == _iota((rblk, n_leaf), 1)).astype(jnp.float32)
        vals = lax.dot_general(oh_leaf, leaf_ref[tb],
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)  # (RBLK, 1)
        # multi-class: round-major tree order, tree t owns margin column
        # t % K; a one-hot class row routes the accumulation (K == 1:
        # plain add).  Zero-leaf padding trees contribute exactly 0.
        cls = (pl.program_id(1) * trees_per_block + tb) % n_classes
        oh_cls = (cls == _iota((1, n_classes), 1)).astype(jnp.float32)
        acc += vals * oh_cls
    out_ref[...] += acc


@functools.partial(jax.jit, static_argnames=("missing_bin", "depth",
                                             "records_per_block", "interpret",
                                             "n_classes", "trees_per_block"))
def predict_ensemble_pallas(trees: TreeArrays, codes, *, missing_bin: int,
                            depth: int, records_per_block: int = 1024,
                            interpret: bool = True, n_classes: int = 1,
                            trees_per_block: int = 8):
    """Batch inference: trees hold stacked (T, ...) arrays; codes (n, F).

    Grid = (record blocks, T / trees_per_block): each step holds a block
    of ``trees_per_block`` tree tables resident in VMEM (paper: one tree
    per BU, here a BU block per grid step) and accumulates into the
    revisited output block — each record block read is amortized across
    the whole tree block.  The ensemble is zero-padded (pass-through
    trees with all-zero leaves) up to a multiple of ``trees_per_block``;
    padding contributes exactly +0.0.  Returns (n,) float32 ensemble sums
    — or (n, K) per-class margins when ``n_classes > 1`` (trees
    round-major; tree t feeds class t % K via a one-hot column route, so
    the walk itself is unchanged).
    """
    n, n_cols = codes.shape
    T = trees.feature.shape[0]
    tblk = min(trees_per_block, T)
    t_pad = -T % tblk
    if t_pad:
        trees = TreeArrays(
            feature=jnp.pad(trees.feature, ((0, t_pad), (0, 0)),
                            constant_values=-1),
            threshold=jnp.pad(trees.threshold, ((0, t_pad), (0, 0))),
            is_cat=jnp.pad(trees.is_cat, ((0, t_pad), (0, 0))),
            default_left=jnp.pad(trees.default_left, ((0, t_pad), (0, 0))),
            leaf_value=jnp.pad(trees.leaf_value, ((0, t_pad), (0, 0))))
    rblk = min(records_per_block, max(8, n))
    n_pad = -n % rblk
    codes = jnp.pad(codes, ((0, n_pad), (0, 0)))
    np_ = codes.shape[0]
    n_int = trees.feature.shape[1]
    n_leaf = trees.leaf_value.shape[1]
    tables = jax.vmap(lambda f, t, c, d: pack_node_table(
        TreeArrays(f, t, c, d, jnp.zeros((n_leaf,)))))(
            trees.feature, trees.threshold, trees.is_cat, trees.default_left)
    out = pl.pallas_call(
        functools.partial(_ensemble_kernel, depth=depth,
                          missing_bin=missing_bin, n_classes=n_classes,
                          trees_per_block=tblk),
        grid=(np_ // rblk, (T + t_pad) // tblk),
        in_specs=[
            pl.BlockSpec((rblk, n_cols), lambda ri, ti: (ri, 0)),
            pl.BlockSpec((tblk, n_int, 4), lambda ri, ti: (ti, 0, 0)),
            pl.BlockSpec((tblk, n_leaf, 1), lambda ri, ti: (ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((rblk, n_classes), lambda ri, ti: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, n_classes), jnp.float32),
        interpret=interpret,
    )(codes, tables, trees.leaf_value[:, :, None])
    return out[:n, 0] if n_classes == 1 else out[:n]

"""jit'd dispatch wrappers over the Pallas kernels and their alternatives.

Every accelerated GBDT step dispatches through an
:class:`repro.api.ExecutionPlan` so the benchmark harness can reproduce the
paper's machine comparison *as algorithm strategies at equal memory
traffic*:

  histogram (step ①):
    * ``scatter``          — single shared scatter-RMW (multicore analog;
                             also the fastest path on this CPU container)
    * ``scatter_private``  — W privatized replicas + reduce (the GPU
                             shared-memory privatization of §II-D)
    * ``sort``             — sort-by-key + segment-sum (GPU-alternative)
    * ``onehot``           — blocked one-hot einsum in pure jnp (XLA)
    * ``pallas_grouped``   — the Booster kernel (group-by-field, MXU)
    * ``pallas_packed``    — the naive-packing ablation kernel

  traversal / inference (step ⑤, §III-D) and partition (step ③):
    * ``reference`` (gather walk)  vs  ``pallas`` (one-hot walk)

On non-TPU backends the Pallas kernels run in interpret mode (Python
execution of the kernel body) — numerically identical, used for validation.

Calling convention: ``build_histogram(..., plan=plan)`` with a resolved
plan.  The PR-1 loose ``strategy=`` / ``interpret=`` kwargs (and their
``default_hist_strategy`` shim) are gone from these entry points;
config-level strategy strings are lifted into a plan once, at the boundary
(``ExecutionPlan.from_config`` / the deprecated grower kwargs), not per
call.
"""
from __future__ import annotations

import functools
import warnings
from collections import Counter
from typing import Optional

import jax
import jax.numpy as jnp

from repro.api.plan import ExecutionPlan, HIST_STRATEGIES, resolve_plan
# safe either import order: binning only depends on jax/numpy, and the
# core package binds this module lazily (runtime attribute access only)
from repro.core.binning import PackedCodes
from repro.kernels import histogram as _hist_k
from repro.kernels import partition as _part_k
from repro.kernels import traversal as _trav_k
from repro.kernels import ref as _ref
from repro.kernels.ref import TreeArrays
from repro.resilience import metrics as _metrics

__all__ = ["HIST_STRATEGIES", "onehot_matmul", "pack_codes", "unpack_codes",
           "build_histogram", "accumulate_histogram", "partition_level",
           "traverse_tree", "predict_ensemble", "pallas_available",
           "degradation_stats", "reset_degradation_stats"]


# --------------------------------------------------------------------------
# graceful kernel degradation: a broken Pallas lowering degrades
# throughput, never correctness
# --------------------------------------------------------------------------
_DEGRADATIONS: Counter = Counter()
_DEGRADE_WARNED: set = set()


def degradation_stats() -> dict:
    """``{"step:strategy->fallback": count}`` of every Pallas demotion
    this process took (also mirrored into the process-wide
    ``resilience.metrics`` ``"degradations"`` counter)."""
    return dict(_DEGRADATIONS)


def reset_degradation_stats() -> dict:
    """Zero the per-step demotion counters (the one-time warning latch
    stays latched); returns the pre-reset values."""
    old = dict(_DEGRADATIONS)
    _DEGRADATIONS.clear()
    return old


def _degrade(step: str, strategy: str, fallback: str,
             exc: Exception) -> None:
    """Record one kernel demotion: count it, and warn ONCE per
    (step, strategy) so a chunked fit does not emit a warning per
    dispatch."""
    _DEGRADATIONS[f"{step}:{strategy}->{fallback}"] += 1
    _metrics.record("degradations")
    key = (step, strategy)
    if key not in _DEGRADE_WARNED:
        _DEGRADE_WARNED.add(key)
        warnings.warn(
            f"Pallas {step} kernel (strategy {strategy!r}) failed "
            f"({type(exc).__name__}: {exc}); demoting to the "
            f"{fallback!r} jnp path for this call — throughput "
            "degrades, correctness does not",
            RuntimeWarning, stacklevel=4)


@functools.lru_cache(maxsize=None)
def pallas_available(step: str, interpret: bool = True) -> bool:
    """Probe whether the ``step`` Pallas kernel actually launches on
    this backend (tiny input, one compile, cached per process).

    ``ExecutionPlan.resolved()`` consults this before electing a Pallas
    strategy so a backend with a broken lowering resolves straight to
    the jnp twin instead of demoting on the first real dispatch.
    ``step``: ``"histogram"`` | ``"partition"`` | ``"traversal"``.
    """
    if step not in ("histogram", "partition", "traversal"):
        # outside the probe's try block: a typo'd step name must raise,
        # not read as "kernel unavailable"
        raise ValueError(f"unknown probe step {step!r}")
    try:
        if step == "histogram":
            out = _hist_k.histogram_pallas(
                jnp.zeros((16, 2), jnp.uint8), jnp.ones((16,), jnp.float32),
                jnp.ones((16,), jnp.float32), jnp.zeros((16,), jnp.int32),
                n_nodes=1, n_bins=4, records_per_block=16,
                fields_per_block=2, packed=False, interpret=interpret)
        elif step == "partition":
            out = _part_k.partition_pallas(
                jnp.zeros((8,), jnp.int32), jnp.zeros((8, 1), jnp.uint8),
                jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                jnp.zeros((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                missing_bin=3, interpret=interpret)
        else:
            tree = TreeArrays(feature=jnp.zeros((1,), jnp.int32),
                              threshold=jnp.zeros((1,), jnp.int32),
                              is_cat=jnp.zeros((1,), jnp.int32),
                              default_left=jnp.zeros((1,), jnp.int32),
                              leaf_value=jnp.zeros((2,), jnp.float32))
            out = _trav_k.traverse_pallas(tree, jnp.zeros((8, 1), jnp.uint8),
                                          missing_bin=3, interpret=interpret)
        jax.block_until_ready(out)
        return True
    except Exception:  # noqa: BLE001 — any launch/lowering failure
        return False


# --------------------------------------------------------------------------
# device-side pack/unpack primitives (paper §III-B compressed codes)
# --------------------------------------------------------------------------
@jax.jit
def pack_codes(codes) -> PackedCodes:
    """4-bit pack on device: (..., n) integer codes -> :class:`PackedCodes`
    (two codes per byte along the last axis).  Codes must be <= 15 —
    i.e. ``n_bins <= 16`` — or information is lost; callers gate on the
    bin count."""
    return PackedCodes.pack(codes)


@jax.jit
def unpack_codes(packed) -> jax.Array:
    """Inverse of :func:`pack_codes` on device: -> (..., n) uint8.
    Plain arrays pass through unchanged, so dispatch layers can call this
    unconditionally."""
    if isinstance(packed, PackedCodes):
        return packed.unpack()
    return jnp.asarray(packed)


# --------------------------------------------------------------------------
# generic primitive: one-hot contraction (shared with the MoE dispatch layer)
# --------------------------------------------------------------------------
def onehot_matmul(idx: jax.Array, values: jax.Array, width: int) -> jax.Array:
    """out[j] = sum_{i : idx[i] == j} values[i]  via a dense MXU contraction.

    idx: (n,) int; values: (n, ...) — returns (width, ...).  This is the
    paper's core primitive (irregular scatter -> dense one-hot matmul) in
    reusable form; the MoE layers use it for token->expert dispatch.
    """
    oh = jax.nn.one_hot(idx, width, dtype=values.dtype)        # (n, width)
    flat = values.reshape(values.shape[0], -1)
    out = jax.lax.dot_general(oh, flat, (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    return out.reshape((width,) + values.shape[1:])


# --------------------------------------------------------------------------
# step ① — histogram strategies
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins"))
def _hist_scatter(codes, g, h, node_ids, n_nodes, n_bins):
    return _ref.histogram_ref(codes, g, h, node_ids, n_nodes, n_bins)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins", "n_private"))
def _hist_scatter_private(codes, g, h, node_ids, n_nodes, n_bins,
                          n_private=32):
    """GPU-style privatization: W replica histograms, then reduce (§II-D)."""
    n, F = codes.shape
    pad = -n % n_private
    codes = jnp.pad(codes, ((0, pad), (0, 0)))
    g = jnp.pad(g, (0, pad))
    h = jnp.pad(h, (0, pad))
    node_ids = jnp.pad(node_ids, (0, pad))
    cw = codes.reshape(n_private, -1, F)
    gw = g.reshape(n_private, -1)
    hw = h.reshape(n_private, -1)
    nw = node_ids.reshape(n_private, -1)
    per = jax.vmap(lambda c, gg, hh, nn: _ref.histogram_ref(
        c, gg, hh, nn, n_nodes, n_bins))(cw, gw, hw, nw)
    return per.sum(axis=0)


@functools.partial(jax.jit, static_argnames=("n_nodes", "n_bins"))
def _hist_sort(codes, g, h, node_ids, n_nodes, n_bins):
    """Sort-by-key + segment-sum per field (regularized-GPU alternative)."""
    n, F = codes.shape
    stats = jnp.stack([g, h], -1).astype(jnp.float32)

    def per_field(col):
        comb = node_ids.astype(jnp.int32) * n_bins + col.astype(jnp.int32)
        order = jnp.argsort(comb)
        return jax.ops.segment_sum(stats[order], comb[order],
                                   num_segments=n_nodes * n_bins)

    hist = jax.vmap(per_field, in_axes=1)(codes)               # (F, NN*NB, 2)
    return hist.reshape(F, n_nodes, n_bins, 2).transpose(1, 0, 2, 3)


@functools.partial(jax.jit,
                   static_argnames=("n_nodes", "n_bins", "chunk", "fblk"))
def _hist_onehot(codes, g, h, node_ids, n_nodes, n_bins, chunk=2048, fblk=8):
    """Blocked pure-jnp one-hot contraction (the kernel's XLA twin)."""
    n, F = codes.shape
    pad = -n % chunk
    codes = jnp.pad(codes, ((0, pad), (0, -F % fblk)))
    g = jnp.pad(g, (0, pad))
    h = jnp.pad(h, (0, pad))
    node_ids = jnp.pad(node_ids, (0, pad))
    np_, Fp = codes.shape
    stats = jnp.stack([g, h], -1).astype(jnp.float32)

    def body(acc, xs):
        c, s, nid = xs                                         # (chunk, Fp) ...
        oh_node = jax.nn.one_hot(nid, n_nodes, dtype=jnp.float32)
        sn = (oh_node[:, :, None] * s[:, None, :]).reshape(chunk, n_nodes * 2)
        oh_bin = jax.nn.one_hot(c.astype(jnp.int32), n_bins,
                                dtype=jnp.float32)             # (chunk, Fp, NB)
        contrib = jnp.einsum("nfb,ns->fbs", oh_bin, sn,
                             preferred_element_type=jnp.float32)
        return acc + contrib, None

    init = jnp.zeros((Fp, n_bins, n_nodes * 2), jnp.float32)
    xs = (codes.reshape(-1, chunk, Fp), stats.reshape(-1, chunk, 2),
          node_ids.reshape(-1, chunk))
    hist, _ = jax.lax.scan(body, init, xs)
    hist = hist[:F].reshape(F, n_bins, n_nodes, 2)
    return hist.transpose(2, 0, 1, 3)


def build_histogram(codes, g, h, node_ids, *, n_nodes: int, n_bins: int,
                    plan: Optional[ExecutionPlan] = None):
    """Dispatch: (n, F) codes -> (n_nodes, F, n_bins, 2) float32 histogram.

    Class-batched form (multi-class boosting): ``g``, ``h``, ``node_ids``
    may carry a leading class axis (K, n) — every class has its own node
    partition but shares the code stream — and the result gains the same
    leading axis: (K, n_nodes, F, n_bins, 2).  The jnp strategies vmap
    over the class axis; the Pallas kernel widens its stats operand so a
    single launch reads the codes once for all K classes.
    """
    plan = resolve_plan(plan)
    strategy = plan.hist_strategy
    if isinstance(codes, PackedCodes) and strategy != "pallas_grouped":
        # the grouped Pallas kernel consumes packed blocks natively (half
        # the HBM code traffic); every other strategy gets the bit-equal
        # unpacked view, fused into its own jit
        codes = codes.unpack()
    batched = g.ndim == 2

    def per_class(fn):
        if not batched:
            return fn
        return jax.vmap(fn, in_axes=(None, 0, 0, 0))

    if strategy == "scatter":
        fn = lambda c, gg, hh, nn: _hist_scatter(c, gg, hh, nn, n_nodes,
                                                 n_bins)
        return per_class(fn)(codes, g, h, node_ids)
    if strategy == "scatter_private":
        fn = lambda c, gg, hh, nn: _hist_scatter_private(c, gg, hh, nn,
                                                         n_nodes, n_bins)
        return per_class(fn)(codes, g, h, node_ids)
    if strategy == "sort":
        fn = lambda c, gg, hh, nn: _hist_sort(c, gg, hh, nn, n_nodes, n_bins)
        return per_class(fn)(codes, g, h, node_ids)
    if strategy == "onehot":
        fn = lambda c, gg, hh, nn: _hist_onehot(c, gg, hh, nn, n_nodes,
                                                n_bins)
        return per_class(fn)(codes, g, h, node_ids)
    if strategy in ("pallas_grouped", "pallas_packed"):
        try:
            return _hist_k.histogram_pallas(
                codes, g, h, node_ids, n_nodes=n_nodes, n_bins=n_bins,
                records_per_block=plan.records_per_block,
                fields_per_block=plan.fields_per_block,
                packed=(strategy == "pallas_packed"),
                interpret=plan.interpret)
        except Exception as exc:  # noqa: BLE001 — demote, never corrupt
            _degrade("histogram", strategy, "scatter", exc)
            if isinstance(codes, PackedCodes):
                codes = codes.unpack()
            fn = lambda c, gg, hh, nn: _hist_scatter(c, gg, hh, nn,
                                                     n_nodes, n_bins)
            return per_class(fn)(codes, g, h, node_ids)
    raise ValueError(f"unknown histogram strategy {strategy!r}; "
                     f"choose from {HIST_STRATEGIES}")


@functools.lru_cache(maxsize=None)
def _accumulate_jit(n_nodes: int, n_bins: int, plan: ExecutionPlan):
    """Jitted ``hist += chunk_hist`` with the accumulator donated.

    Donation lets XLA update the (K, NN, F, NB, 2) accumulator in place
    instead of allocating a fresh buffer per chunk — the out-of-core
    trainer calls this once per chunk per level, so without donation the
    allocator churns one accumulator-sized buffer per chunk.  Donation is
    only requested on backends that implement it (TPU/GPU); the CPU
    backend would warn and copy anyway.
    """
    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()

    def impl(hist, codes, g, h, node_ids):
        return hist + build_histogram(codes, g, h, node_ids, n_nodes=n_nodes,
                                      n_bins=n_bins, plan=plan)

    return jax.jit(impl, donate_argnums=donate)


def accumulate_histogram(hist, codes, g, h, node_ids, *, n_nodes: int,
                         n_bins: int,
                         plan: Optional[ExecutionPlan] = None):
    """Chunked step ①: ``hist + build_histogram(chunk)`` in one dispatch.

    The out-of-core trainer accumulates the per-level histogram across
    device-sized chunks — every chunk reuses the per-chunk strategy
    unchanged (Pallas or jnp), and only the (n_nodes, F, n_bins, 2)
    accumulator stays resident between chunks (donated into the jit, so
    no fresh accumulator-sized allocation per chunk).  Adding a zero-stat
    padded record contributes exactly +0.0, so padded chunks keep
    bit-equality with the monolithic histogram.
    """
    # chunk budgets don't change the kernel — drop them from the jit key
    return _accumulate_jit(n_nodes, n_bins,
                           resolve_plan(plan).without_chunking())(
        hist, codes, g, h, node_ids)


# --------------------------------------------------------------------------
# step ③ — partition
# --------------------------------------------------------------------------
def partition_level(node_ids, codes_lvl, split_feature, split_threshold,
                    split_is_cat, split_default_left, *, missing_bin: int,
                    plan: Optional[ExecutionPlan] = None):
    plan = resolve_plan(plan)
    if isinstance(codes_lvl, PackedCodes):
        codes_lvl = codes_lvl.unpack()
    if plan.partition_strategy == "reference":
        return _ref.partition_ref(node_ids, codes_lvl, split_feature,
                                  split_threshold, split_is_cat,
                                  split_default_left, missing_bin)
    try:
        return _part_k.partition_pallas(
            node_ids, codes_lvl, split_feature, split_threshold,
            split_is_cat, split_default_left, missing_bin=missing_bin,
            interpret=plan.interpret)
    except Exception as exc:  # noqa: BLE001 — demote, never corrupt
        _degrade("partition", plan.partition_strategy, "reference", exc)
        return _ref.partition_ref(node_ids, codes_lvl, split_feature,
                                  split_threshold, split_is_cat,
                                  split_default_left, missing_bin)


# --------------------------------------------------------------------------
# step ⑤ — traversal / batch inference
# --------------------------------------------------------------------------
def traverse_tree(tree: TreeArrays, codes, *, missing_bin: int,
                  plan: Optional[ExecutionPlan] = None):
    plan = resolve_plan(plan)
    if isinstance(codes, PackedCodes):
        codes = codes.unpack()
    # "scan" only changes multi-tree inference; a single walk is a walk
    if plan.traversal_strategy in ("reference", "scan"):
        return _ref.traverse_ref(tree, codes, missing_bin)
    try:
        return _trav_k.traverse_pallas(tree, codes, missing_bin=missing_bin,
                                       interpret=plan.interpret)
    except Exception as exc:  # noqa: BLE001 — demote, never corrupt
        _degrade("traversal", plan.traversal_strategy, "reference", exc)
        return _ref.traverse_ref(tree, codes, missing_bin)


_PREDICT_ROWS_PER_CHUNK = 1024   # (chunk, T) walk state stays cache-sized


@functools.partial(jax.jit, static_argnames=("missing_bin", "n_classes"))
def _predict_batched_jit(trees, codes, missing_bin, n_classes):
    """Optimized tree-batched level walk (same math as the
    :func:`repro.kernels.ref.predict_ensemble_batched` oracle — node
    decisions are integer-exact, so the two agree bit-for-bit on the
    walks and to float tolerance on the fold):

    * the four per-node parameters are packed into ONE int32 table
      ``((feat+1) << 16) | (thr << 8) | (cat << 1) | dl`` so each level
      costs a single table gather + a single code gather instead of
      five (bin codes are uint8 and field counts < 2**15 — the repo's
      binning invariants — so the pack is lossless);
    * records walk in ``lax.map`` chunks so the (chunk, T) node matrix
      and its gather intermediates stay cache-resident instead of
      materializing (n, T) arrays per level.
    """
    n = codes.shape[0]
    T = trees.feature.shape[0]
    depth = int(trees.leaf_value.shape[-1]).bit_length() - 1
    if codes.shape[1] >= 1 << 15:
        # field ids this wide overflow the int32 pack — take the unpacked
        # (slower, still one-pass) walk instead of silently corrupting
        return _ref.predict_ensemble_batched(trees, codes, missing_bin,
                                             n_classes=n_classes)
    packed_t = (((trees.feature + 1) << 16) | (trees.threshold << 8)
                | (trees.is_cat << 1) | trees.default_left).T  # (N_int, T)
    leaf_t = trees.leaf_value.T                                # (N_leaf, T)
    cls_oh = (None if n_classes == 1 else
              jax.nn.one_hot(jnp.arange(T) % n_classes, n_classes,
                             dtype=jnp.float32))               # (T, K)

    def walk(cb):
        node = jnp.zeros((cb.shape[0], T), jnp.int32)
        for _ in range(depth):
            p = jnp.take_along_axis(packed_t, node, axis=0)
            f = (p >> 16) - 1
            code = jnp.take_along_axis(cb, jnp.maximum(f, 0), axis=1)
            thr = (p >> 8) & 255
            go_left = jnp.where((p >> 1) & 1, code == thr, code <= thr)
            go_left = jnp.where(code == missing_bin, (p & 1) == 1,
                                go_left)
            go_left = jnp.where(f < 0, True, go_left)
            node = 2 * node + 2 - go_left.astype(jnp.int32)
        vals = jnp.take_along_axis(leaf_t, node - packed_t.shape[0],
                                   axis=0)                     # (chunk, T)
        if cls_oh is None:
            return jnp.sum(vals, axis=1)
        return jax.lax.dot_general(vals, cls_oh, (((1,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32)

    chunk = min(_PREDICT_ROWS_PER_CHUNK, max(1, n))
    cp = jnp.pad(codes.astype(jnp.int32), ((0, -n % chunk), (0, 0)))
    out = jax.lax.map(walk, cp.reshape(-1, chunk, cp.shape[1]))
    return out.reshape((-1,) + out.shape[2:])[:n]


def predict_ensemble(trees: TreeArrays, codes, *, missing_bin: int,
                     depth: int, plan: Optional[ExecutionPlan] = None,
                     n_classes: int = 1):
    """Ensemble margins: (n,) for scalar objectives, (n, K) when
    ``n_classes > 1`` (trees round-major, tree t feeds class t % K).

    ``plan.traversal_strategy`` picks the engine: ``"reference"`` is the
    tree-batched level walk (one pass over the codes for the whole
    ensemble, jitted), ``"scan"`` the legacy per-tree lax.scan baseline,
    ``"pallas"`` the tree-blocked kernel (``plan.trees_per_block`` tree
    tables resident per grid step).
    """
    plan = resolve_plan(plan)
    if isinstance(codes, PackedCodes):
        codes = codes.unpack()
    if plan.traversal_strategy == "scan":
        return _ref.predict_ensemble_ref(trees, codes, missing_bin,
                                         n_classes=n_classes)
    if plan.traversal_strategy == "reference":
        return _predict_batched_jit(trees, codes, missing_bin, n_classes)
    try:
        return _trav_k.predict_ensemble_pallas(
            trees, codes, missing_bin=missing_bin, depth=depth,
            interpret=plan.interpret, n_classes=n_classes,
            trees_per_block=plan.trees_per_block)
    except Exception as exc:  # noqa: BLE001 — demote, never corrupt
        _degrade("predict", plan.traversal_strategy, "reference", exc)
        return _predict_batched_jit(trees, codes, missing_bin, n_classes)

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth used by the per-kernel allclose tests and by the
benchmark harness as the "software baseline" implementations.  They mirror
the three dominant GBDT training steps the paper accelerates:

  * ``histogram_ref``    — step ① histogram binning of gradient statistics
  * ``partition_ref``    — step ③ single-predicate evaluation / partition
  * ``traverse_ref``     — step ⑤ one-tree traversal (+ batch inference)
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

Array = jax.Array


class TreeArrays(NamedTuple):
    """Fixed-shape complete-binary-tree table (depth ``D`` static).

    ``feature`` is -1 for pass-through nodes (a leaf decided above them);
    internal layout matches the paper's step-⑤ "map the tree to a table"
    (feature id, split point, child pointers are implicit: 2i+1 / 2i+2).
    """

    feature: Array       # (2**D - 1,) int32; -1 == pass-through
    threshold: Array     # (2**D - 1,) int32 bin code
    is_cat: Array        # (2**D - 1,) int32 {0,1}; ==1: go left iff code == thr
    default_left: Array  # (2**D - 1,) int32 {0,1}; missing-value direction
    leaf_value: Array    # (2**D,) float32 values at the bottom level

    @property
    def depth(self) -> int:
        return int(self.leaf_value.shape[-1]).bit_length() - 1


# --------------------------------------------------------------------------
# step ① — histogram binning
# --------------------------------------------------------------------------
def histogram_ref(codes: Array, g: Array, h: Array, node_ids: Array,
                  n_nodes: int, n_bins: int) -> Array:
    """Scatter-add oracle: hist[node, f, bin] += (g, h).

    codes: (n, F) uint; g, h: (n,); node_ids: (n,) int32 in [0, n_nodes).
    Returns (n_nodes, F, n_bins, 2) float32.
    """
    n, F = codes.shape
    stats = jnp.stack([g, h], axis=-1).astype(jnp.float32)          # (n, 2)
    comb = node_ids.astype(jnp.int32)[:, None] * n_bins + codes.astype(jnp.int32)
    hist = jnp.zeros((F, n_nodes * n_bins, 2), jnp.float32)
    hist = hist.at[jnp.arange(F)[None, :], comb].add(stats[:, None, :])
    return hist.reshape(F, n_nodes, n_bins, 2).transpose(1, 0, 2, 3)


def _decide_go_left(code: Array, feature: Array, threshold: Array,
                    is_cat: Array, default_left: Array, missing_bin: int
                    ) -> Array:
    """Shared predicate semantics (paper Fig 2/3 + missing-bin handling)."""
    is_missing = code == missing_bin
    left_num = code <= threshold
    left_cat = code == threshold
    go_left = jnp.where(is_cat == 1, left_cat, left_num)
    go_left = jnp.where(is_missing, default_left == 1, go_left)
    return jnp.where(feature < 0, True, go_left)


# --------------------------------------------------------------------------
# step ③ — single-predicate evaluation (one level of partitioning)
# --------------------------------------------------------------------------
def partition_ref(node_ids: Array, codes_lvl: Array, split_feature: Array,
                  split_threshold: Array, split_is_cat: Array,
                  split_default_left: Array, missing_bin: int) -> Array:
    """Route each record to its child given the level's chosen splits.

    node_ids: (n,) level-local node index in [0, NN).
    codes_lvl: (n, C) compact per-level field columns; split_feature indexes
        into [0, C) (the paper's field *renumbering*), or -1 for non-splitting
        nodes (records go left, i.e. follow the pass-through spine).
    Returns new (n,) node ids in [0, 2*NN).
    """
    f = split_feature[node_ids]                                     # (n,)
    thr = split_threshold[node_ids]
    cat = split_is_cat[node_ids]
    dl = split_default_left[node_ids]
    code = jnp.take_along_axis(
        codes_lvl, jnp.maximum(f, 0).astype(jnp.int32)[:, None], axis=1)[:, 0]
    go_left = _decide_go_left(code.astype(jnp.int32), f, thr, cat, dl,
                              missing_bin)
    return 2 * node_ids + (1 - go_left.astype(jnp.int32))


# --------------------------------------------------------------------------
# step ⑤ — one-tree traversal (and the batch-inference building block)
# --------------------------------------------------------------------------
def traverse_ref(tree: TreeArrays, codes: Array, missing_bin: int) -> Array:
    """Walk every record through one tree; returns (n,) leaf values.

    codes: (n, C) — columns indexed by ``tree.feature`` (full field set or
    the compacted/renumbered subset, caller's choice).
    """
    n = codes.shape[0]
    depth = tree.depth
    codes = codes.astype(jnp.int32)
    node = jnp.zeros((n,), jnp.int32)
    for _ in range(depth):
        f = tree.feature[node]
        code = jnp.take_along_axis(
            codes, jnp.maximum(f, 0)[:, None], axis=1)[:, 0]
        go_left = _decide_go_left(code, f, tree.threshold[node],
                                  tree.is_cat[node], tree.default_left[node],
                                  missing_bin)
        node = 2 * node + 2 - go_left.astype(jnp.int32)
    leaf = node - (2 ** depth - 1)
    return tree.leaf_value[leaf]


def predict_ensemble_ref(trees: TreeArrays, codes: Array, missing_bin: int,
                         n_classes: int = 1) -> Array:
    """Legacy batch inference: one tree at a time (paper §II-B baseline).

    ``trees`` holds stacked arrays with a leading tree dimension (T, ...).
    Multi-class ensembles store trees round-major (round r, class k at
    index ``r * K + k``); tree t accumulates into margin column ``t % K``
    and the output gains a class axis: (n, K).  ``n_classes == 1`` keeps
    the scalar (n,) output.

    A depth-T ensemble re-reads every code T times here — this is the
    ``"scan"`` traversal strategy the benchmarks keep as the software
    baseline; the production path is :func:`predict_ensemble_batched`.
    """
    T = trees.feature.shape[0]
    cls_oh = jax.nn.one_hot(jnp.arange(T) % n_classes, n_classes,
                            dtype=jnp.float32)               # (T, K)

    def body(carry, xs):
        t, oh = xs
        tree = TreeArrays(*t)
        out = traverse_ref(tree, codes, missing_bin)         # (n,)
        return carry + out[:, None] * oh[None, :], None

    init = jnp.zeros((codes.shape[0], n_classes), jnp.float32)
    out, _ = jax.lax.scan(body, init, (tuple(trees), cls_oh))
    return out[:, 0] if n_classes == 1 else out


def predict_ensemble_batched(trees: TreeArrays, codes: Array,
                             missing_bin: int, n_classes: int = 1) -> Array:
    """Tree-batched batch inference: all trees advance one level per pass.

    The paper's §III-D scheme pins one tree per BU and streams a *shared*
    record stream past all of them — the software analog keeps an (n, T)
    node-index matrix and advances every tree simultaneously per depth
    level with batched ``take_along_axis`` over the stacked (T, N_int)
    node tables, so the whole ensemble makes ONE pass over the codes
    instead of T.  A final (T, K) one-hot contraction folds the
    round-major per-tree leaf values into class margins (a plain tree-sum
    for ``n_classes == 1``).

    Decision semantics are identical to the per-tree scan — node paths
    and leaf choices are bit-equal; only the floating accumulation order
    of the final fold differs.
    """
    T = trees.feature.shape[0]
    depth = int(trees.leaf_value.shape[-1]).bit_length() - 1
    codes = codes.astype(jnp.int32)
    # (N_int, T) transposed tables: take_along_axis(tab, node, axis=0)
    # fetches tab[node[i, t], t] — every tree's parameter in one gather
    feat_t = trees.feature.T
    thr_t = trees.threshold.T
    cat_t = trees.is_cat.T
    dl_t = trees.default_left.T
    node = jnp.zeros((codes.shape[0], T), jnp.int32)         # (n, T)
    for _ in range(depth):
        f = jnp.take_along_axis(feat_t, node, axis=0)        # (n, T)
        code = jnp.take_along_axis(codes, jnp.maximum(f, 0), axis=1)
        go_left = _decide_go_left(code, f,
                                  jnp.take_along_axis(thr_t, node, axis=0),
                                  jnp.take_along_axis(cat_t, node, axis=0),
                                  jnp.take_along_axis(dl_t, node, axis=0),
                                  missing_bin)
        node = 2 * node + 2 - go_left.astype(jnp.int32)
    leaf = node - (2 ** depth - 1)
    vals = jnp.take_along_axis(trees.leaf_value.T, leaf, axis=0)  # (n, T)
    if n_classes == 1:
        return jnp.sum(vals, axis=1)
    cls_oh = jax.nn.one_hot(jnp.arange(T) % n_classes, n_classes,
                            dtype=jnp.float32)               # (T, K)
    return jax.lax.dot_general(vals, cls_oh, (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)

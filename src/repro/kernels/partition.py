"""Pallas TPU kernel for step ③ — single-predicate evaluation / partition.

Paper §III-B: the freshly chosen predicate is broadcast (replicated) to all
BUs; each BU evaluates it against a streamed single-field column (fetched
from the redundant per-field column-major copy) and routes the record
pointer to the predicate-true or predicate-false stream.

Our level-wise grower evaluates *all* of a level's predicates in one pass:
each record carries its level-local node id, and the level's split table
(one predicate per node, ≤ 2**level entries — tiny, VMEM-replicated like the
paper's broadcast) decides left/right.  The routed result is the record's
child node id; the fixed-shape design replaces the paper's pointer streams
with an in-place id update (stream compaction is only needed by the
leaf-wise grower and is done with a sort there).

The field columns consumed here are gathered from the column-major copy —
only the ≤ NN fields named by the level's predicates travel HBM→VMEM, which
is the redundant-representation bandwidth saving of §III (steps ③/⑤).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _iota(shape, dim):
    return lax.broadcasted_iota(jnp.int32, shape, dim)


def _iota_f(shape, dim):
    return lax.broadcasted_iota(jnp.float32, shape, dim)


def _partition_kernel(node_ref, codes_ref, table_ref, out_ref, *,
                      missing_bin: int):
    rblk = codes_ref.shape[0]
    n_nodes, _ = table_ref.shape
    n_cols = codes_ref.shape[1]
    node = node_ref[...].astype(jnp.int32)                    # (RBLK, 1)
    codes = codes_ref[...].astype(jnp.float32)                # (RBLK, C)
    table = table_ref[...]                                    # (NN, 4) f32
    oh_node = (node == _iota((rblk, n_nodes), 1)).astype(jnp.float32)
    params = lax.dot_general(oh_node, table, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    f = params[:, 0:1]
    thr = params[:, 1:2]
    cat = params[:, 2:3]
    dl = params[:, 3:4]
    oh_f = (f == _iota_f((rblk, n_cols), 1)).astype(jnp.float32)
    code = jnp.sum(oh_f * codes, axis=1, keepdims=True)
    go_left = jnp.where(cat == 1.0, code == thr, code <= thr)
    go_left = jnp.where(code == float(missing_bin), dl == 1.0, go_left)
    go_left = jnp.where(f < 0.0, True, go_left)
    out_ref[...] = 2 * node + (1 - go_left.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("missing_bin",
                                             "records_per_block", "interpret"))
def partition_pallas(node_ids, codes_lvl, split_feature, split_threshold,
                     split_is_cat, split_default_left, *, missing_bin: int,
                     records_per_block: int = 1024, interpret: bool = True):
    """Route records to children.  Level-local ids: out in [0, 2*NN).

    node_ids (n,) int32; codes_lvl (n, C) uint8 compact per-level columns;
    split_* (NN,) with split_feature indexing [0, C) or -1 (pass-through).
    """
    n, n_cols = codes_lvl.shape
    rblk = min(records_per_block, max(8, n))
    n_pad = -n % rblk
    codes_lvl = jnp.pad(codes_lvl, ((0, n_pad), (0, 0)))
    node_ids_p = jnp.pad(node_ids, (0, n_pad))
    np_ = codes_lvl.shape[0]
    n_nodes = split_feature.shape[0]
    table = jnp.stack([split_feature, split_threshold, split_is_cat,
                       split_default_left], axis=1).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_partition_kernel, missing_bin=missing_bin),
        grid=(np_ // rblk,),
        in_specs=[
            pl.BlockSpec((rblk, 1), lambda ri: (ri, 0)),
            pl.BlockSpec((rblk, n_cols), lambda ri: (ri, 0)),
            pl.BlockSpec((n_nodes, 4), lambda ri: (0, 0)),    # replicated
        ],
        out_specs=pl.BlockSpec((rblk, 1), lambda ri: (ri, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 1), jnp.int32),
        interpret=interpret,
    )(node_ids_p[:, None], codes_lvl, table)
    return out[:n, 0]

from repro.kernels.ref import TreeArrays

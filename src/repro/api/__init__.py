"""``repro.api`` — the unified estimator facade over the Booster engine.

Public surface:

  * :class:`ExecutionPlan` — one object deciding where every GBDT step runs
    (kernel strategies, Pallas interpret mode, optional inference mesh).
  * :class:`BoosterRegressor` / :class:`BoosterClassifier` — sklearn/XGBoost
    style estimators: raw NaN-carrying matrices in, predictions out; binning,
    training, checkpointing and serving all behind ``fit`` / ``predict``.
  * :func:`save` / :func:`load` (+ ``save_checkpoint`` / ``load_checkpoint``)
    — the one serialization story: npz + json meta, shared by estimators,
    pipelines and training checkpoints.
  * :class:`Server` / :class:`ModelRegistry` / :class:`Request` — the
    serving daemon: deadline-aware request batching over the compile-once
    inference engine, multi-model tenancy, zero-retrace hot-swap.
  * :class:`RecoveryPolicy` / :class:`RetryingSource` / :class:`RetryPolicy`
    — the resilience layer: self-healing streaming fits (checkpoint-replay,
    OOM chunk degradation) and transparently retrying data sources; typed
    failures (``QueueFullError`` etc.) live in :mod:`repro.resilience`.

Only :mod:`repro.api.plan` is imported eagerly — the kernels layer depends
on it, so the estimator/serialize modules (which depend on the kernels
layer) are loaded lazily to keep the import graph acyclic.
"""
from repro.api.plan import ExecutionPlan, resolve_plan

_LAZY = {
    "BoosterRegressor": ("repro.api.estimator", "BoosterRegressor"),
    "BoosterClassifier": ("repro.api.estimator", "BoosterClassifier"),
    "save": ("repro.api.serialize", "save"),
    "load": ("repro.api.serialize", "load"),
    "save_checkpoint": ("repro.api.serialize", "save_checkpoint"),
    "load_checkpoint": ("repro.api.serialize", "load_checkpoint"),
    "pack": ("repro.api.serialize", "pack"),
    "unpack": ("repro.api.serialize", "unpack"),
    # dataset helpers re-exported so the quickstart needs one import root
    "make_tabular": ("repro.data.synthetic", "make_tabular"),
    "paper_dataset": ("repro.data.synthetic", "paper_dataset"),
    # out-of-core sources (fit(data=...) inputs)
    "DataSource": ("repro.data.pipeline", "DataSource"),
    "ArraySource": ("repro.data.pipeline", "ArraySource"),
    "NpzShardSource": ("repro.data.pipeline", "NpzShardSource"),
    "SyntheticSource": ("repro.data.synthetic", "SyntheticSource"),
    "write_npz_shards": ("repro.data.pipeline", "write_npz_shards"),
    # distributed training engine (fit(mesh=...) / train_distributed)
    "DistributedConfig": ("repro.distributed.trainer", "DistributedConfig"),
    "train_distributed": ("repro.distributed.trainer", "train_distributed"),
    "data_parallel_mesh": ("repro.distributed.trainer",
                           "data_parallel_mesh"),
    # the serving daemon (deadline batching + hot-swap model registry)
    "Server": ("repro.serving", "Server"),
    "ModelRegistry": ("repro.serving", "ModelRegistry"),
    "Request": ("repro.serving", "Request"),
    "warmup_buckets": ("repro.serving", "warmup_buckets"),
    "ServerHealth": ("repro.serving", "ServerHealth"),
    # the resilience layer (recovery policies, retrying sources, typed
    # failures, fault injection)
    "RecoveryPolicy": ("repro.resilience", "RecoveryPolicy"),
    "RetryPolicy": ("repro.resilience", "RetryPolicy"),
    "RetryingSource": ("repro.resilience", "RetryingSource"),
    "FaultSchedule": ("repro.resilience", "FaultSchedule"),
    "GracefulShutdown": ("repro.resilience", "GracefulShutdown"),
    "TrainingInterrupted": ("repro.resilience", "TrainingInterrupted"),
    "NumericalDivergenceError": ("repro.resilience",
                                 "NumericalDivergenceError"),
    "QueueFullError": ("repro.resilience", "QueueFullError"),
    "DeadlineExceededError": ("repro.resilience", "DeadlineExceededError"),
    "DispatcherCrashError": ("repro.resilience", "DispatcherCrashError"),
    "ShardCorruptionError": ("repro.resilience", "ShardCorruptionError"),
}

__all__ = ["ExecutionPlan", "resolve_plan"] + sorted(_LAZY)


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib
    value = getattr(importlib.import_module(mod_name), attr)
    globals()[name] = value  # cache for subsequent lookups
    return value


def __dir__():
    return sorted(set(globals()) | set(_LAZY))

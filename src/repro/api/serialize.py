"""One serialization story for every GBDT artifact (npz + json meta).

A *bundle* is a directory holding ``arrays.npz`` (all array payloads,
slash-named) and ``manifest.json`` (scalars + a sha256 of the payload),
written with the checkpoint layer's two-phase atomic commit.  The same
packed format covers all three artifact shapes:

  * a bare :class:`~repro.core.gbdt.GBDTModel`   (arrays + model meta)
  * a :class:`~repro.core.inference.GBDTPipeline` (+ binner state)
  * a fitted ``repro.api`` estimator              (+ constructor params)

so a training checkpoint, a pipeline and an estimator all round-trip
through :func:`save` / :func:`load` — and through the fault-tolerant step
checkpoints via :func:`save_checkpoint` / :func:`load_checkpoint`, which
ride :func:`repro.distributed.checkpoint.save_named` (atomic rename,
sha256 verification, ``keep_last`` GC, corrupt-step fallback).
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.binning import Binner
from repro.core.gbdt import GBDTModel, model_from_meta
from repro.core.inference import GBDTPipeline
from repro.distributed import checkpoint as ckpt
from repro.kernels.ref import TreeArrays

FORMAT = "repro-gbdt-bundle"
VERSION = 1


# --------------------------------------------------------------------------
# pack / unpack — the canonical in-memory form
# --------------------------------------------------------------------------
def _pack_parts(model: GBDTModel, binner: Optional[Binner] = None,
                estimator_meta: Optional[Dict] = None
                ) -> Tuple[Dict[str, np.ndarray], Dict]:
    arrays = {f"model/trees/{k}": np.asarray(v)
              for k, v in model.trees._asdict().items()}
    meta: Dict[str, Any] = {
        "format": FORMAT, "version": VERSION,
        "model": model.meta(),
    }
    if binner is not None:
        arrays["binner/edges"] = np.asarray(binner._edges)
        arrays["binner/is_cat"] = np.asarray(binner._is_cat)
        arrays["binner/n_value_bins"] = np.asarray(binner._n_value_bins)
        meta["binner"] = {
            "max_bins": int(binner.max_bins),
            "categorical_fields": sorted(int(c)
                                         for c in binner.categorical_fields),
        }
    if estimator_meta is not None:
        meta["estimator"] = estimator_meta
    return arrays, meta


def pack(obj: Any) -> Tuple[Dict[str, np.ndarray], Dict]:
    """Decompose a model / pipeline / fitted estimator into the canonical
    ``(arrays, meta)`` pair (arrays npz-able, meta pure JSON)."""
    from repro.api.estimator import BoosterEstimator  # local: import cycle
    if isinstance(obj, BoosterEstimator):
        return obj._pack()
    if isinstance(obj, GBDTPipeline):
        return _pack_parts(obj.model, obj.binner)
    if isinstance(obj, GBDTModel):
        return _pack_parts(obj)
    raise TypeError(f"cannot serialize {type(obj).__name__}; expected a "
                    "GBDTModel, GBDTPipeline, or fitted estimator")


def _unpack_model(arrays: Dict[str, np.ndarray], meta: Dict) -> GBDTModel:
    trees = TreeArrays(**{f: jnp.asarray(arrays[f"model/trees/{f}"])
                          for f in TreeArrays._fields})
    return model_from_meta(trees, meta["model"])


def _unpack_binner(arrays: Dict[str, np.ndarray], meta: Dict) -> Binner:
    b = Binner(int(meta["binner"]["max_bins"]),
               [int(c) for c in meta["binner"]["categorical_fields"]])
    b._edges = np.asarray(arrays["binner/edges"])
    b._is_cat = np.asarray(arrays["binner/is_cat"])
    b._n_value_bins = np.asarray(arrays["binner/n_value_bins"])
    return b


def unpack(arrays: Dict[str, np.ndarray], meta: Dict) -> Any:
    """Rebuild the richest artifact the payload describes: estimator when
    constructor params are present, else pipeline when the binner is, else
    the bare model."""
    if meta.get("format") != FORMAT:
        raise ValueError(f"not a {FORMAT} payload: format={meta.get('format')!r}")
    model = _unpack_model(arrays, meta)
    binner = _unpack_binner(arrays, meta) if "binner" in meta else None
    if "estimator" in meta:
        from repro.api.estimator import BoosterEstimator
        if binner is None:
            raise ValueError("estimator payload is missing its binner state")
        return BoosterEstimator._from_parts(meta["estimator"], model, binner)
    if binner is not None:
        return GBDTPipeline(binner=binner, model=model)
    return model


# --------------------------------------------------------------------------
# standalone bundles — save(path) / load(path)
# --------------------------------------------------------------------------
def save(path: str, obj: Any) -> str:
    """Atomically write ``obj`` as a bundle directory at ``path``."""
    arrays, meta = pack(obj)
    parent = os.path.dirname(os.path.abspath(path))
    os.makedirs(parent, exist_ok=True)
    return ckpt.write_payload_dir(os.path.abspath(path), arrays,
                                  {"names": sorted(arrays), "meta": meta})


def load(path: str) -> Any:
    """Load a bundle written by :func:`save` (sha256-verified)."""
    manifest = ckpt.validate_payload_dir(path)
    if manifest is None:
        raise FileNotFoundError(f"no valid bundle at {path!r}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        arrays = {k: z[k] for k in manifest["names"]}
    return unpack(arrays, manifest["meta"])


# --------------------------------------------------------------------------
# step checkpoints — the fault-tolerant training flow
# --------------------------------------------------------------------------
def save_checkpoint(directory: str, obj: Any, step: int, *,
                    keep_last: int = 3) -> str:
    """Checkpoint ``obj`` under ``directory/step_<k>`` (atomic, GC'd)."""
    arrays, meta = pack(obj)
    return ckpt.save_named(directory, arrays, step, keep_last=keep_last,
                           extra_meta=meta)


def load_checkpoint(directory: str, *, step: Optional[int] = None
                    ) -> Tuple[Any, int]:
    """Restore the newest valid step checkpoint; returns ``(obj, step)``."""
    arrays, s, meta = ckpt.restore_named(directory, step=step)
    return unpack(arrays, meta), s


def has_checkpoint(directory: str) -> bool:
    return bool(ckpt.list_steps(directory))


def _json_safe(value: Any) -> Any:
    """Coerce estimator params to JSON-stable types (tuples/arrays of
    categorical field ids become int lists, numpy scalars become python)."""
    if isinstance(value, (list, tuple, np.ndarray, frozenset, set)):
        return sorted(int(v) for v in value)
    if isinstance(value, np.generic):
        return value.item()
    return value


def estimator_params_to_meta(params: Dict[str, Any]) -> Dict[str, Any]:
    out = {}
    for k, v in params.items():
        if k == "plan":
            continue  # plans are runtime substrate choices, not model state
        out[k] = _json_safe(v)
    json.dumps(out)  # fail fast on anything non-serializable
    return out

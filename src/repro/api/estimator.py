"""sklearn/XGBoost-style estimators over the Booster training engine.

``BoosterRegressor`` / ``BoosterClassifier`` own the whole vertical: raw
NaN-carrying feature matrices in, predictions out.  Binning (quantile
sketch + categorical collapse), kernel-strategy selection (via
:class:`~repro.api.plan.ExecutionPlan`), training (``core.gbdt.train``),
fault-tolerant checkpointing and sharded batch inference all live behind
``fit`` / ``predict`` — callers never touch ``GBDTConfig`` or
``bin_dataset`` directly.
"""
from __future__ import annotations

import warnings
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.api import serialize
from repro.api.plan import ExecutionPlan
from repro.core.binning import Binner
from repro.core.gbdt import (GBDTConfig, GBDTModel, TrainResult,
                             _predict_one_tree, train)
from repro.core.inference import (GBDTPipeline, feature_importance,
                                  pad_trees, sharded_predict)
from repro.kernels.ref import TreeArrays

_PARAM_DEFAULTS: Dict[str, Any] = dict(
    n_trees=100, max_depth=6, learning_rate=0.1, lambda_=1.0, gamma=0.0,
    min_child_weight=1.0, objective=None, subsample=1.0,
    colsample_bytree=1.0, grow_policy="depthwise", max_leaves=None,
    early_stopping_rounds=None, max_bins=256, categorical_fields=None,
    seed=0, plan=None)


class NotFittedError(RuntimeError):
    """Raised when predict/save is called before ``fit``."""


class BoosterEstimator:
    """Base estimator: hyper-parameters + a fitted (binner, model) pair.

    ``get_params`` / ``set_params`` follow the sklearn contract; every
    constructor argument is a tunable hyper-parameter.  ``plan`` (an
    :class:`ExecutionPlan`) is the execution substrate choice and may be
    overridden per ``fit``/``predict`` call.
    """

    _default_objective: str = "reg:squarederror"

    def __init__(self, **params):
        unknown = set(params) - set(_PARAM_DEFAULTS)
        if unknown:
            raise TypeError(f"unknown estimator parameter(s): "
                            f"{sorted(unknown)}")
        for name, default in _PARAM_DEFAULTS.items():
            setattr(self, name, self._normalize(name,
                                                params.get(name, default)))
        self._model: Optional[GBDTModel] = None
        self._binner: Optional[Binner] = None
        self._result: Optional[TrainResult] = None

    @staticmethod
    def _normalize(name: str, value: Any) -> Any:
        # sequences (lists/arrays of categorical field ids) become plain
        # int tuples so params stay hashable, comparable and JSON-safe
        if (name == "categorical_fields" and value is not None
                and not isinstance(value, tuple)):
            return tuple(int(c) for c in value)
        return value

    # -- sklearn plumbing --------------------------------------------------
    def get_params(self, deep: bool = True) -> Dict[str, Any]:
        return {name: getattr(self, name) for name in _PARAM_DEFAULTS}

    def set_params(self, **params) -> "BoosterEstimator":
        unknown = set(params) - set(_PARAM_DEFAULTS)
        if unknown:
            raise ValueError(f"invalid parameter(s) for "
                             f"{type(self).__name__}: {sorted(unknown)}")
        for name, value in params.items():
            setattr(self, name, self._normalize(name, value))
        return self

    def __repr__(self) -> str:
        changed = {k: v for k, v in self.get_params().items()
                   if v != _PARAM_DEFAULTS[k]}
        args = ", ".join(f"{k}={v!r}" for k, v in sorted(changed.items()))
        return f"{type(self).__name__}({args})"

    # -- fitted-state access ----------------------------------------------
    @property
    def is_fitted(self) -> bool:
        return self._model is not None

    def _check_fitted(self) -> GBDTModel:
        if self._model is None:
            raise NotFittedError(
                f"this {type(self).__name__} instance is not fitted yet; "
                "call fit(X, y) first")
        return self._model

    @property
    def model_(self) -> GBDTModel:
        return self._check_fitted()

    @property
    def binner_(self) -> Binner:
        self._check_fitted()
        return self._binner

    @property
    def n_trees_(self) -> int:
        return self._check_fitted().n_trees

    @property
    def history_(self) -> Dict[str, list]:
        self._check_fitted()
        return self._result.history if self._result is not None else {}

    def evals_result(self) -> Dict[str, list]:
        return self.history_

    @property
    def step_times_(self) -> Dict[str, float]:
        """Accumulated seconds per paper step from the last ``fit``."""
        self._check_fitted()
        return self._result.step_times if self._result is not None else {}

    @property
    def feature_importances_(self) -> np.ndarray:
        """Gain-style per-field importances (normalized to sum 1)."""
        return feature_importance(self._check_fitted(), kind="gain")

    # -- plan resolution ---------------------------------------------------
    def _resolve_plan(self, plan: Optional[ExecutionPlan]) -> ExecutionPlan:
        if plan is None:
            plan = self.plan
        return (plan if plan is not None else ExecutionPlan()).resolved()

    def _config(self, n_trees: int) -> GBDTConfig:
        return GBDTConfig(
            n_trees=n_trees, max_depth=self.max_depth,
            learning_rate=self.learning_rate, lambda_=self.lambda_,
            gamma=self.gamma, min_child_weight=self.min_child_weight,
            objective=self.objective or self._default_objective,
            subsample=self.subsample,
            colsample_bytree=self.colsample_bytree,
            grow_policy=self.grow_policy, max_leaves=self.max_leaves,
            early_stopping_rounds=self.early_stopping_rounds,
            seed=self.seed)

    # -- fit ---------------------------------------------------------------
    def fit(self, X, y, *, eval_set: Optional[Tuple] = None,
            xgb_model: Any = None, plan: Optional[ExecutionPlan] = None,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 25, callback=None,
            verbose: bool = False) -> "BoosterEstimator":
        """Bin ``X`` (raw floats, NaN == missing) and boost ``self.n_trees``
        trees.

        eval_set:        optional raw ``(X_val, y_val)`` pair — enables the
                         eval history and ``early_stopping_rounds``.
        xgb_model:       warm start: a fitted estimator, ``GBDTPipeline``,
                         ``GBDTModel``, or a bundle path — ``n_trees``
                         *additional* trees are grown (XGBoost semantics).
        plan:            ExecutionPlan override for this fit.
        checkpoint_dir:  when set, resumes from the newest valid step
                         checkpoint and writes one every
                         ``checkpoint_every`` trees (atomic, sha-verified).
                         An explicit ``xgb_model`` takes precedence over
                         any existing checkpoints (a warning is emitted).
        """
        plan = self._resolve_plan(plan)
        X = np.asarray(X, dtype=np.float64)
        n_trees = self.n_trees

        init_model, binner = self._warm_start(xgb_model)
        if checkpoint_dir is not None and serialize.has_checkpoint(
                checkpoint_dir):
            if xgb_model is not None:
                warnings.warn(
                    f"{checkpoint_dir!r} already holds checkpoints; the "
                    "explicit xgb_model wins and they are ignored (new "
                    "checkpoints will overwrite colliding steps)",
                    UserWarning, stacklevel=2)
            else:
                try:
                    restored, step = serialize.load_checkpoint(
                        checkpoint_dir)
                except (FileNotFoundError, ValueError, KeyError):
                    # step dirs exist but none hold a valid bundle payload
                    # (legacy format or corruption) — train fresh
                    restored = None
                if restored is not None:
                    init_model, binner = self._warm_parts(restored)
                    n_trees = max(0, self.n_trees - init_model.n_trees)
                    if verbose:
                        print(f"[{type(self).__name__}] resuming from "
                              f"checkpoint step {step} "
                              f"({init_model.n_trees} trees)")

        if init_model is not None:
            # fail early with a clear message instead of a shape error
            # when stacking warm-start trees with freshly grown ones
            obj = self.objective or self._default_objective
            if init_model.max_depth != self.max_depth:
                raise ValueError(
                    f"warm-start/checkpoint model has max_depth="
                    f"{init_model.max_depth} but this estimator is "
                    f"configured with max_depth={self.max_depth}")
            if init_model.objective != obj:
                raise ValueError(
                    f"warm-start/checkpoint model was trained with "
                    f"objective={init_model.objective!r} but this "
                    f"estimator uses {obj!r}")

        if binner is None:
            binner = Binner(max_bins=self.max_bins,
                            categorical_fields=self.categorical_fields)
            binner.fit(X)
        data = binner.transform(X)
        ev = None
        if eval_set is not None:
            X_val, y_val = eval_set
            ev = (binner.transform(np.asarray(X_val, dtype=np.float64)),
                  np.asarray(y_val, dtype=np.float32))

        def cb(t_idx, model):
            if callback is not None:
                callback(t_idx, model)
            if (checkpoint_dir is not None
                    and (t_idx + 1) % checkpoint_every == 0):
                serialize.save_checkpoint(
                    checkpoint_dir,
                    GBDTPipeline(binner=binner, model=model), t_idx + 1)

        result = train(self._config(n_trees), data, y, eval_set=ev,
                       init_model=init_model, callback=cb, verbose=verbose,
                       plan=plan)
        self._model, self._binner, self._result = result.model, binner, result
        if checkpoint_dir is not None:
            serialize.save_checkpoint(checkpoint_dir, self,
                                      result.model.n_trees)
        return self

    def _warm_start(self, xgb_model: Any
                    ) -> Tuple[Optional[GBDTModel], Optional[Binner]]:
        if xgb_model is None:
            return None, None
        if isinstance(xgb_model, str):
            xgb_model = serialize.load(xgb_model)
        return self._warm_parts(xgb_model)

    @staticmethod
    def _warm_parts(obj: Any) -> Tuple[GBDTModel, Optional[Binner]]:
        if isinstance(obj, BoosterEstimator):
            return obj._check_fitted(), obj._binner
        if isinstance(obj, GBDTPipeline):
            return obj.model, obj.binner
        if isinstance(obj, GBDTModel):
            return obj, None
        raise TypeError(f"cannot warm-start from {type(obj).__name__}")

    # -- predict -----------------------------------------------------------
    def _bin(self, X) -> Any:
        self._check_fitted()
        return self._binner.transform(np.asarray(X, dtype=np.float64))

    def predict_margin(self, X, *, plan: Optional[ExecutionPlan] = None
                       ) -> jax.Array:
        """Raw ensemble margins for raw (unbinned) ``X``.

        A plan carrying a ``mesh`` dispatches the paper's §III-D scheme:
        trees shard round-robin over the mesh's ``"model"`` axis (the
        ensemble is zero-padded to divide it), records over the data axes.
        """
        model = self._check_fitted()
        plan = self._resolve_plan(plan)
        data = self._bin(X)
        if plan.mesh is not None:
            padded = pad_trees(model, plan.mesh.shape["model"])
            return sharded_predict(plan.mesh, padded, data.codes)
        return model.predict_margin(data.codes, plan=plan)

    def predict(self, X, *, plan: Optional[ExecutionPlan] = None
                ) -> jax.Array:
        model = self._check_fitted()
        return model.loss.transform(self.predict_margin(X, plan=plan))

    def staged_predict(self, X, *, plan: Optional[ExecutionPlan] = None
                       ) -> Iterator[jax.Array]:
        """Yield predictions after each boosting stage (1..n_trees trees).

        The k-th yield equals ``predict`` of the k-tree prefix ensemble;
        on the training matrix its loss reproduces
        ``history_["train_loss"][k-1]`` exactly.
        """
        model = self._check_fitted()
        plan = self._resolve_plan(plan)
        data = self._bin(X)
        n = data.codes.shape[0]
        margin = jax.numpy.full((n,), model.base_margin, jax.numpy.float32)
        for t in range(model.n_trees):
            tree = TreeArrays(*[a[t] for a in model.trees])
            margin = margin + _predict_one_tree(tree, data, plan)
            yield model.loss.transform(margin)

    # -- serialization -----------------------------------------------------
    def _pack(self):
        model = self._check_fitted()
        meta = {"class": type(self).__name__,
                "params": serialize.estimator_params_to_meta(
                    self.get_params())}
        return serialize._pack_parts(model, self._binner, meta)

    @classmethod
    def _from_parts(cls, est_meta: Dict, model: GBDTModel,
                    binner: Binner) -> "BoosterEstimator":
        klass = {c.__name__: c for c in (BoosterRegressor,
                                         BoosterClassifier)}.get(
            est_meta.get("class"), cls)
        est = klass(**est_meta.get("params", {}))
        est._model, est._binner = model, binner
        return est

    def save(self, path: str) -> str:
        """Write this fitted estimator as an atomic npz+json bundle."""
        return serialize.save(path, self)

    @classmethod
    def load(cls, path: str) -> "BoosterEstimator":
        obj = serialize.load(path)
        if isinstance(obj, GBDTPipeline):     # promote: same payload family
            est = cls()
            est._model, est._binner = obj.model, obj.binner
            return est
        if not isinstance(obj, BoosterEstimator):
            raise TypeError(f"bundle at {path!r} holds a "
                            f"{type(obj).__name__}, not an estimator")
        return obj

    def to_pipeline(self) -> GBDTPipeline:
        """The binner+model bundle view (for the functional APIs)."""
        return GBDTPipeline(binner=self.binner_, model=self.model_)


class BoosterRegressor(BoosterEstimator):
    """Gradient-boosted regression trees (default squared-error loss)."""

    _default_objective = "reg:squarederror"


class BoosterClassifier(BoosterEstimator):
    """Gradient-boosted binary classifier (default logistic loss).

    ``predict`` returns hard 0/1 labels; ``predict_proba`` the class
    probabilities, XGBoost-style.
    """

    _default_objective = "binary:logistic"

    def predict_proba(self, X, *, plan: Optional[ExecutionPlan] = None
                      ) -> np.ndarray:
        model = self._check_fitted()
        p = np.asarray(model.loss.transform(
            self.predict_margin(X, plan=plan)))
        return np.stack([1.0 - p, p], axis=-1)

    def predict(self, X, *, plan: Optional[ExecutionPlan] = None
                ) -> np.ndarray:
        return (self.predict_proba(X, plan=plan)[:, 1] > 0.5).astype(
            np.int32)

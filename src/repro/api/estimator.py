"""sklearn/XGBoost-style estimators over the Booster training engine.

``BoosterRegressor`` / ``BoosterClassifier`` own the whole vertical: raw
NaN-carrying feature matrices in, predictions out.  Binning (quantile
sketch + categorical collapse), kernel-strategy selection (via
:class:`~repro.api.plan.ExecutionPlan`), training (``core.gbdt.train``),
fault-tolerant checkpointing and sharded batch inference all live behind
``fit`` / ``predict`` — callers never touch ``GBDTConfig`` or
``bin_dataset`` directly.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Dict, Iterator, Optional, Tuple

import jax
import numpy as np

from repro.api import serialize
from repro.api.plan import ExecutionPlan
from repro.core.binning import Binner
from repro.core.gbdt import (GBDTConfig, GBDTModel, TrainResult,
                             _predict_forest, _predict_one_tree, train)
from repro.core.inference import (GBDTPipeline, feature_importance,
                                  pad_trees, sharded_predict)
from repro.kernels.ref import TreeArrays
from repro.resilience.errors import TrainingInterrupted
from repro.resilience.recovery import RecoveryPolicy


def _validate_labels(y: np.ndarray, what: str = "y") -> None:
    """Reject NaN/inf labels up front: one non-finite label poisons every
    gradient (the loss reduces over all rows), so the fit would silently
    produce a garbage model instead of failing here with the row index."""
    if np.issubdtype(y.dtype, np.number):
        finite = np.isfinite(np.asarray(y, np.float64))
        if not finite.all():
            bad = int(y.shape[0] - finite.sum())
            first = int(np.argmin(finite))
            raise ValueError(
                f"{what} contains {bad} non-finite label(s) (first at row "
                f"{first}); NaN/inf labels are never valid — clean or drop "
                "those rows before fitting")


def _validate_fit_arrays(X: np.ndarray, y: np.ndarray,
                         what: str = "fit") -> None:
    """Shape/content checks shared by the fit entry points: 2-D X, equal
    lengths, at least one row, finite labels."""
    if X.ndim != 2:
        raise ValueError(
            f"{what} expects a 2-D feature matrix, got shape {X.shape}")
    if X.shape[0] == 0:
        raise ValueError(f"{what} received an empty dataset (X has 0 rows)")
    if y.shape[0] != X.shape[0]:
        raise ValueError(
            f"{what}: X has {X.shape[0]} rows but y has {y.shape[0]} "
            "labels — they must align row-for-row")
    _validate_labels(y, what=f"{what} labels")

_PARAM_DEFAULTS: Dict[str, Any] = dict(
    n_trees=100, max_depth=6, learning_rate=0.1, lambda_=1.0, gamma=0.0,
    min_child_weight=1.0, objective=None, subsample=1.0,
    colsample_bytree=1.0, goss_top_rate=0.0, goss_other_rate=0.0,
    grow_policy="depthwise", max_leaves=None, fused_rounds=False,
    log_every=10,
    early_stopping_rounds=None, max_bins=256, categorical_fields=None,
    sketch_size=32768, n_classes=None, seed=0, plan=None)


class NotFittedError(RuntimeError):
    """Raised when predict/save is called before ``fit``."""


class BoosterEstimator:
    """Base estimator: hyper-parameters + a fitted (binner, model) pair.

    ``get_params`` / ``set_params`` follow the sklearn contract; every
    constructor argument is a tunable hyper-parameter.  ``plan`` (an
    :class:`ExecutionPlan`) is the execution substrate choice and may be
    overridden per ``fit``/``predict`` call.
    """

    _default_objective: str = "reg:squarederror"

    def __init__(self, **params):
        unknown = set(params) - set(_PARAM_DEFAULTS)
        if unknown:
            raise TypeError(f"unknown estimator parameter(s): "
                            f"{sorted(unknown)}")
        for name, default in _PARAM_DEFAULTS.items():
            setattr(self, name, self._normalize(name,
                                                params.get(name, default)))
        self._model: Optional[GBDTModel] = None
        self._binner: Optional[Binner] = None
        self._result: Optional[TrainResult] = None

    @staticmethod
    def _normalize(name: str, value: Any) -> Any:
        # sequences (lists/arrays of categorical field ids) become plain
        # int tuples so params stay hashable, comparable and JSON-safe
        if (name == "categorical_fields" and value is not None
                and not isinstance(value, tuple)):
            return tuple(int(c) for c in value)
        return value

    # -- sklearn plumbing --------------------------------------------------
    def get_params(self, deep: bool = True) -> Dict[str, Any]:
        return {name: getattr(self, name) for name in _PARAM_DEFAULTS}

    def set_params(self, **params) -> "BoosterEstimator":
        unknown = set(params) - set(_PARAM_DEFAULTS)
        if unknown:
            raise ValueError(f"invalid parameter(s) for "
                             f"{type(self).__name__}: {sorted(unknown)}")
        for name, value in params.items():
            setattr(self, name, self._normalize(name, value))
        return self

    def __repr__(self) -> str:
        changed = {k: v for k, v in self.get_params().items()
                   if v != _PARAM_DEFAULTS[k]}
        args = ", ".join(f"{k}={v!r}" for k, v in sorted(changed.items()))
        return f"{type(self).__name__}({args})"

    # -- fitted-state access ----------------------------------------------
    @property
    def is_fitted(self) -> bool:
        return self._model is not None

    def _check_fitted(self) -> GBDTModel:
        if self._model is None:
            raise NotFittedError(
                f"this {type(self).__name__} instance is not fitted yet; "
                "call fit(X, y) first")
        return self._model

    @property
    def model_(self) -> GBDTModel:
        return self._check_fitted()

    @property
    def binner_(self) -> Binner:
        self._check_fitted()
        return self._binner

    @property
    def n_trees_(self) -> int:
        return self._check_fitted().n_trees

    @property
    def history_(self) -> Dict[str, list]:
        self._check_fitted()
        return self._result.history if self._result is not None else {}

    def evals_result(self) -> Dict[str, list]:
        return self.history_

    @property
    def step_times_(self) -> Dict[str, float]:
        """Accumulated seconds per paper step from the last ``fit``."""
        self._check_fitted()
        return self._result.step_times if self._result is not None else {}

    @property
    def stats_(self) -> Dict[str, Any]:
        """Trainer extras from the last ``fit`` (streaming fits report
        n_rows / chunk_rows / n_chunks / passes_per_round)."""
        self._check_fitted()
        return self._result.stats if self._result is not None else {}

    @property
    def feature_importances_(self) -> np.ndarray:
        """Gain-style per-field importances (normalized to sum 1)."""
        return feature_importance(self._check_fitted(), kind="gain")

    # -- plan resolution ---------------------------------------------------
    def _resolve_plan(self, plan: Optional[ExecutionPlan]) -> ExecutionPlan:
        if plan is None:
            plan = self.plan
        return (plan if plan is not None else ExecutionPlan()).resolved()

    def _resolve_objective(self, y: np.ndarray
                           ) -> Tuple[str, Optional[int]]:
        """(objective, n_classes) for this fit.  The classifier overrides
        this to auto-detect multi-class label sets."""
        return self.objective or self._default_objective, self.n_classes

    def _config(self, n_trees: int, objective: Optional[str] = None,
                n_classes: Optional[int] = None) -> GBDTConfig:
        """``objective``/``n_classes`` are the *resolved* pair from
        ``_resolve_objective``.  ``n_classes`` is used verbatim — a
        resolved scalar objective deliberately carries K=None, so unlike
        ``objective`` it must NOT fall back to the constructor param."""
        return GBDTConfig(
            n_trees=n_trees, max_depth=self.max_depth,
            learning_rate=self.learning_rate, lambda_=self.lambda_,
            gamma=self.gamma, min_child_weight=self.min_child_weight,
            objective=objective or self.objective or self._default_objective,
            subsample=self.subsample,
            colsample_bytree=self.colsample_bytree,
            goss_top_rate=self.goss_top_rate,
            goss_other_rate=self.goss_other_rate,
            grow_policy=self.grow_policy, max_leaves=self.max_leaves,
            fused_rounds=self.fused_rounds, log_every=self.log_every,
            early_stopping_rounds=self.early_stopping_rounds,
            n_classes=n_classes,
            seed=self.seed)

    # -- fit ---------------------------------------------------------------
    def fit(self, X=None, y=None, *, data: Any = None,
            eval_set: Optional[Tuple] = None,
            xgb_model: Any = None, plan: Optional[ExecutionPlan] = None,
            mesh: Optional[jax.sharding.Mesh] = None,
            checkpoint_dir: Optional[str] = None,
            checkpoint_every: int = 25, callback=None,
            verbose: bool = False,
            recovery: Optional[RecoveryPolicy] = None,
            shutdown: Any = None
            ) -> "BoosterEstimator":
        """Bin ``X`` (raw floats, NaN == missing) and boost ``self.n_trees``
        trees.

        data:            out-of-core alternative to ``(X, y)``: a
                         :class:`repro.data.DataSource` (or an npz-shard
                         directory path, or an ``(X, y)`` tuple) streamed
                         in ``plan.chunk_bytes``-sized chunks — bin edges
                         come from quantile *sketches* and the binned
                         matrix is never materialized.  Setting
                         ``plan.chunk_bytes`` with plain ``(X, y)`` arrays
                         also routes through this streaming path.
        eval_set:        optional raw ``(X_val, y_val)`` pair — enables the
                         eval history and ``early_stopping_rounds``.
        xgb_model:       warm start: a fitted estimator, ``GBDTPipeline``,
                         ``GBDTModel``, or a bundle path — ``n_trees``
                         *additional* trees are grown (XGBoost semantics).
        plan:            ExecutionPlan override for this fit.
        mesh:            data-parallel training mesh — records shard over
                         the mesh's data axes and the fit runs through
                         ``repro.distributed.train_distributed`` (per-shard
                         histograms, one psum per level).  Shorthand for
                         ``plan.replace(mesh=mesh)``; incompatible with
                         the streaming (``data=``/``chunk_bytes``) path.
        checkpoint_dir:  when set, resumes from the newest valid step
                         checkpoint and writes one every
                         ``checkpoint_every`` trees (atomic, sha-verified).
                         An explicit ``xgb_model`` takes precedence over
                         any existing checkpoints (a warning is emitted).
        recovery:        a :class:`repro.resilience.RecoveryPolicy` making
                         the fit self-healing on EVERY execution path:
                         streaming fits replay transient failures from
                         checkpoint/memory and degrade chunk size on OOM;
                         distributed (``mesh=``) fits re-mesh on
                         preemption, sub-batch histograms on OOM and
                         retry transients; all trainers arm numerical
                         divergence sentinels (rollback + LR backoff).
                         Its ``checkpoint_dir`` defaults to this fit's
                         ``checkpoint_dir``.
        shutdown:        a :class:`repro.resilience.GracefulShutdown` —
                         on SIGTERM/SIGINT the trainer finishes the
                         in-flight round, commits it, and raises a
                         resumable :class:`TrainingInterrupted`.  The
                         estimator keeps the partial model as fitted
                         state and (with ``checkpoint_dir``) persists a
                         resume checkpoint before re-raising.
        """
        plan = self._resolve_plan(plan)
        if mesh is not None:
            plan = plan.replace(mesh=mesh)
        if plan.mesh is not None and (data is not None
                                      or plan.chunk_bytes is not None):
            raise ValueError(
                "distributed training (mesh=) shards in-memory records and "
                "cannot combine with the out-of-core streaming path "
                "(data=/plan.chunk_bytes) — drop one of the two")
        if data is None and plan.chunk_bytes is not None and X is not None:
            if y is None:
                raise TypeError("fit needs (X, y) arrays or data=DataSource")
            from repro.data.pipeline import ArraySource
            # no eager float64 copy — the binner converts per chunk, which
            # is the whole point of the chunk_bytes memory cap
            data, X, y = ArraySource(np.asarray(X), np.asarray(y)), None, None
        if data is not None:
            if X is not None or y is not None:
                raise ValueError(
                    "pass either (X, y) arrays or data=..., not both")
            return self._fit_streaming(
                data, eval_set=eval_set, xgb_model=xgb_model, plan=plan,
                checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every, callback=callback,
                verbose=verbose, recovery=recovery, shutdown=shutdown)
        if (recovery is not None and recovery.checkpoint_dir is None
                and checkpoint_dir is not None):
            recovery = dataclasses.replace(
                recovery, checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every)
        if X is None or y is None:
            raise TypeError("fit needs (X, y) arrays or data=DataSource")
        X = np.asarray(X, dtype=np.float64)
        y = np.asarray(y)
        _validate_fit_arrays(X, y)
        objective, n_classes = self._resolve_objective(y)

        init_model, binner, n_trees = self._resume_or_warm_start(
            xgb_model, checkpoint_dir, verbose)
        objective, n_classes = self._check_warm_model(init_model, objective,
                                                      n_classes)

        if binner is None:
            binner = Binner(max_bins=self.max_bins,
                            categorical_fields=self.categorical_fields)
            binner.fit(X)
        data = binner.transform(X)
        ev = None
        if eval_set is not None:
            X_val, y_val = eval_set
            X_val = np.asarray(X_val, dtype=np.float64)
            y_val = np.asarray(y_val, dtype=np.float32)
            _validate_fit_arrays(X_val, y_val, what="eval_set")
            ev = (binner.transform(X_val), y_val)

        def cb(t_idx, model):
            if callback is not None:
                callback(t_idx, model)
            if (checkpoint_dir is not None
                    and (t_idx + 1) % checkpoint_every == 0):
                serialize.save_checkpoint(
                    checkpoint_dir,
                    GBDTPipeline(binner=binner, model=model), t_idx + 1)

        try:
            result = train(self._config(n_trees, objective, n_classes),
                           data, y, eval_set=ev,
                           init_model=init_model, callback=cb,
                           verbose=verbose, plan=plan, recovery=recovery,
                           shutdown=shutdown)
        except TrainingInterrupted as stop:
            self._finish_interrupted(stop, binner, checkpoint_dir)
            raise
        self._model, self._binner, self._result = result.model, binner, result
        if checkpoint_dir is not None:
            # step numbers count ROUNDS (same unit as the per-round callback
            # saves) so multi-class resume never sees mixed-unit steps
            serialize.save_checkpoint(checkpoint_dir, self,
                                      result.model.n_rounds)
        return self

    def _resume_or_warm_start(self, xgb_model: Any,
                              checkpoint_dir: Optional[str],
                              verbose: bool, stacklevel: int = 3):
        """(init_model, binner, n_trees_to_grow) from an explicit warm
        start and/or the newest valid step checkpoint (xgb_model wins).
        ``stacklevel`` points warnings at the user's fit() call — the
        streaming path adds one frame."""
        n_trees = self.n_trees
        init_model, binner = self._warm_start(xgb_model)
        if checkpoint_dir is not None and serialize.has_checkpoint(
                checkpoint_dir):
            if xgb_model is not None:
                warnings.warn(
                    f"{checkpoint_dir!r} already holds checkpoints; the "
                    "explicit xgb_model wins and they are ignored (new "
                    "checkpoints will overwrite colliding steps)",
                    UserWarning, stacklevel=stacklevel)
            else:
                try:
                    restored, step = serialize.load_checkpoint(
                        checkpoint_dir)
                except (FileNotFoundError, ValueError, KeyError):
                    # step dirs exist but none hold a valid bundle payload
                    # (legacy format or corruption) — train fresh
                    restored = None
                if restored is not None:
                    init_model, binner = self._warm_parts(restored)
                    # multi-class rounds grow K trees each — count rounds
                    n_trees = max(0, self.n_trees - init_model.n_rounds)
                    if verbose:
                        print(f"[{type(self).__name__}] resuming from "
                              f"checkpoint step {step} "
                              f"({init_model.n_rounds} rounds)")
        return init_model, binner, n_trees

    def _check_warm_model(self, init_model: Optional[GBDTModel],
                          objective: str, n_classes: Optional[int]):
        """Validate warm-start/checkpoint compatibility; returns the
        (objective, n_classes) pair the continued fit must use."""
        if init_model is None:
            return objective, n_classes
        # fail early with a clear message instead of a shape error
        # when stacking warm-start trees with freshly grown ones
        if init_model.max_depth != self.max_depth:
            raise ValueError(
                f"warm-start/checkpoint model has max_depth="
                f"{init_model.max_depth} but this estimator is "
                f"configured with max_depth={self.max_depth}")
        if init_model.n_classes > 1:
            # the fitted model's objective/K win: labels observed in a
            # continuation batch are only a LOWER bound on K (the batch
            # may lack the highest classes), so the classifier's
            # auto-detection must not narrow — or flip to binary — an
            # existing softmax model.  Non-classification objectives
            # (an explicit setting, or a regressor's default) are a
            # genuine mismatch.
            if (self.objective not in (None, init_model.objective)
                    or objective not in ("binary:logistic",
                                         init_model.objective)):
                raise ValueError(
                    f"warm-start/checkpoint model was trained with "
                    f"objective={init_model.objective!r} but this "
                    f"estimator uses {objective!r}")
            if self.n_classes not in (None, init_model.n_classes):
                raise ValueError(
                    f"warm-start/checkpoint model has n_classes="
                    f"{init_model.n_classes} but this estimator sets "
                    f"n_classes={self.n_classes}")
            if (n_classes or 0) > init_model.n_classes:
                raise ValueError(
                    f"labels reach class {n_classes - 1} but the "
                    f"warm-start/checkpoint model has n_classes="
                    f"{init_model.n_classes}")
            return init_model.objective, init_model.n_classes
        if init_model.objective != objective:
            raise ValueError(
                f"warm-start/checkpoint model was trained with "
                f"objective={init_model.objective!r} but this "
                f"estimator uses {objective!r}")
        return objective, n_classes

    def _finish_interrupted(self, stop: TrainingInterrupted, binner,
                            checkpoint_dir: Optional[str]) -> None:
        """A graceful shutdown interrupted the fit after a committed round:
        keep the partial ensemble as fitted state and persist a resume
        checkpoint (step == rounds, the same unit the per-round callback
        uses), then let the typed error propagate so the caller decides
        whether to resume (re-fit with the same ``checkpoint_dir``)."""
        if stop.result is None or stop.result.model is None:
            return
        self._model, self._binner = stop.result.model, binner
        self._result = stop.result
        if checkpoint_dir is not None and self._model.n_rounds > 0:
            serialize.save_checkpoint(checkpoint_dir, self,
                                      self._model.n_rounds)
            if stop.checkpoint_dir is None:
                stop.checkpoint_dir = checkpoint_dir

    # -- out-of-core fit ---------------------------------------------------
    def _fit_streaming(self, data, *, eval_set, xgb_model, plan,
                       checkpoint_dir, checkpoint_every, callback,
                       verbose, recovery=None,
                       shutdown=None) -> "BoosterEstimator":
        """``fit`` over a chunked DataSource: one sketch+label pass builds
        the binner (``StreamingBinner``), then ``core.gbdt.train_streaming``
        re-streams chunks per tree level — the full binned matrix never
        exists on device or host."""
        from repro.core.binning import StreamingBinner
        from repro.core.gbdt import train_streaming
        from repro.data.pipeline import as_source

        source = as_source(data)
        F = source.n_fields
        init_model, binner, n_trees = self._resume_or_warm_start(
            xgb_model, checkpoint_dir, verbose, stacklevel=4)

        # pass 0 — gather labels (always) + feed the quantile sketches
        # (only when no warm binner already fixes the bin edges)
        sketch_rows = plan.chunk_rows(F)
        if binner is None:
            binner = StreamingBinner(
                max_bins=self.max_bins,
                categorical_fields=self.categorical_fields,
                sketch_size=self.sketch_size)
            sketch = binner
        else:
            sketch = None
        ys = []
        for X_chunk, y_chunk in source.chunks(sketch_rows):
            if y_chunk is None:
                raise ValueError(
                    "streaming fit needs a labeled DataSource (every "
                    "chunk must yield a y)")
            if sketch is not None:
                sketch.partial_fit(X_chunk)
            ys.append(np.asarray(y_chunk))
        if not ys:
            raise ValueError("DataSource yielded no chunks")
        if sketch is not None:
            sketch.finalize()
        y = np.concatenate(ys)
        _validate_labels(y, what="streamed labels")

        objective, n_classes = self._resolve_objective(y)
        objective, n_classes = self._check_warm_model(init_model, objective,
                                                      n_classes)

        ev = None
        if eval_set is not None:
            X_val, y_val = eval_set
            X_val = np.asarray(X_val, dtype=np.float64)
            y_val = np.asarray(y_val, dtype=np.float32)
            _validate_fit_arrays(X_val, y_val, what="eval_set")
            ev = (binner.transform(X_val), y_val)

        if (recovery is not None and recovery.checkpoint_dir is None
                and checkpoint_dir is not None):
            recovery = dataclasses.replace(
                recovery, checkpoint_dir=checkpoint_dir,
                checkpoint_every=checkpoint_every)
        # when the trainer checkpoints (recovery with a checkpoint_dir),
        # the estimator-side callback must not double-write the same steps
        trainer_saves = (recovery is not None
                         and recovery.checkpoint_dir is not None)

        def cb(t_idx, model):
            if callback is not None:
                callback(t_idx, model)
            if (not trainer_saves and checkpoint_dir is not None
                    and (t_idx + 1) % checkpoint_every == 0):
                serialize.save_checkpoint(
                    checkpoint_dir,
                    GBDTPipeline(binner=binner, model=model), t_idx + 1)

        try:
            result = train_streaming(
                self._config(n_trees, objective, n_classes), source, binner,
                y, eval_set=ev, init_model=init_model, callback=cb,
                verbose=verbose, plan=plan, recovery=recovery,
                shutdown=shutdown)
        except TrainingInterrupted as stop:
            self._finish_interrupted(stop, binner, checkpoint_dir)
            raise
        self._model, self._binner, self._result = result.model, binner, result
        if checkpoint_dir is not None:
            serialize.save_checkpoint(checkpoint_dir, self,
                                      result.model.n_rounds)
        return self

    def _warm_start(self, xgb_model: Any
                    ) -> Tuple[Optional[GBDTModel], Optional[Binner]]:
        if xgb_model is None:
            return None, None
        if isinstance(xgb_model, str):
            xgb_model = serialize.load(xgb_model)
        return self._warm_parts(xgb_model)

    @staticmethod
    def _warm_parts(obj: Any) -> Tuple[GBDTModel, Optional[Binner]]:
        if isinstance(obj, BoosterEstimator):
            return obj._check_fitted(), obj._binner
        if isinstance(obj, GBDTPipeline):
            return obj.model, obj.binner
        if isinstance(obj, GBDTModel):
            return obj, None
        raise TypeError(f"cannot warm-start from {type(obj).__name__}")

    # -- predict -----------------------------------------------------------
    def _bin(self, X) -> Any:
        self._check_fitted()
        return self._binner.transform(np.asarray(X, dtype=np.float64))

    def predict_margin(self, X, *, plan: Optional[ExecutionPlan] = None
                       ) -> jax.Array:
        """Raw ensemble margins for raw (unbinned) ``X``.

        The default path is the serving engine: the batch is binned ON
        DEVICE and dispatched through the compile-once, shape-bucketed
        predict cache (see ``docs/api.md`` — varying request batch sizes
        reuse one compiled step per power-of-two bucket).  A plan
        carrying a ``mesh`` dispatches the paper's §III-D scheme instead:
        trees shard round-robin over the mesh's ``"model"`` axis (the
        ensemble is zero-padded to divide it — and to keep per-shard
        tree counts a multiple of K for multi-class ensembles), records
        over the data axes.
        """
        model = self._check_fitted()
        plan = self._resolve_plan(plan)
        if plan.mesh is not None:
            data = self._bin(X)
            padded = pad_trees(model, plan.mesh.shape["model"]
                               * max(model.n_classes, 1))
            return sharded_predict(plan.mesh, padded, data.codes,
                                   plan=plan)
        return self.to_pipeline().predict_margin(X, plan=plan)

    def predict(self, X, *, plan: Optional[ExecutionPlan] = None
                ) -> jax.Array:
        model = self._check_fitted()
        return model.loss.transform(self.predict_margin(X, plan=plan))

    def staged_predict(self, X, *, plan: Optional[ExecutionPlan] = None
                       ) -> Iterator[jax.Array]:
        """Yield predictions after each boosting stage (1..n_trees rounds).

        For scalar objectives the k-th yield equals ``predict`` of the
        k-tree prefix ensemble; on the training matrix its (margin-space)
        loss reproduces ``history_["train_loss"][k-1]``.  Multi-class
        models add one *forest* (K per-class trees) per stage and yield
        the (n, K) softmax probabilities — i.e. ``predict_proba`` of the
        k-round prefix (``predict`` is its argmax; train_loss operates on
        the pre-softmax margins, not on these rows).
        """
        model = self._check_fitted()
        plan = self._resolve_plan(plan)
        data = self._bin(X)
        n = data.codes.shape[0]
        K = model.n_classes
        if K > 1:
            margin = jax.numpy.broadcast_to(
                jax.numpy.asarray(model.base_margin, jax.numpy.float32),
                (n, K))
            for r in range(model.n_rounds):
                forest = TreeArrays(*[a[r * K:(r + 1) * K]
                                      for a in model.trees])
                margin = margin + _predict_forest(forest, data, plan)
                yield model.loss.transform(margin)
            return
        margin = jax.numpy.full((n,), model.base_margin, jax.numpy.float32)
        for t in range(model.n_trees):
            tree = TreeArrays(*[a[t] for a in model.trees])
            margin = margin + _predict_one_tree(tree, data, plan)
            yield model.loss.transform(margin)

    # -- serialization -----------------------------------------------------
    def _pack(self):
        model = self._check_fitted()
        meta = {"class": type(self).__name__,
                "params": serialize.estimator_params_to_meta(
                    self.get_params())}
        return serialize._pack_parts(model, self._binner, meta)

    @classmethod
    def _from_parts(cls, est_meta: Dict, model: GBDTModel,
                    binner: Binner) -> "BoosterEstimator":
        klass = {c.__name__: c for c in (BoosterRegressor,
                                         BoosterClassifier)}.get(
            est_meta.get("class"), cls)
        est = klass(**est_meta.get("params", {}))
        est._model, est._binner = model, binner
        return est

    def save(self, path: str) -> str:
        """Write this fitted estimator as an atomic npz+json bundle."""
        return serialize.save(path, self)

    @classmethod
    def load(cls, path: str) -> "BoosterEstimator":
        obj = serialize.load(path)
        if isinstance(obj, GBDTPipeline):     # promote: same payload family
            est = cls()
            est._model, est._binner = obj.model, obj.binner
            return est
        if not isinstance(obj, BoosterEstimator):
            raise TypeError(f"bundle at {path!r} holds a "
                            f"{type(obj).__name__}, not an estimator")
        return obj

    def to_pipeline(self) -> GBDTPipeline:
        """The binner+model bundle view (for the functional APIs)."""
        return GBDTPipeline(binner=self.binner_, model=self.model_)


class BoosterRegressor(BoosterEstimator):
    """Gradient-boosted regression trees (default squared-error loss)."""

    _default_objective = "reg:squarederror"


class BoosterClassifier(BoosterEstimator):
    """Gradient-boosted classifier (binary logistic or multi-class softmax).

    The objective is auto-detected from the label set when left unset:
    labels {0, 1} train ``binary:logistic``; integer labels 0..K-1 with
    K > 2 train ``multi:softmax`` with K per-class trees per round.
    ``predict`` returns hard class labels (argmax for K > 2);
    ``predict_proba`` the (n, K) class probabilities, XGBoost-style.
    """

    _default_objective = "binary:logistic"

    def _resolve_objective(self, y: np.ndarray
                           ) -> Tuple[str, Optional[int]]:
        labels = np.unique(np.asarray(y))
        integral = bool(labels.size == 0
                        or (np.all(labels >= 0)
                            and np.all(labels == np.round(labels))))
        if not integral and self.objective in (None, "multi:softmax"):
            # auto-detection and softmax need class ids; an explicit
            # scalar objective may legitimately take soft targets
            # (label-smoothed / distilled logistic labels)
            raise ValueError(
                "classifier labels must be non-negative integers "
                f"(got values like {labels[:5]})")
        # soft labels behave as the 2-"class" scalar case below: the
        # explicit objective stands, and a wide n_classes still conflicts
        detected = (int(labels.max()) + 1 if labels.size and integral
                    else 2)
        if self.objective == "multi:softmax" or (
                self.objective is None
                and (detected > 2 or (self.n_classes or 0) > 2)):
            K = self.n_classes if self.n_classes is not None else max(
                detected, 2)
            if detected > K:
                raise ValueError(
                    f"labels reach class {detected - 1} but n_classes={K}")
            return "multi:softmax", K
        obj = self.objective or self._default_objective
        # binary (incl. an explicit-but-redundant n_classes=2): scalar path.
        # A wider K — whether set explicitly or observed in the labels —
        # conflicts with an explicit scalar objective: fail loudly instead
        # of silently training a binary model on K classes.
        if self.n_classes is not None and self.n_classes > 2:
            raise ValueError(
                f"n_classes={self.n_classes} conflicts with "
                f"objective={obj!r}; use objective='multi:softmax' "
                "(or leave objective unset)")
        if detected > 2:
            raise ValueError(
                f"labels span {detected} classes but objective={obj!r} "
                "is scalar; use objective='multi:softmax' (or leave "
                "objective unset for auto-detection)")
        return obj, None

    def predict_proba(self, X, *, plan: Optional[ExecutionPlan] = None
                      ) -> np.ndarray:
        model = self._check_fitted()
        p = np.asarray(model.loss.transform(
            self.predict_margin(X, plan=plan)))
        if model.n_classes > 1:
            return p                       # (n, K) softmax rows
        return np.stack([1.0 - p, p], axis=-1)

    def predict(self, X, *, plan: Optional[ExecutionPlan] = None
                ) -> np.ndarray:
        model = self._check_fitted()
        if model.n_classes > 1:
            return self.predict_proba(X, plan=plan).argmax(
                axis=-1).astype(np.int32)
        return (self.predict_proba(X, plan=plan)[:, 1] > 0.5).astype(
            np.int32)

"""ExecutionPlan — the single object that decides *where* each GBDT step runs.

Every accelerated step (histogram ①, partition ③, traversal/inference ⑤)
used to take its own ``strategy=`` / ``interpret=`` kwargs, and callers had
to thread three strings plus an interpret flag through ``GBDTConfig``,
``train``, the pipeline and the kernels.  An ``ExecutionPlan`` centralizes
that selection: build one (or let ``ExecutionPlan.auto()`` probe the
backend once), pass it down, and every dispatch layer reads from it.

A plan is a frozen, hashable dataclass, so it can ride through ``jax.jit``
as a static argument — strategy choices are compile-time decisions.

Strategy fields accept ``"auto"``; ``resolved()`` replaces every ``"auto"``
(and a ``None`` interpret flag) with the backend default, so kernels only
ever see concrete choices.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax

HIST_STRATEGIES = ("scatter", "scatter_private", "sort", "onehot",
                   "pallas_grouped", "pallas_packed")
PARTITION_STRATEGIES = ("reference", "pallas")
TRAVERSAL_STRATEGIES = ("reference", "scan", "pallas")


@functools.lru_cache(maxsize=None)
def _backend() -> str:
    """Probe the JAX backend exactly once per process."""
    return jax.default_backend()


def _on_tpu() -> bool:
    return _backend() == "tpu"


def _pallas_ok(step: str) -> bool:
    """One-time per-process probe that the ``step`` Pallas kernel
    launches on this backend (graceful degradation at plan-resolution
    time).  Lazy import: the kernels layer imports this module at load,
    so the dependency must stay runtime-only — and ``resolved()`` is
    never called during that import."""
    from repro.kernels import ops as _ops
    return _ops.pallas_available(step, interpret=not _on_tpu())


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Kernel/strategy/interpret/mesh selection for every GBDT step.

    Fields
    ------
    hist_strategy:       step ① — one of ``HIST_STRATEGIES`` or ``"auto"``
    partition_strategy:  step ③ — ``"reference"`` | ``"pallas"`` | ``"auto"``
    traversal_strategy:  step ⑤ / batch inference — ``"reference"`` (the
                         tree-batched level walk: every tree advances one
                         depth level per pass over the codes),
                         ``"scan"`` (legacy one-tree-at-a-time lax.scan —
                         kept as the baseline the benchmarks compare
                         against), ``"pallas"`` (tree-blocked one-hot
                         kernel), or ``"auto"``
    interpret:           run Pallas kernels in interpret mode (None = auto:
                         interpret everywhere except a real TPU)
    records_per_block:   Pallas histogram grid — records per kernel block
    fields_per_block:    Pallas histogram grid — fields per kernel block
    trees_per_block:     Pallas batch inference (§III-D) — tree tables
                         resident per grid step; each record block fetched
                         into VMEM is amortized across this many trees
                         (the ensemble is zero-padded to a multiple)
    host_offload_split:  run step ② split selection on host (paper's offload)
    hist_subtraction:    step ① sibling subtraction in the level-wise
                         growers — at each level > 0 only the *smaller*
                         child of every split parent is binned explicitly
                         and the sibling histogram is derived as
                         ``parent − smaller`` (paper §II-A, "without any
                         explicit binning at the other child").  ``None``
                         resolves to ``False``: the derived sibling is a
                         float-reassociated value (documented tolerance,
                         see ``docs/api.md``), so the direct pass stays
                         the default
    chunk_bytes:         out-of-core training budget — caps the bytes of
                         binned records resident on device at once; when
                         set, ``fit(data=...)`` streams chunk-sized
                         histogram/partition passes instead of
                         materializing the matrix (None = in-memory)
    packed_codes:        store/stream bin codes 4-bit packed (two per byte,
                         paper §III-B's compressed redundant representation).
                         ``None`` = auto: pack whenever the dataset's
                         ``n_bins <= 16``; ``True`` forces packing (errors
                         above 16 bins); ``False`` forces plain uint8.
                         Affects the resident-bytes model of
                         ``chunk_rows()`` and the layout the streaming
                         trainer writes/ships — results are bit-equal
                         either way
    mesh:                optional ``jax.sharding.Mesh``; when set, ensemble
                         inference shards trees over the ``"model"`` axis and
                         records over the data axes (paper §III-D), and
                         ``train``/``fit`` route through the data-parallel
                         distributed trainer (paper §III-B — per-shard
                         histograms + one psum per level)
    data_axes:           mesh axes carrying *records* during distributed
                         training; ``None`` resolves to every mesh axis
                         except ``"model"`` (``launch.mesh.data_axes``).
                         Only meaningful together with ``mesh``
    """

    hist_strategy: str = "auto"
    partition_strategy: str = "auto"
    traversal_strategy: str = "auto"
    interpret: Optional[bool] = None
    records_per_block: int = 512
    fields_per_block: int = 8
    trees_per_block: int = 8
    host_offload_split: bool = False
    hist_subtraction: Optional[bool] = None
    packed_codes: Optional[bool] = None
    chunk_bytes: Optional[int] = None
    mesh: Optional[jax.sharding.Mesh] = None
    data_axes: Optional[Tuple[str, ...]] = None

    def __post_init__(self):
        if self.data_axes is not None:
            # normalize lists so plans stay hashable jit keys
            object.__setattr__(self, "data_axes",
                               tuple(str(a) for a in self.data_axes))
            if self.mesh is None:
                raise ValueError("data_axes only applies together with a "
                                 "mesh (the distributed-training record "
                                 "axes)")
            missing = set(self.data_axes) - set(self.mesh.axis_names)
            if missing:
                raise ValueError(
                    f"data_axes {sorted(missing)} not present on the mesh "
                    f"(axes: {self.mesh.axis_names})")
        if self.chunk_bytes is not None and self.chunk_bytes <= 0:
            raise ValueError("chunk_bytes must be positive (or None for "
                             "in-memory training)")
        if self.trees_per_block < 1:
            raise ValueError("trees_per_block must be >= 1")
        if self.hist_strategy not in HIST_STRATEGIES + ("auto",):
            raise ValueError(
                f"unknown histogram strategy {self.hist_strategy!r}; "
                f"choose from {HIST_STRATEGIES + ('auto',)}")
        if self.partition_strategy not in PARTITION_STRATEGIES + ("auto",):
            raise ValueError(
                f"unknown partition strategy {self.partition_strategy!r}")
        if self.traversal_strategy not in TRAVERSAL_STRATEGIES + ("auto",):
            raise ValueError(
                f"unknown traversal strategy {self.traversal_strategy!r}")

    # -- construction ------------------------------------------------------
    @classmethod
    def auto(cls, mesh: Optional[jax.sharding.Mesh] = None,
             **overrides) -> "ExecutionPlan":
        """Backend-probed plan: Pallas kernels on TPU, software paths (with
        Pallas interpret-mode validation available) everywhere else."""
        return cls(mesh=mesh, **overrides).resolved()

    @classmethod
    def from_config(cls, config=None,
                    mesh: Optional[jax.sharding.Mesh] = None, *,
                    base: Optional["ExecutionPlan"] = None,
                    distributed: bool = False,
                    hist_strategy: Optional[str] = None
                    ) -> "ExecutionPlan":
        """Lift legacy config-level strategy selections into one plan.

        Two spellings fold here (both deprecated at their call sites,
        kept for one release):

        * ``from_config(config)`` — lift the per-step strategy strings
          off a ``GBDTConfig``.
        * ``from_config(base=plan, hist_strategy=..., distributed=True)``
          — the distributed growers' historical defaults (previously
          ``distributed/sharding._legacy_distributed_plan``): no plan
          means scatter histograms regardless of backend; an explicit
          loose ``hist_strategy`` overrides the plan's field; and
          ``distributed=True`` pins the partition step to the reference
          kernel — it runs inside shard_map'd local functions where the
          Pallas path is untested, and the pre-plan code hardcoded it.

        The result is always :meth:`resolved`.
        """
        if config is not None:
            if base is not None or hist_strategy is not None:
                raise ValueError("pass either config or base/hist_strategy,"
                                 " not both")
            base = cls(hist_strategy=config.hist_strategy,
                       partition_strategy=config.partition_strategy,
                       traversal_strategy=config.traversal_strategy,
                       host_offload_split=config.host_offload_split,
                       mesh=mesh)
        elif base is None:
            base = (cls(hist_strategy=hist_strategy or "scatter", mesh=mesh)
                    if distributed else cls(mesh=mesh))
        plan = resolve_plan(base, hist_strategy=hist_strategy)
        if distributed:
            plan = plan.replace(partition_strategy="reference")
        return plan

    def resolved(self) -> "ExecutionPlan":
        """Replace every ``"auto"`` / ``None`` with the backend default.

        On TPU the ``"auto"`` defaults elect the Pallas kernels — but
        only after a one-time per-process launch probe
        (:func:`repro.kernels.ops.pallas_available`) confirms each
        kernel actually lowers on this backend; a broken lowering
        resolves straight to the jnp twin (graceful degradation at plan
        time, before the first real dispatch).  Explicit strategy
        selections are honored unprobed — the dispatch layer still
        demotes them per call if they fail.
        """
        tpu = _on_tpu()
        kw = {}
        if self.hist_strategy == "auto":
            kw["hist_strategy"] = ("pallas_grouped" if tpu and
                                   _pallas_ok("histogram") else "scatter")
        if self.partition_strategy == "auto":
            kw["partition_strategy"] = ("pallas" if tpu and
                                        _pallas_ok("partition")
                                        else "reference")
        if self.traversal_strategy == "auto":
            kw["traversal_strategy"] = ("pallas" if tpu and
                                        _pallas_ok("traversal")
                                        else "reference")
        if self.interpret is None:
            kw["interpret"] = not tpu
        if self.hist_subtraction is None:
            kw["hist_subtraction"] = False
        return dataclasses.replace(self, **kw) if kw else self

    def replace(self, **changes) -> "ExecutionPlan":
        return dataclasses.replace(self, **changes)

    # -- out-of-core chunking ----------------------------------------------
    DEFAULT_CHUNK_BYTES = 1 << 26          # 64 MiB of resident chunk state

    def chunk_rows(self, n_fields: int, n_classes: int = 1) -> int:
        """Rows per streamed chunk under the ``chunk_bytes`` budget.

        Per-row resident footprint during a chunked pass: the code row
        plus its column-major transpose (2F bytes unpacked; F bytes when
        ``packed_codes`` halves both copies to a nibble each) and the
        per-class float32 g/h/node-id triple (12K bytes).
        """
        budget = self.chunk_bytes or self.DEFAULT_CHUNK_BYTES
        code_bytes = (1 if self.packed_codes else 2) * max(n_fields, 1)
        per_row = code_bytes + 12 * max(n_classes, 1)
        return max(256, budget // per_row)

    def without_chunking(self) -> "ExecutionPlan":
        """Drop ``chunk_bytes`` so kernel-level jits (which take the plan
        as a static argument) don't recompile across chunk budgets."""
        if self.chunk_bytes is None:
            return self
        return dataclasses.replace(self, chunk_bytes=None)

    def describe(self) -> str:
        m = (f"mesh{dict(self.mesh.shape)}" if self.mesh is not None
             else "single-device")
        sub = "+sub" if self.hist_subtraction else ""
        if self.packed_codes is not None:
            sub += f", packed={self.packed_codes}"
        return (f"ExecutionPlan(hist={self.hist_strategy}{sub}, "
                f"partition={self.partition_strategy}, "
                f"traversal={self.traversal_strategy}, "
                f"interpret={self.interpret}, {m})")


def resolve_plan(plan: Optional[ExecutionPlan] = None,
                 **loose) -> ExecutionPlan:
    """Resolve a plan plus config-level loose kwargs into a concrete plan.

    ``loose`` entries that are ``None`` or ``"auto"`` are ignored; any other
    value overrides the plan field of the same name.  This is the lifting
    layer for config-level strategy strings (``GBDTConfig``'s legacy
    fields, ``distributed_histogram(strategy=...)``); the ``kernels.ops``
    entry points take ``plan=`` only.
    """
    loose = {k: v for k, v in loose.items()
             if v is not None and v != "auto"}
    base = plan if plan is not None else ExecutionPlan()
    if loose:
        base = dataclasses.replace(base, **loose)
    return base.resolved()

"""Differentiable losses for gradient boosting.

GB is agnostic to the loss as long as it is differentiable and convex
(paper §II-A); training only ever consumes the per-record first/second
order gradient statistics (g_i, h_i) of the loss at the current margin.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class Loss:
    """A boosting loss: value + (g, h) statistics at the current margin.

    Scalar-margin losses leave ``n_outputs`` at ``None``: margins are (n,)
    and one tree grows per boosting round.  Vector-margin losses (softmax)
    set ``n_outputs = K``: margins are (n, K), ``grad_hess`` returns
    (n, K) statistics, and the trainer grows K per-class trees per round.
    """

    name: str
    # (margin, y) -> per-record loss
    value_fn: Callable[[Array, Array], Array]
    # (margin, y) -> (g, h)
    grad_hess_fn: Callable[[Array, Array], Tuple[Array, Array]]
    # margin -> prediction in output space (e.g. sigmoid for logistic)
    transform_fn: Callable[[Array], Array]
    # constant initial margin given labels
    base_margin_fn: Callable[[Array], Array]
    # vector-margin width (None == scalar margins)
    n_outputs: Optional[int] = None

    def value(self, margin: Array, y: Array) -> Array:
        return self.value_fn(margin, y)

    def grad_hess(self, margin: Array, y: Array) -> Tuple[Array, Array]:
        return self.grad_hess_fn(margin, y)

    def transform(self, margin: Array) -> Array:
        return self.transform_fn(margin)

    def base_margin(self, y: Array) -> Array:
        return self.base_margin_fn(y)


def _sq_value(margin, y):
    return 0.5 * (margin - y) ** 2


def _sq_grad_hess(margin, y):
    return margin - y, jnp.ones_like(margin)


squared_error = Loss(
    name="reg:squarederror",
    value_fn=_sq_value,
    grad_hess_fn=_sq_grad_hess,
    transform_fn=lambda m: m,
    base_margin_fn=lambda y: jnp.mean(y),
)


def _logistic_value(margin, y):
    # numerically stable log(1 + exp(-y'm)) with y in {0, 1}
    return jnp.logaddexp(0.0, margin) - y * margin


def _logistic_grad_hess(margin, y):
    p = jax.nn.sigmoid(margin)
    return p - y, jnp.maximum(p * (1.0 - p), 1e-16)


def _logistic_base(y):
    p = jnp.clip(jnp.mean(y), 1e-6, 1.0 - 1e-6)
    return jnp.log(p / (1.0 - p))


binary_logistic = Loss(
    name="binary:logistic",
    value_fn=_logistic_value,
    grad_hess_fn=_logistic_grad_hess,
    transform_fn=jax.nn.sigmoid,
    base_margin_fn=_logistic_base,
)


def _huber_value(margin, y, delta=1.0):
    r = margin - y
    a = jnp.abs(r)
    return jnp.where(a <= delta, 0.5 * r * r, delta * (a - 0.5 * delta))


def _huber_grad_hess(margin, y, delta=1.0):
    r = margin - y
    g = jnp.clip(r, -delta, delta)
    h = jnp.where(jnp.abs(r) <= delta, jnp.ones_like(r), 1e-2)
    return g, h


pseudo_huber = Loss(
    name="reg:huber",
    value_fn=_huber_value,
    grad_hess_fn=_huber_grad_hess,
    transform_fn=lambda m: m,
    base_margin_fn=lambda y: jnp.median(y),
)

# --------------------------------------------------------------------------
# multi-class softmax (vector margins, K per-class trees per round)
# --------------------------------------------------------------------------
def _softmax_value(margin, y):
    # cross-entropy: logsumexp(m) - m[y], numerically stable
    y = y.astype(jnp.int32)
    picked = jnp.take_along_axis(margin, y[:, None], axis=-1)[:, 0]
    return jax.nn.logsumexp(margin, axis=-1) - picked


def _softmax_grad_hess(margin, y):
    """Exact diagonal of the softmax cross-entropy Hessian.

    g_k = p_k - 1[y == k],  h_k = p_k (1 - p_k)  — matches jax.grad /
    the diagonal of jax.hessian of ``_softmax_value`` (tested)."""
    K = margin.shape[-1]
    p = jax.nn.softmax(margin, axis=-1)
    g = p - jax.nn.one_hot(y.astype(jnp.int32), K, dtype=p.dtype)
    h = jnp.maximum(p * (1.0 - p), 1e-16)
    return g, h


def multi_softmax(n_classes: int) -> Loss:
    """The ``multi:softmax`` objective for a fixed class count ``K``."""
    if n_classes < 2:
        raise ValueError(f"multi:softmax needs n_classes >= 2, "
                         f"got {n_classes}")

    def base_margin(y):
        # log class priors, centered (softmax is shift-invariant; centering
        # keeps margins small and the K=1-compatible float path exact)
        counts = jnp.bincount(y.astype(jnp.int32), length=n_classes)
        p = jnp.clip(counts / jnp.maximum(y.shape[0], 1), 1e-6, 1.0)
        logp = jnp.log(p)
        return logp - jnp.mean(logp)

    return Loss(
        name="multi:softmax",
        value_fn=_softmax_value,
        grad_hess_fn=_softmax_grad_hess,
        transform_fn=lambda m: jax.nn.softmax(m, axis=-1),
        base_margin_fn=base_margin,
        n_outputs=int(n_classes),
    )


LOSSES = {
    squared_error.name: squared_error,
    binary_logistic.name: binary_logistic,
    pseudo_huber.name: pseudo_huber,
}

MULTICLASS_OBJECTIVES = ("multi:softmax",)


def get_loss(name: str, n_classes: Optional[int] = None) -> Loss:
    if name in MULTICLASS_OBJECTIVES:
        if n_classes is None:
            raise ValueError(f"{name!r} requires n_classes")
        return multi_softmax(n_classes)
    if name not in LOSSES:
        raise KeyError(f"unknown loss {name!r}; available: "
                       f"{sorted(LOSSES) + list(MULTICLASS_OBJECTIVES)}")
    return LOSSES[name]

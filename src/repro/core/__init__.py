from repro.core.binning import (BinnedDataset, Binner, StreamingBinner,
                                bin_dataset, dataset_from_codes)
from repro.core.gbdt import (GBDTConfig, GBDTModel, TrainResult, goss_weights,
                             train, train_streaming)
from repro.core.losses import LOSSES, get_loss
from repro.core.splits import SplitDecision, find_best_splits
from repro.core.tree import (fit_forest, fit_forest_chunked, fit_tree,
                             fit_tree_lossguide)
from repro.core.inference import (GBDTPipeline, feature_importance,
                                  pad_trees, sharded_predict)
from repro.kernels.ref import TreeArrays

"""Tree growing — steps ①–④ of the paper's training algorithm.

Two growers, matching the two configurations described in §II-A:

  * ``fit_tree``          — the *level-by-level* configuration ("streams in
    all the input records and histogram-bins the relevant records at each
    vertex ... maintains a separate histogram per vertex").  This is the
    fixed-shape, fully jittable primary path: every record carries a
    level-local node id; one histogram pass per level computes all vertex
    histograms at once; the partition kernel routes records to children.
    One full-data scan per level by default; with
    ``ExecutionPlan.hist_subtraction`` levels > 0 bin only the smaller
    child of every split parent (a compacted half-stream pass) and derive
    the sibling as ``parent − smaller`` — the paper's §II-A trick applied
    level-synchronously.

  * ``fit_tree_lossguide`` — the *vertex-by-vertex* (leaf-wise, best-first)
    configuration with the paper's step-① optimization applied literally:
    bin only the smaller child and derive the sibling by subtracting from
    the parent's histogram ("without any explicit binning at the other
    child", §II-A).  Host-driven control flow (a gain heap), device math.

Both emit the same fixed-shape ``TreeArrays`` (complete binary tree with
pass-through nodes), so every downstream consumer (partition, traversal,
inference, checkpointing, sharding) is grower-agnostic.
"""
from __future__ import annotations

import functools
import heapq
import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import ExecutionPlan, resolve_plan
from repro.core import splits as splits_mod
from repro.core.binning import PackedCodes
from repro.kernels import ops
from repro.kernels.ref import TreeArrays


def _lift_loose_kwargs(plan: Optional[ExecutionPlan],
                       **loose) -> ExecutionPlan:
    """Resolve the growers' plan, lifting any legacy per-step loose kwargs
    (``hist_strategy=`` etc.) into it with a deprecation warning — one
    release path before the growers take ``plan=`` only."""
    passed = sorted(k for k, v in loose.items()
                    if v is not None and v != "auto" and v is not False)
    if passed:
        warnings.warn(
            "legacy strategy-string kwargs are deprecated; pass "
            f"plan=ExecutionPlan({', '.join(f'{k}=...' for k in passed)}) "
            "instead", DeprecationWarning, stacklevel=3)
    return resolve_plan(plan, **loose)


def _gather_fields(codes_cm, idx):
    """Leading-axis (field) gather from the column-major copy, unpacked.

    ``codes_cm`` is (F, n) — plain uint8 or :class:`PackedCodes` over the
    record axis.  Packed rows are selected WITHOUT unpacking the full
    matrix; only the gathered level rows expand to uint8."""
    if isinstance(codes_cm, PackedCodes):
        return codes_cm[idx].unpack()
    return codes_cm[idx]


def fit_tree(codes, codes_cm, g, h, *, depth: int, n_bins: int,
             missing_bin: int, is_cat_field, field_mask,
             lambda_: float, gamma: float, min_child_weight: float,
             plan: Optional[ExecutionPlan] = None,
             hist_strategy: Optional[str] = None,
             partition_strategy: Optional[str] = None,
             host_offload_split: Optional[bool] = None) -> TreeArrays:
    """Grow one depth-``depth`` tree level-by-level (fixed shapes, jittable).

    codes: (n, F) uint8 row-major (step-① input);
    codes_cm: (F, n) uint8 column-major redundant copy (step-③ input);
    g, h: (n,) float32 gradient statistics.  ``plan`` selects the kernel
    strategies (the legacy per-step string kwargs are deprecated — they
    still lift into the plan, with a ``DeprecationWarning``, for one
    release).

    The scalar grower IS the K=1 slice of ``fit_forest`` — one body to
    maintain; the class axis costs nothing at K=1 (same kernels, same
    matmul shapes, bit-identical results).
    """
    plan = _lift_loose_kwargs(plan, hist_strategy=hist_strategy,
                              partition_strategy=partition_strategy,
                              host_offload_split=host_offload_split)
    forest = _fit_forest_jit(codes, codes_cm, g[None], h[None], depth=depth,
                             n_bins=n_bins, missing_bin=missing_bin,
                             is_cat_field=is_cat_field,
                             field_mask=field_mask, lambda_=lambda_,
                             gamma=gamma,
                             min_child_weight=min_child_weight, plan=plan)
    return TreeArrays(*[a[0] for a in forest])


# --------------------------------------------------------------------------
# class-batched grower: K per-class trees per round (multi-class boosting)
# --------------------------------------------------------------------------
def fit_forest(codes, codes_cm, g, h, *, depth: int, n_bins: int,
               missing_bin: int, is_cat_field, field_mask,
               lambda_: float, gamma: float, min_child_weight: float,
               plan: Optional[ExecutionPlan] = None,
               hist_strategy: Optional[str] = None,
               partition_strategy: Optional[str] = None,
               host_offload_split: Optional[bool] = None) -> TreeArrays:
    """Grow K trees level-synchronously (one per class, shared code stream).

    g, h: (K, n) per-class gradient statistics.  Every per-node array of
    ``fit_tree`` gains a leading class axis; the step-① histogram is built
    ONCE per level for all classes (the class-batched ``build_histogram``),
    so the record/code stream is read once per level regardless of K.
    Returns TreeArrays with leading (K, ...) axes.

    The loose ``hist_strategy=`` / ``partition_strategy=`` /
    ``host_offload_split=`` kwargs are deprecated (lifted into the plan
    with a warning, OUTSIDE the jit so the warning actually fires on
    every call rather than only at trace time).
    """
    plan = _lift_loose_kwargs(plan, hist_strategy=hist_strategy,
                              partition_strategy=partition_strategy,
                              host_offload_split=host_offload_split)
    return _fit_forest_jit(codes, codes_cm, g, h, depth=depth,
                           n_bins=n_bins, missing_bin=missing_bin,
                           is_cat_field=is_cat_field, field_mask=field_mask,
                           lambda_=lambda_, gamma=gamma,
                           min_child_weight=min_child_weight, plan=plan)


@functools.partial(
    jax.jit,
    static_argnames=("depth", "n_bins", "missing_bin", "plan"))
def _fit_forest_jit(codes, codes_cm, g, h, *, depth: int, n_bins: int,
                    missing_bin: int, is_cat_field, field_mask,
                    lambda_: float, gamma: float, min_child_weight: float,
                    plan: ExecutionPlan) -> TreeArrays:
    n, F = codes.shape
    K = g.shape[0]
    n_int = 2 ** depth - 1
    n_leaf = 2 ** depth

    feature = jnp.full((K, n_int), -1, jnp.int32)
    threshold = jnp.zeros((K, n_int), jnp.int32)
    is_cat = jnp.zeros((K, n_int), jnp.int32)
    default_left = jnp.zeros((K, n_int), jnp.int32)
    value_bottom = jnp.zeros((K, n_leaf), jnp.float32)
    value_set = jnp.zeros((K, n_leaf), bool)

    node_ids = jnp.zeros((K, n), jnp.int32)        # per-class vertex ids
    find = (splits_mod.find_best_splits_host if plan.host_offload_split
            else splits_mod.find_best_splits)

    part = jax.vmap(functools.partial(ops.partition_level,
                                      missing_bin=missing_bin, plan=plan))

    state = (feature, threshold, is_cat, default_left, value_bottom,
             value_set)
    prev_hist = None
    for level in range(depth):
        nn = 2 ** level

        # step ① — one batched pass covers all K class partitions; with
        # plan.hist_subtraction, levels > 0 bin only the smaller child of
        # each parent and derive the sibling from the previous level's hist
        if plan.hist_subtraction and level > 0:
            hist = _subtract_level_hist(codes, g, h, node_ids, prev_hist,
                                        n_nodes=nn, n_bins=n_bins, plan=plan)
        else:
            hist = ops.build_histogram(codes, g, h, node_ids, n_nodes=nn,
                                       n_bins=n_bins, plan=plan)
        prev_hist = hist                                      # (K,nn,F,NB,2)
        # step ② — split decisions + tree-table updates (shared with the
        # chunked grower, which accumulates the same hist across chunks)
        state, best, do_split = _decide_level(
            hist, level, depth, state, is_cat_field, field_mask, lambda_,
            gamma, min_child_weight, find)

        # step ③ — per-class predicate columns from the column-major copy
        codes_lvl = _gather_fields(
            codes_cm, jnp.where(do_split, best.feature, 0))     # (K,nn,n)
        node_ids = part(
            node_ids, codes_lvl.transpose(0, 2, 1),
            jnp.where(do_split,
                      jnp.broadcast_to(jnp.arange(nn, dtype=jnp.int32),
                                       (K, nn)), -1),
            best.threshold, best.is_cat, best.default_left)

    feature, threshold, is_cat, default_left, value_bottom, value_set = state
    value_bottom = _settle_bottom_leaves(g, h, node_ids, value_bottom,
                                         value_set, n_leaf, lambda_)
    return TreeArrays(feature=feature, threshold=threshold, is_cat=is_cat,
                      default_left=default_left, leaf_value=value_bottom)


def _decide_level(hist, level, depth, state, is_cat_field, field_mask,
                  lambda_, gamma, min_child_weight, find):
    """Step ② for one level: pick splits from the (K, nn, F, NB, 2) level
    histogram and fold them into the tree-table ``state``.  Pure jnp on
    node-sized arrays — shared verbatim by the in-memory (jitted) and
    chunked (host-driven) growers, so both emit identical trees for
    identical histograms."""
    feature, threshold, is_cat, default_left, value_bottom, value_set = state
    K, nn, F, n_bins, _ = hist.shape
    off = nn - 1
    reps = 2 ** (depth - level)

    # find_best_splits is vectorized over nodes: fold the class axis into
    # the node axis (works for the host offload too)
    flat = find(hist.reshape(K * nn, F, n_bins, 2), is_cat_field,
                field_mask, lambda_, gamma, min_child_weight)
    best = splits_mod.SplitDecision(*[a.reshape(K, nn) for a in flat])

    resolved = value_set[:, jnp.arange(nn) * reps]              # (K, nn)
    do_split = (best.gain > 0.0) & (~resolved)

    w = splits_mod.leaf_weight(best.node_g, best.node_h, lambda_)
    newly_leaf = (~do_split) & (~resolved)
    mask_b = jnp.repeat(newly_leaf, reps, axis=1)               # (K, n_leaf)
    value_bottom = jnp.where(mask_b & (~value_set),
                             jnp.repeat(w, reps, axis=1), value_bottom)
    value_set = value_set | mask_b

    feature = jax.lax.dynamic_update_slice(
        feature, jnp.where(do_split, best.feature, -1), (0, off))
    threshold = jax.lax.dynamic_update_slice(threshold, best.threshold,
                                             (0, off))
    is_cat = jax.lax.dynamic_update_slice(is_cat, best.is_cat, (0, off))
    default_left = jax.lax.dynamic_update_slice(
        default_left, best.default_left, (0, off))
    state = (feature, threshold, is_cat, default_left, value_bottom,
             value_set)
    return state, best, do_split


def _settle_bottom_leaves(g, h, node_ids, value_bottom, value_set, n_leaf,
                          lambda_):
    """Leaf weights for every bottom slot not settled by an earlier level."""
    Gb = jax.vmap(lambda gg, nid: jax.ops.segment_sum(
        gg.astype(jnp.float32), nid, n_leaf))(g, node_ids)
    Hb = jax.vmap(lambda hh, nid: jax.ops.segment_sum(
        hh.astype(jnp.float32), nid, n_leaf))(h, node_ids)
    wb = splits_mod.leaf_weight(Gb, Hb, lambda_)
    return jnp.where(value_set, value_bottom, wb)


# --------------------------------------------------------------------------
# histogram subtraction (paper §II-A) for the level-wise growers
# --------------------------------------------------------------------------
def _child_is_smaller(smaller_is_left):
    """(K, NN/2) per-parent 'left child is smaller' -> (K, NN) per-child
    'this node is the smaller sibling' (children of parent p sit at slots
    2p / 2p+1)."""
    sil2 = jnp.repeat(smaller_is_left, 2, axis=1)             # (K, NN)
    left_slot = (jnp.arange(sil2.shape[1]) % 2) == 0
    return jnp.where(left_slot[None, :], sil2, ~sil2)


def _combine_sibling_hist(parent_hist, small, is_small):
    """Derive the level histogram from the smaller-child partial histogram:
    ``hist[c] = small[c]`` where c is the smaller sibling, else
    ``parent[c // 2] − small[sibling(c)]`` — the paper's "without any
    explicit binning at the other child".  Exact in real arithmetic; in
    float32 the derived sibling reassociates the parent sum (documented
    tolerance, see docs/api.md)."""
    K, nn, F, NB, S = small.shape
    sib = small.reshape(K, nn // 2, 2, F, NB, S)[:, :, ::-1]
    derived = jnp.repeat(parent_hist, 2, axis=1) - sib.reshape(small.shape)
    return jnp.where(is_small[:, :, None, None, None], small, derived)


def _compact_selected(codes, g, h, nid, sel, n_half: int):
    """Pack the ``sel``-marked records into a fixed (n_half, ...) buffer.

    ``n_half = n // 2`` always fits: summed over parents,
    ``min(left, right) <= (left + right) / 2``, so the smaller children
    hold at most ``n // 2`` records (selection is by RECORD COUNT, which
    is what guarantees the bound — hessian mass does not, e.g. under
    GOSS zero-weighting).  Slots past the selected count are padding with
    zero gradient statistics (contributing exactly +0.0) and node 0.
    """
    n = codes.shape[0]
    pos = jnp.where(sel, jnp.cumsum(sel) - 1, n_half)         # dump slot
    idx = jnp.full((n_half + 1,), n, jnp.int32).at[pos].set(
        jnp.arange(n, dtype=jnp.int32), mode="drop")[:n_half]
    valid = idx < n
    take = jnp.where(valid, idx, 0)
    return (codes[take],
            jnp.where(valid, g[take], 0.0),
            jnp.where(valid, h[take], 0.0),
            jnp.where(valid, nid[take], 0))


def _subtract_level_hist(codes, g, h, node_ids, parent_hist, *,
                         n_nodes: int, n_bins: int, plan: ExecutionPlan):
    """Step ① for one level (> 0) via smaller-child subtraction.

    Bins ONLY the records that landed in the smaller child of each split
    parent — compacted to an ``n // 2`` buffer so the histogram kernel
    reads half the record stream — and derives every sibling as
    ``parent − smaller``.  Per-node record counts come from an O(n)
    on-device segment-sum of the freshly partitioned node ids (no
    device→host trip in the level loop).

    Class handling: the jnp strategies run one full pass *per class*
    anyway, so per-class compaction halves their work at any K.  The
    class-batched Pallas kernel reads the code stream ONCE for all K —
    per-class compaction would read K·n/2 codes instead of n, a net
    loss for K > 2 — so there the bigger-child records are masked to
    zero statistics instead (single batched launch, work unchanged,
    siblings still derived).
    """
    K, n = g.shape
    ones = jnp.ones((n,), jnp.int32)
    counts = jax.vmap(
        lambda nid: jax.ops.segment_sum(ones, nid, n_nodes))(node_ids)
    smaller_is_left = counts[:, 0::2] <= counts[:, 1::2]      # (K, NN/2)
    is_small = _child_is_smaller(smaller_is_left)             # (K, NN)
    sel = jax.vmap(lambda m, nid: m[nid])(is_small, node_ids)  # (K, n)
    if K > 1 and plan.hist_strategy.startswith("pallas"):
        w = sel.astype(jnp.float32)
        small = ops.build_histogram(codes, g * w, h * w, node_ids,
                                    n_nodes=n_nodes, n_bins=n_bins,
                                    plan=plan)
        return _combine_sibling_hist(parent_hist, small, is_small)
    n_half = max(1, n // 2)
    smalls = []
    for k in range(K):
        ck, gk, hk, nk = _compact_selected(codes, g[k], h[k], node_ids[k],
                                           sel[k], n_half)
        smalls.append(ops.build_histogram(ck, gk, hk, nk, n_nodes=n_nodes,
                                          n_bins=n_bins, plan=plan))
    return _combine_sibling_hist(parent_hist, jnp.stack(smalls), is_small)


# --------------------------------------------------------------------------
# out-of-core grower: chunk-accumulated histograms + chunk-local node ids
# --------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("missing_bin", "plan"))
def _partition_chunk(codes, node_ids, feature, threshold, is_cat,
                     default_left, do_split, *, missing_bin: int,
                     plan: ExecutionPlan):
    """Step ③ for one chunk: route the chunk's per-class node ids through
    one level's split decisions.  The column-major copy is chunk-local
    (``codes.T``) — the paper's redundant representation kept to one
    chunk's footprint.  Packed chunks unpack here, inside the jit, so the
    chunk crosses host→device at half the bytes."""
    K, nn = feature.shape
    if isinstance(codes, PackedCodes):
        codes = codes.unpack()
    codes_cm = codes.T                                        # (F, rows)
    codes_lvl = codes_cm[jnp.where(do_split, feature, 0)]     # (K, nn, rows)
    part = jax.vmap(functools.partial(ops.partition_level,
                                      missing_bin=missing_bin, plan=plan))
    return part(node_ids, codes_lvl.transpose(0, 2, 1),
                jnp.where(do_split,
                          jnp.broadcast_to(jnp.arange(nn, dtype=jnp.int32),
                                           (K, nn)), -1),
                threshold, is_cat, default_left)


def fit_forest_chunked(chunks, g, h, *, depth: int, n_bins: int,
                       missing_bin: int, is_cat_field, field_mask,
                       lambda_: float, gamma: float, min_child_weight: float,
                       plan: Optional[ExecutionPlan] = None):
    """Out-of-core twin of :func:`fit_forest`: same math, chunked scans.

    ``chunks`` is a zero-argument callable returning a fresh iterator of
    ``(lo, hi, codes)`` tuples — ``codes`` a (rows, F) uint8 chunk (or a
    :class:`PackedCodes` carrying the same logical rows 4-bit packed, in
    which case every host→device chunk copy moves half the bytes) whose
    first ``hi - lo`` rows are records ``lo:hi`` (extra rows are padding
    and are neutralized with zero gradient statistics).  One iteration
    happens per level (histogram accumulation, with the previous level's
    partition applied lazily in the same pass) plus one final partition
    pass — ``depth + 1`` data passes per tree, device memory bounded by
    one chunk.

    g, h: (K, n) numpy float32 per-class gradient statistics (host
    resident).  Returns ``(TreeArrays with (K, ...) axes, node_ids)``
    where ``node_ids`` is the host (K, n) int32 array of final leaf slots
    — the streaming trainer updates margins from it directly, so step ⑤
    needs no extra traversal pass over the stream.
    """
    plan = resolve_plan(plan).without_chunking()
    g = np.asarray(g, np.float32)
    h = np.asarray(h, np.float32)
    K, n = g.shape
    F = int(is_cat_field.shape[0])
    n_int = 2 ** depth - 1
    n_leaf = 2 ** depth

    state = (jnp.full((K, n_int), -1, jnp.int32),      # feature
             jnp.zeros((K, n_int), jnp.int32),         # threshold
             jnp.zeros((K, n_int), jnp.int32),         # is_cat
             jnp.zeros((K, n_int), jnp.int32),         # default_left
             jnp.zeros((K, n_leaf), jnp.float32),      # value_bottom
             jnp.zeros((K, n_leaf), bool))             # value_set
    node_ids = np.zeros((K, n), np.int32)
    find = (splits_mod.find_best_splits_host if plan.host_offload_split
            else splits_mod.find_best_splits)
    pending = None                    # previous level's partition arguments

    def stat_chunk(a, lo, hi, rows):
        """(K, rows) slice of a host array, zero-padded to the chunk (pad
        rows carry zero stats / node 0, contributing exactly +0.0)."""
        s = a[:, lo:hi]
        if rows > hi - lo:
            s = np.pad(s, ((0, 0), (0, rows - (hi - lo))))
        return jnp.asarray(s)

    def apply_pending(codes, lo, hi, rows):
        nid = stat_chunk(node_ids, lo, hi, rows)
        if pending is None:
            return nid
        nid = _partition_chunk(codes, nid, *pending,
                               missing_bin=missing_bin, plan=plan)
        node_ids[:, lo:hi] = np.asarray(nid[:, :hi - lo])
        return nid

    use_sub = bool(plan.hist_subtraction)
    prev_hist = None
    smaller_is_left = None            # (K, nn) hessian-based, per level
    for level in range(depth):
        nn = 2 ** level
        sub_level = use_sub and level > 0
        # chunked subtraction: every chunk must be streamed anyway (the
        # previous level's partition is applied lazily in this pass), so
        # instead of compacting, the bigger-child records are masked to
        # zero stats — the accumulator stays class-batched — and siblings
        # are derived once per level from the previous level's histogram.
        # Smaller-child selection comes from the decision's left_h channel
        # (hessian mass), available BEFORE the pass; masking keeps any
        # selection exact, so hessian-vs-count ties are harmless here.
        is_small = _child_is_smaller(smaller_is_left) if sub_level else None
        hist = jnp.zeros((K, nn, F, n_bins, 2), jnp.float32)
        for lo, hi, codes in chunks():
            if not isinstance(codes, PackedCodes):
                codes = jnp.asarray(codes)
            rows = codes.shape[0]
            nid = apply_pending(codes, lo, hi, rows)
            gc = stat_chunk(g, lo, hi, rows)
            hc = stat_chunk(h, lo, hi, rows)
            if sub_level:
                w = jax.vmap(lambda m, i: m[i])(is_small, nid)
                w = w.astype(jnp.float32)
                gc, hc = gc * w, hc * w
            hist = ops.accumulate_histogram(
                hist, codes, gc, hc, nid, n_nodes=nn,
                n_bins=n_bins, plan=plan)
        if sub_level:
            hist = _combine_sibling_hist(prev_hist, hist, is_small)
        prev_hist = hist
        state, best, do_split = _decide_level(
            hist, level, depth, state, is_cat_field, field_mask, lambda_,
            gamma, min_child_weight, find)
        smaller_is_left = jnp.where(do_split,
                                    2.0 * best.left_h <= best.node_h, False)
        pending = (best.feature, best.threshold, best.is_cat,
                   best.default_left, do_split)

    for lo, hi, codes in chunks():    # final pass: last level's partition
        if not isinstance(codes, PackedCodes):
            codes = jnp.asarray(codes)
        apply_pending(codes, lo, hi, codes.shape[0])

    feature, threshold, is_cat, default_left, value_bottom, value_set = state
    value_bottom = _settle_bottom_leaves(
        jnp.asarray(g), jnp.asarray(h), jnp.asarray(node_ids), value_bottom,
        value_set, n_leaf, lambda_)
    tree = TreeArrays(feature=feature, threshold=threshold, is_cat=is_cat,
                      default_left=default_left, leaf_value=value_bottom)
    return tree, node_ids


# --------------------------------------------------------------------------
# vertex-by-vertex (leaf-wise) grower with the smaller-child subtraction trick
# --------------------------------------------------------------------------
def fit_tree_lossguide(codes, codes_cm, g, h, *, depth: int, n_bins: int,
                       missing_bin: int, is_cat_field, field_mask,
                       lambda_: float, gamma: float, min_child_weight: float,
                       max_leaves: Optional[int] = None,
                       plan: Optional[ExecutionPlan] = None,
                       hist_strategy: Optional[str] = None) -> TreeArrays:
    """Best-first growth; bins only the smaller child per split (§II-A).

    Control flow (the gain heap) runs on host — the paper itself argues this
    coordination is cheap relative to the record scans; the scans themselves
    (histogram of the smaller child, predicate masks) run on device.
    """
    plan = _lift_loose_kwargs(plan, hist_strategy=hist_strategy)
    n, F = codes.shape
    n_int = 2 ** depth - 1
    n_leaf_slots = 2 ** depth
    max_leaves = max_leaves or n_leaf_slots
    g = jnp.asarray(g, jnp.float32)
    h = jnp.asarray(h, jnp.float32)

    feature = np.full((n_int,), -1, np.int32)
    threshold = np.zeros((n_int,), np.int32)
    is_cat_a = np.zeros((n_int,), np.int32)
    default_left = np.zeros((n_int,), np.int32)
    value_bottom = np.zeros((n_leaf_slots,), np.float32)

    def hist_of(mask):
        return ops.build_histogram(
            codes, g * mask, h * mask, jnp.zeros((n,), jnp.int32),
            n_nodes=1, n_bins=n_bins, plan=plan)[0]               # (F, NB, 2)

    def best_of(hist):
        d = splits_mod.find_best_splits(hist[None], is_cat_field, field_mask,
                                        lambda_, gamma, min_child_weight)
        return jax.device_get(
            (d.gain[0], d.feature[0], d.threshold[0], d.is_cat[0],
             d.default_left[0], d.node_g[0], d.node_h[0], d.left_h[0]))

    root_mask = jnp.ones((n,), jnp.float32)
    root_hist = hist_of(root_mask)
    heap = []
    counter = 0  # tie-break: deterministic heap order

    def push(pos, level, hist, mask):
        nonlocal counter
        gain, f, t, c, dl, G, H, HL = best_of(hist)
        heapq.heappush(heap, (-float(gain), counter,
                              dict(pos=pos, level=level, hist=hist, mask=mask,
                                   f=int(f), t=int(t), c=int(c), dl=int(dl),
                                   G=float(G), H=float(H), HL=float(HL),
                                   gain=float(gain))))
        counter += 1

    def settle_leaf(e):
        reps = 2 ** (depth - e["level"])
        base = e["pos"] - (2 ** e["level"] - 1)
        w = -e["G"] / (e["H"] + lambda_)
        value_bottom[base * reps:(base + 1) * reps] = w

    push(0, 0, root_hist, root_mask)
    n_leaves = 1
    while heap and n_leaves < max_leaves:
        _, _, e = heapq.heappop(heap)
        if e["gain"] <= 0.0 or e["level"] >= depth:
            settle_leaf(e)
            continue
        pos, lvl = e["pos"], e["level"]
        feature[pos], threshold[pos] = e["f"], e["t"]
        is_cat_a[pos], default_left[pos] = e["c"], e["dl"]

        # step ③ — one predicate, one column from the column-major copy
        col = _gather_fields(codes_cm, e["f"]).astype(jnp.int32)
        miss = col == missing_bin
        left = jnp.where(jnp.asarray(e["c"] == 1), col == e["t"],
                         col <= e["t"])
        left = jnp.where(miss, e["dl"] == 1, left)
        mask_l = e["mask"] * left.astype(jnp.float32)
        mask_r = e["mask"] - mask_l

        # the paper's step-① optimization: bin ONLY the smaller child, the
        # sibling histogram is parent − child (no explicit binning).  The
        # decision's left_h counts channel already crossed to the host with
        # the split, so picking the smaller side costs no extra syncs.
        hl = e["HL"]
        hr = e["H"] - e["HL"]
        if hl <= hr:
            hist_small = hist_of(mask_l)
            hist_l, hist_r = hist_small, e["hist"] - hist_small
        else:
            hist_small = hist_of(mask_r)
            hist_l, hist_r = e["hist"] - hist_small, hist_small

        push(2 * pos + 1, lvl + 1, hist_l, mask_l)
        push(2 * pos + 2, lvl + 1, hist_r, mask_r)
        n_leaves += 1

    while heap:  # settle everything left on the heap as leaves
        _, _, e = heapq.heappop(heap)
        settle_leaf(e)

    return TreeArrays(feature=jnp.asarray(feature),
                      threshold=jnp.asarray(threshold),
                      is_cat=jnp.asarray(is_cat_a),
                      default_left=jnp.asarray(default_left),
                      leaf_value=jnp.asarray(value_bottom))

"""Offline pre-processing: quantile-sketch discretization into bin codes.

Paper §II-A: "the input records are (pre-)processed in software (1) to
discretize floating-point fields into some number of bins (e.g., 256 bins,
including one bin for records with a missing field), (2) to one-hot encode
categorical fields, and (3) to include an 'absent' bin for each categorical
field".

We reproduce the *optimized* encoding the paper bakes into its baseline:
one-hot features are collapsed back to the *field* level (one bin per
category + one missing bin), so every record has exactly one live bin per
field — the density property that group-by-field mapping exploits.

Bin-code conventions (per field, ``n_bins = max_bins`` total):
  * numeric field:  codes 0..n_value_bins-1 from quantile edges,
                    missing  -> code ``max_bins - 1``
  * categorical:    codes 0..n_categories-1,
                    missing/absent -> code ``max_bins - 1``
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array

# --------------------------------------------------------------------------
# 4-bit packed codes (paper §III-B: the bandwidth-preserving compressed
# representation).  Two bin codes per byte along the LAST axis whenever the
# bin count fits a nibble (n_bins <= 16): the low nibble holds the even
# index, the high nibble the odd index.  Packing is lossless — codes are
# small integers — so every consumer stays bit-equal to the uint8 path.
# --------------------------------------------------------------------------
PACK_MAX_BINS = 16      # nibble capacity: codes 0..15


def pack_nibbles(codes) -> Array:
    """Pack integer codes <= 15 two-per-byte along the last axis.

    An odd-length last axis is zero-padded to even before pairing; the
    logical length must be carried alongside (``PackedCodes.n``) so
    :func:`unpack_nibbles` can strip the pad nibble again.
    """
    codes = jnp.asarray(codes, jnp.uint8)
    if codes.shape[-1] % 2:
        pad = [(0, 0)] * (codes.ndim - 1) + [(0, 1)]
        codes = jnp.pad(codes, pad)
    return codes[..., 0::2] | (codes[..., 1::2] << 4)


def unpack_nibbles(data, n: int) -> Array:
    """Inverse of :func:`pack_nibbles`: (..., ceil(n/2)) -> (..., n)."""
    data = jnp.asarray(data, jnp.uint8)
    full = jnp.stack([data & 0xF, data >> 4], axis=-1)
    return full.reshape(data.shape[:-1] + (-1,))[..., :n]


def pack_nibbles_np(codes: np.ndarray) -> np.ndarray:
    """Host (numpy) twin of :func:`pack_nibbles` — the shard writer's path."""
    codes = np.ascontiguousarray(codes, np.uint8)
    if codes.shape[-1] % 2:
        pad = [(0, 0)] * (codes.ndim - 1) + [(0, 1)]
        codes = np.pad(codes, pad)
    return codes[..., 0::2] | (codes[..., 1::2] << 4)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class PackedCodes:
    """4-bit bin codes, two per byte along the last axis.

    A jax pytree (the packed bytes are the single leaf; the logical
    last-axis length is static aux data), so it flows through ``jit`` /
    ``vmap`` untouched and kernels can consume the packed bytes directly.
    Leading-axis indexing (``pc[idx]``) selects rows without unpacking —
    the packed axis is always the *last* one in both layouts (row-major
    packs fields, column-major packs records).
    """

    data: Array     # (..., ceil(n/2)) uint8 packed bytes
    n: int          # logical last-axis length
    bits: int = 4

    @property
    def shape(self):
        return self.data.shape[:-1] + (self.n,)

    @property
    def ndim(self) -> int:
        return self.data.ndim

    @property
    def dtype(self):
        return jnp.uint8

    @property
    def nbytes(self) -> int:
        return int(np.prod(self.data.shape))    # uint8: 1 byte/element

    def unpack(self) -> Array:
        return unpack_nibbles(self.data, self.n)

    def __getitem__(self, idx) -> "PackedCodes":
        """Leading-axis selection; the packed last axis is never indexed."""
        return PackedCodes(self.data[idx], self.n, self.bits)

    def __array__(self, dtype=None, copy=None):
        """numpy conversion yields the UNPACKED logical matrix, so
        ``np.asarray(codes)`` reads the same either layout."""
        out = np.asarray(self.unpack())
        return out if dtype is None else out.astype(dtype)

    def tree_flatten(self):
        return (self.data,), (self.n, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, leaves):
        return cls(leaves[0], *aux)

    @classmethod
    def pack(cls, codes) -> "PackedCodes":
        codes = jnp.asarray(codes)
        return cls(pack_nibbles(codes), int(codes.shape[-1]))

    @classmethod
    def pack_np(cls, codes: np.ndarray) -> "PackedCodes":
        """Pack on the host — the bytes stay numpy until a consumer ships
        them (half the host->device traffic of shipping unpacked codes)."""
        codes = np.asarray(codes, np.uint8)
        return cls(pack_nibbles_np(codes), int(codes.shape[-1]))


def as_unpacked(codes) -> Array:
    """``codes`` as a plain (..., n) uint8 array, whatever the layout."""
    if isinstance(codes, PackedCodes):
        return codes.unpack()
    return jnp.asarray(codes)


@dataclasses.dataclass(frozen=True)
class BinnedDataset:
    """A pre-processed dataset: bin codes in redundant dual layout.

    Paper §III: the redundant per-field column-major format is stored *in
    addition to* the natural per-record row-major format.  ``codes`` is the
    row-major (records, fields) copy consumed by histogram binning (step ①);
    ``codes_cm`` is the (fields, records) copy consumed by single-predicate
    evaluation (step ③) and one-tree traversal (step ⑤).

    When ``n_bins <= 16`` both copies are stored as :class:`PackedCodes`
    (4-bit, two codes per byte), so the redundant representation costs
    *less* than one unpacked copy instead of doubling it.  Consumers
    branch on ``isinstance(..., PackedCodes)``; results are bit-equal.
    """

    codes: Array          # (n, F) uint8 row-major, or PackedCodes over F
    codes_cm: Array       # (F, n) uint8 column-major, or PackedCodes over n
    is_categorical: Array  # (F,) bool
    n_bins: int            # total bins per field incl. the missing bin
    bin_edges: np.ndarray  # (F, n_bins-1) float64 upper edges (numeric fields)
    n_value_bins: np.ndarray  # (F,) int, live value bins per field

    @property
    def n_records(self) -> int:
        return self.codes.shape[0]

    @property
    def n_fields(self) -> int:
        return self.codes.shape[1]

    @property
    def missing_bin(self) -> int:
        return self.n_bins - 1


class Binner:
    """Quantile sketch binner (fit on host with numpy, apply with JAX)."""

    def __init__(self, max_bins: int = 256,
                 categorical_fields: Optional[Sequence[int]] = None):
        if not (2 <= max_bins <= 256):
            raise ValueError("max_bins must be in [2, 256] for uint8 codes")
        self.max_bins = max_bins
        self.categorical_fields = frozenset(categorical_fields or ())
        self._edges: Optional[np.ndarray] = None
        self._is_cat: Optional[np.ndarray] = None
        self._n_value_bins: Optional[np.ndarray] = None

    # -- fit ---------------------------------------------------------------
    def fit(self, X: np.ndarray) -> "Binner":
        """Compute per-field quantile edges / category tables.

        ``X`` is (n, F) float; NaN marks a missing value.  Categorical fields
        must already hold small non-negative integer category ids.
        """
        X = np.asarray(X, dtype=np.float64)
        n, F = X.shape
        n_value_bins = self.max_bins - 1  # last code reserved for missing
        edges = np.full((F, n_value_bins - 1), np.inf, dtype=np.float64)
        is_cat = np.zeros((F,), dtype=bool)
        nvb = np.zeros((F,), dtype=np.int64)
        for f in range(F):
            col = X[:, f]
            valid = col[~np.isnan(col)]
            if f in self.categorical_fields:
                is_cat[f] = True
                ncat = int(valid.max()) + 1 if valid.size else 1
                if ncat > n_value_bins:
                    raise ValueError(
                        f"field {f}: {ncat} categories exceed {n_value_bins} "
                        "value bins; raise max_bins or re-map categories")
                nvb[f] = ncat
                continue
            if valid.size == 0:
                nvb[f] = 1
                continue
            qs = np.linspace(0.0, 1.0, n_value_bins + 1)[1:-1]
            e = np.unique(np.quantile(valid, qs))
            edges[f, : e.size] = e
            nvb[f] = e.size + 1
        self._edges, self._is_cat, self._n_value_bins = edges, is_cat, nvb
        return self

    # -- transform ----------------------------------------------------------
    def transform_codes(self, X: np.ndarray) -> np.ndarray:
        """Raw (n, F) uint8 bin codes on the host — the chunk-sized unit
        the streaming trainer binned-transforms per pass (no device copies
        and no redundant column-major twin, unlike ``transform``)."""
        if self._edges is None:
            raise RuntimeError("Binner.fit must run before transform")
        X = np.asarray(X, dtype=np.float64)
        n, F = X.shape
        codes = np.zeros((n, F), dtype=np.uint8)
        missing_code = self.max_bins - 1
        for f in range(F):
            col = X[:, f]
            nan = np.isnan(col)
            if self._is_cat[f]:
                c = np.where(nan, 0, col).astype(np.int64)
                c = np.clip(c, 0, self._n_value_bins[f] - 1)
            else:
                c = np.searchsorted(self._edges[f], np.where(nan, 0.0, col),
                                    side="right")
            codes[:, f] = np.where(nan, missing_code, c).astype(np.uint8)
        return codes

    def _device_tables(self):
        """Edge/category tables as device arrays, cached per fit — the
        lookup state of :meth:`transform_codes_device`."""
        cached = getattr(self, "_dev_tables", None)
        if cached is None or cached[0] is not self._edges:
            tables = (jnp.asarray(self._edges, jnp.float32),
                      jnp.asarray(self._is_cat),
                      jnp.asarray(self._n_value_bins, jnp.int32))
            self._dev_tables = cached = (self._edges, tables)
        return cached[1]

    def transform_codes_device(self, X) -> Array:
        """(n, F) uint8 bin codes computed ON DEVICE in one jitted
        dispatch — the serving path's binned transform.

        Unlike :meth:`transform_codes` (host numpy, one pass per field)
        this never round-trips through numpy per request: ``X`` is
        shipped once and searchsorted against float32 edge tables
        resident on device.  Codes match the host path except for raw
        values whose float64/float32 roundings straddle a bin edge
        (distinct float64 values that collapse in float32) — measure-zero
        for real feature streams, and irrelevant for float32 inputs.
        """
        if self._edges is None:
            raise RuntimeError("Binner.fit must run before transform")
        return _transform_codes_jit(jnp.asarray(X, jnp.float32),
                                    *self._device_tables(),
                                    missing_code=self.max_bins - 1)

    def transform(self, X: np.ndarray,
                  packed: Optional[bool] = None) -> BinnedDataset:
        """Binned dataset in the redundant dual layout.

        ``packed=None`` (auto) bit-packs both copies whenever the codes
        fit a nibble (``max_bins <= 16``); pass ``False`` to force plain
        uint8, or ``True`` to require packing (errors above 16 bins).
        """
        codes = self.transform_codes(X)
        rm, cm = _dual_layout(codes, self.max_bins, packed)
        return BinnedDataset(
            codes=rm,
            codes_cm=cm,   # materialized redundant copy (packed when <=16 bins)
            is_categorical=jnp.asarray(self._is_cat),
            n_bins=self.max_bins,
            bin_edges=self._edges,
            n_value_bins=self._n_value_bins,
        )

    def fit_transform(self, X: np.ndarray) -> BinnedDataset:
        return self.fit(X).transform(X)


@functools.partial(jax.jit, static_argnames=("missing_code",))
def _transform_codes_jit(X, edges, is_cat, n_value_bins, *,
                         missing_code: int):
    """Device twin of ``Binner.transform_codes``: NaN -> missing code,
    categoricals truncate-and-clip, numerics searchsorted per field
    (``edges`` rows are inf-padded, so the sentinel never matches)."""
    nan = jnp.isnan(X)
    filled = jnp.where(nan, 0.0, X)
    num = jax.vmap(
        lambda e, col: jnp.searchsorted(e, col, side="right"))(
            edges, filled.T).T.astype(jnp.int32)             # (n, F)
    cat = jnp.clip(filled.astype(jnp.int32), 0,
                   n_value_bins[None, :] - 1)
    codes = jnp.where(is_cat[None, :], cat, num)
    return jnp.where(nan, missing_code, codes).astype(jnp.uint8)


class _QuantileSketch:
    """Bounded-memory weighted quantile summary (merge-and-compress).

    Values are buffered verbatim until ``capacity`` is exceeded, at which
    point the summary is compressed to ``capacity`` evenly spaced (by
    cumulative weight) support points.  While uncompressed the summary is
    *exact*: ``quantiles`` reproduces ``np.quantile`` of the full stream
    bit-for-bit, which is what the sketch-vs-exact parity tests pin down.
    """

    __slots__ = ("capacity", "values", "weights", "exact", "_buf")

    def __init__(self, capacity: int):
        if capacity < 8:
            raise ValueError("sketch capacity must be >= 8")
        self.capacity = capacity
        self.values = np.empty((0,), np.float64)
        self.weights = np.empty((0,), np.float64)
        self.exact = True
        self._buf: list = []

    @property
    def n_support(self) -> int:
        return self.values.size + sum(b.size for b in self._buf)

    def update(self, vals: np.ndarray) -> None:
        if vals.size == 0:
            return
        self._buf.append(np.asarray(vals, np.float64))
        if self.n_support > 2 * self.capacity:
            self._compress()

    def _flush(self) -> None:
        if self._buf:
            self.values = np.concatenate([self.values] + self._buf)
            self.weights = np.concatenate(
                [self.weights] + [np.ones((b.size,)) for b in self._buf])
            self._buf = []

    def _compress(self) -> None:
        self._flush()
        if self.values.size <= self.capacity:
            return
        order = np.argsort(self.values, kind="stable")
        v, w = self.values[order], self.weights[order]
        total = float(w.sum())
        mid = np.cumsum(w) - 0.5 * w          # midpoint cumulative weight
        pts = (np.arange(self.capacity) + 0.5) / self.capacity * total
        self.values = np.interp(pts, mid, v)
        self.weights = np.full((self.capacity,), total / self.capacity)
        self.exact = False

    def quantiles(self, qs: np.ndarray) -> np.ndarray:
        """Quantile estimates; exact (``np.quantile``) when uncompressed."""
        self._flush()
        if self.values.size == 0:
            return np.empty((0,), np.float64)
        if self.exact:
            return np.quantile(self.values, qs)
        order = np.argsort(self.values, kind="stable")
        v, w = self.values[order], self.weights[order]
        total = float(w.sum())
        mid = (np.cumsum(w) - 0.5 * w) / total
        return np.interp(qs, mid, v)


class StreamingBinner(Binner):
    """Out-of-core binner: quantile *sketches* over an iterator of chunks.

    Drop-in for :class:`Binner` when ``X`` cannot be materialized — feed
    chunks through ``partial_fit`` (or a whole :class:`repro.data.DataSource`
    through ``fit_source``), then ``finalize`` computes the same per-field
    edge/category tables ``Binner.fit`` produces.  ``transform`` is
    inherited unchanged, so downstream code cannot tell the binners apart.

    For streams no longer than ``sketch_size`` the sketch never compresses
    and the resulting edges are *bit-identical* to ``Binner.fit`` on the
    concatenated stream; beyond that the edges are approximate quantiles
    with bounded (merge-and-compress) summary error.
    """

    def __init__(self, max_bins: int = 256,
                 categorical_fields: Optional[Sequence[int]] = None,
                 sketch_size: int = 32768):
        super().__init__(max_bins, categorical_fields)
        self.sketch_size = sketch_size
        self._sketches: Optional[list] = None
        self._cat_max: Optional[np.ndarray] = None
        self._n_seen = 0

    @property
    def n_rows_seen(self) -> int:
        return self._n_seen

    def _reset(self) -> None:
        """Start a fresh stream — ``fit``/``fit_source`` must match
        ``Binner.fit`` semantics (recompute, not accumulate)."""
        self._sketches, self._cat_max, self._n_seen = None, None, 0

    def partial_fit(self, X_chunk: np.ndarray) -> "StreamingBinner":
        X = np.asarray(X_chunk, dtype=np.float64)
        if X.ndim != 2:
            raise ValueError("partial_fit expects a 2-D (rows, fields) chunk")
        n, F = X.shape
        if self._sketches is None:
            self._sketches = [None if f in self.categorical_fields
                              else _QuantileSketch(self.sketch_size)
                              for f in range(F)]
            self._cat_max = np.full((F,), -1, np.int64)
        elif len(self._sketches) != F:
            raise ValueError(
                f"chunk has {F} fields; earlier chunks had "
                f"{len(self._sketches)}")
        self._n_seen += n
        for f in range(F):
            col = X[:, f]
            valid = col[~np.isnan(col)]
            if self._sketches[f] is None:      # categorical: track max id
                if valid.size:
                    self._cat_max[f] = max(self._cat_max[f],
                                           int(valid.max()))
            else:
                self._sketches[f].update(valid)
        return self

    def finalize(self) -> "StreamingBinner":
        """Turn the accumulated sketches into ``Binner``-compatible tables."""
        if self._sketches is None:
            raise RuntimeError("finalize called before any partial_fit")
        F = len(self._sketches)
        n_value_bins = self.max_bins - 1
        edges = np.full((F, n_value_bins - 1), np.inf, dtype=np.float64)
        is_cat = np.zeros((F,), dtype=bool)
        nvb = np.zeros((F,), dtype=np.int64)
        qs = np.linspace(0.0, 1.0, n_value_bins + 1)[1:-1]
        for f in range(F):
            sk = self._sketches[f]
            if sk is None:
                is_cat[f] = True
                ncat = int(self._cat_max[f]) + 1 if self._cat_max[f] >= 0 \
                    else 1
                if ncat > n_value_bins:
                    raise ValueError(
                        f"field {f}: {ncat} categories exceed {n_value_bins} "
                        "value bins; raise max_bins or re-map categories")
                nvb[f] = ncat
                continue
            q = sk.quantiles(qs)
            if q.size == 0:
                nvb[f] = 1
                continue
            e = np.unique(q)
            edges[f, : e.size] = e
            nvb[f] = e.size + 1
        self._edges, self._is_cat, self._n_value_bins = edges, is_cat, nvb
        return self

    def fit(self, X: np.ndarray) -> "StreamingBinner":
        """One-shot convenience: sketch the whole matrix, then finalize.
        Like ``Binner.fit``, refitting recomputes from scratch."""
        self._reset()
        return self.partial_fit(X).finalize()

    def fit_source(self, source, chunk_rows: int) -> "StreamingBinner":
        """Sketch every chunk of a :class:`repro.data.DataSource` (a fresh
        fit — accumulate across calls with ``partial_fit`` instead)."""
        self._reset()
        for X_chunk, _ in source.chunks(chunk_rows):
            self.partial_fit(X_chunk)
        return self.finalize()


def _dual_layout(codes_np: np.ndarray, n_bins: int,
                 packed: Optional[bool] = None):
    """Build the (row-major, column-major) device pair from host codes,
    bit-packing both copies when the bin count fits a nibble."""
    if packed is None:
        packed = n_bins <= PACK_MAX_BINS
    if packed and n_bins > PACK_MAX_BINS:
        raise ValueError(
            f"packed codes need n_bins <= {PACK_MAX_BINS}, got {n_bins}")
    codes_np = np.ascontiguousarray(codes_np, np.uint8)
    n, F = codes_np.shape
    if packed:
        rm = PackedCodes(jnp.asarray(pack_nibbles_np(codes_np)), F)
        cm = PackedCodes(jnp.asarray(pack_nibbles_np(codes_np.T)), n)
        return rm, cm
    return jnp.asarray(codes_np), jnp.asarray(codes_np.T.copy())


def bin_dataset(X: np.ndarray, max_bins: int = 256,
                categorical_fields: Optional[Sequence[int]] = None,
                packed: Optional[bool] = None) -> BinnedDataset:
    return Binner(max_bins, categorical_fields).fit(X).transform(
        X, packed=packed)


def dataset_from_codes(codes, is_categorical=None, n_bins: int = 256,
                       packed: Optional[bool] = None) -> BinnedDataset:
    """Wrap pre-binned integer codes (tests / synthetic data) as a dataset."""
    codes_np = np.asarray(codes, dtype=np.uint8)
    n, F = codes_np.shape
    rm, cm = _dual_layout(codes_np, n_bins, packed)
    if is_categorical is None:
        is_categorical = jnp.zeros((F,), dtype=bool)
    return BinnedDataset(
        codes=rm,
        codes_cm=cm,
        is_categorical=jnp.asarray(is_categorical),
        n_bins=n_bins,
        bin_edges=np.zeros((F, n_bins - 2)),
        n_value_bins=np.full((F,), n_bins - 1),
    )

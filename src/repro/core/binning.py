"""Offline pre-processing: quantile-sketch discretization into bin codes.

Paper §II-A: "the input records are (pre-)processed in software (1) to
discretize floating-point fields into some number of bins (e.g., 256 bins,
including one bin for records with a missing field), (2) to one-hot encode
categorical fields, and (3) to include an 'absent' bin for each categorical
field".

We reproduce the *optimized* encoding the paper bakes into its baseline:
one-hot features are collapsed back to the *field* level (one bin per
category + one missing bin), so every record has exactly one live bin per
field — the density property that group-by-field mapping exploits.

Bin-code conventions (per field, ``n_bins = max_bins`` total):
  * numeric field:  codes 0..n_value_bins-1 from quantile edges,
                    missing  -> code ``max_bins - 1``
  * categorical:    codes 0..n_categories-1,
                    missing/absent -> code ``max_bins - 1``
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class BinnedDataset:
    """A pre-processed dataset: uint8 codes in redundant dual layout.

    Paper §III: the redundant per-field column-major format is stored *in
    addition to* the natural per-record row-major format.  ``codes`` is the
    row-major (records, fields) copy consumed by histogram binning (step ①);
    ``codes_cm`` is the (fields, records) copy consumed by single-predicate
    evaluation (step ③) and one-tree traversal (step ⑤).
    """

    codes: Array          # (n, F) uint8, row-major
    codes_cm: Array       # (F, n) uint8, column-major (redundant copy)
    is_categorical: Array  # (F,) bool
    n_bins: int            # total bins per field incl. the missing bin
    bin_edges: np.ndarray  # (F, n_bins-1) float64 upper edges (numeric fields)
    n_value_bins: np.ndarray  # (F,) int, live value bins per field

    @property
    def n_records(self) -> int:
        return self.codes.shape[0]

    @property
    def n_fields(self) -> int:
        return self.codes.shape[1]

    @property
    def missing_bin(self) -> int:
        return self.n_bins - 1


class Binner:
    """Quantile sketch binner (fit on host with numpy, apply with JAX)."""

    def __init__(self, max_bins: int = 256,
                 categorical_fields: Optional[Sequence[int]] = None):
        if not (2 <= max_bins <= 256):
            raise ValueError("max_bins must be in [2, 256] for uint8 codes")
        self.max_bins = max_bins
        self.categorical_fields = frozenset(categorical_fields or ())
        self._edges: Optional[np.ndarray] = None
        self._is_cat: Optional[np.ndarray] = None
        self._n_value_bins: Optional[np.ndarray] = None

    # -- fit ---------------------------------------------------------------
    def fit(self, X: np.ndarray) -> "Binner":
        """Compute per-field quantile edges / category tables.

        ``X`` is (n, F) float; NaN marks a missing value.  Categorical fields
        must already hold small non-negative integer category ids.
        """
        X = np.asarray(X, dtype=np.float64)
        n, F = X.shape
        n_value_bins = self.max_bins - 1  # last code reserved for missing
        edges = np.full((F, n_value_bins - 1), np.inf, dtype=np.float64)
        is_cat = np.zeros((F,), dtype=bool)
        nvb = np.zeros((F,), dtype=np.int64)
        for f in range(F):
            col = X[:, f]
            valid = col[~np.isnan(col)]
            if f in self.categorical_fields:
                is_cat[f] = True
                ncat = int(valid.max()) + 1 if valid.size else 1
                if ncat > n_value_bins:
                    raise ValueError(
                        f"field {f}: {ncat} categories exceed {n_value_bins} "
                        "value bins; raise max_bins or re-map categories")
                nvb[f] = ncat
                continue
            if valid.size == 0:
                nvb[f] = 1
                continue
            qs = np.linspace(0.0, 1.0, n_value_bins + 1)[1:-1]
            e = np.unique(np.quantile(valid, qs))
            edges[f, : e.size] = e
            nvb[f] = e.size + 1
        self._edges, self._is_cat, self._n_value_bins = edges, is_cat, nvb
        return self

    # -- transform ----------------------------------------------------------
    def transform(self, X: np.ndarray) -> BinnedDataset:
        if self._edges is None:
            raise RuntimeError("Binner.fit must run before transform")
        X = np.asarray(X, dtype=np.float64)
        n, F = X.shape
        codes = np.zeros((n, F), dtype=np.uint8)
        missing_code = self.max_bins - 1
        for f in range(F):
            col = X[:, f]
            nan = np.isnan(col)
            if self._is_cat[f]:
                c = np.where(nan, 0, col).astype(np.int64)
                c = np.clip(c, 0, self._n_value_bins[f] - 1)
            else:
                c = np.searchsorted(self._edges[f], np.where(nan, 0.0, col),
                                    side="right")
            codes[:, f] = np.where(nan, missing_code, c).astype(np.uint8)
        codes_j = jnp.asarray(codes)
        return BinnedDataset(
            codes=codes_j,
            codes_cm=jnp.asarray(codes.T.copy()),  # materialized redundant copy
            is_categorical=jnp.asarray(self._is_cat),
            n_bins=self.max_bins,
            bin_edges=self._edges,
            n_value_bins=self._n_value_bins,
        )

    def fit_transform(self, X: np.ndarray) -> BinnedDataset:
        return self.fit(X).transform(X)


def bin_dataset(X: np.ndarray, max_bins: int = 256,
                categorical_fields: Optional[Sequence[int]] = None
                ) -> BinnedDataset:
    return Binner(max_bins, categorical_fields).fit_transform(X)


def dataset_from_codes(codes, is_categorical=None, n_bins: int = 256
                       ) -> BinnedDataset:
    """Wrap pre-binned integer codes (tests / synthetic data) as a dataset."""
    codes = jnp.asarray(codes, dtype=jnp.uint8)
    n, F = codes.shape
    if is_categorical is None:
        is_categorical = jnp.zeros((F,), dtype=bool)
    return BinnedDataset(
        codes=codes,
        codes_cm=jnp.asarray(np.asarray(codes).T.copy()),
        is_categorical=jnp.asarray(is_categorical),
        n_bins=n_bins,
        bin_edges=np.zeros((F, n_bins - 2)),
        n_value_bins=np.full((F,), n_bins - 1),
    )

"""Batch inference extensions (paper §III-D).

* ``sharded_predict`` — "the case of too many trees ... can be addressed
  by distributing the trees to multiple Booster chips (in a simple
  round-robin manner)": trees shard over the "model" mesh axis, records
  over the data axes; each shard runs its resident trees over its record
  block and one psum combines the ensemble sum — tree-parallel x
  record-parallel, exactly the paper's multi-chip scheme.
* ``feature_importance`` — gain / cover / split-count importances from the
  fixed-shape tree arrays (production-model introspection).
* ``GBDTPipeline`` — binner + model bundle: predicts raw (unbinned,
  NaN-carrying) feature matrices and round-trips through the checkpoint
  layer.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.api.plan import ExecutionPlan
from repro.core.binning import Binner
from repro.core.gbdt import GBDTModel
from repro.kernels import ops
from repro.kernels.ref import TreeArrays
from repro.launch.mesh import data_axes


def sharded_predict(mesh: Mesh, model: GBDTModel, codes) -> jax.Array:
    """Tree-parallel x record-parallel ensemble inference on ``mesh``.

    Requires n_trees % mesh"model" == 0 (pad the ensemble with zero-value
    trees via ``pad_trees`` otherwise).  Returns margins (n,).
    """
    da = data_axes(mesh)
    m = mesh.shape["model"]
    T = model.n_trees
    if getattr(model, "n_classes", 1) > 1:
        raise NotImplementedError(
            "sharded_predict does not support multi-class ensembles yet")
    if T % m:
        raise ValueError(f"{T} trees do not divide the model axis ({m}); "
                         "use pad_trees() first")

    plan = ExecutionPlan.auto(traversal_strategy="reference")

    def local(codes_l, *tree_leaves):
        trees_l = TreeArrays(*tree_leaves)       # (T/m, ...) local trees
        out = ops.predict_ensemble(trees_l, codes_l,
                                   missing_bin=model.missing_bin,
                                   depth=model.max_depth, plan=plan)
        # paper §III-D: combine the per-chip tree outputs
        return jax.lax.psum(out, "model")

    # the scan-carry zeros inside predict_ensemble are unvarying; skip the
    # static varying-axes check (the psum makes the output well-defined)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(da, None),) + tuple(P("model") for _ in range(5)),
        out_specs=P(da), check_vma=False)
    return fn(codes, *model.trees) + model.base_margin


def pad_trees(model: GBDTModel, multiple: int) -> GBDTModel:
    """Append zero-output pass-through trees so n_trees divides a mesh axis."""
    T = model.n_trees
    pad = -T % multiple
    if pad == 0:
        return model
    t = model.trees

    def pad0(a):
        return jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)

    padded = TreeArrays(
        feature=jnp.concatenate(
            [t.feature, jnp.full((pad,) + t.feature.shape[1:], -1,
                                 t.feature.dtype)]),
        threshold=pad0(t.threshold), is_cat=pad0(t.is_cat),
        default_left=pad0(t.default_left), leaf_value=pad0(t.leaf_value))
    return dataclasses.replace(model, trees=padded)


def feature_importance(model: GBDTModel, kind: str = "gain"
                       ) -> np.ndarray:
    """Per-field importance over the ensemble.

    kind: "split" (split counts), "gain" (sum of leaf-weight variance
    proxy per split — exact gains are not stored in the compact arrays,
    so subtree leaf-value spread stands in), or "cover" (uniform count
    weighting by subtree width).
    """
    feats = np.asarray(model.trees.feature)        # (T, n_int)
    leaves = np.asarray(model.trees.leaf_value, np.float64)  # (T, n_leaf)
    F = model.n_fields
    imp = np.zeros((F,), np.float64)
    T = feats.shape[0]
    depth = model.max_depth
    if kind == "split":
        valid = feats >= 0
        np.add.at(imp, feats[valid], 1.0)
    else:
        # vectorized per level: the heap positions at ``level`` cover the
        # bottom row in contiguous runs of reps = 2**(depth - level) slots,
        # so one reshape turns the subtree-leaf variance into a segment op
        for level in range(depth):
            nn = 2 ** level
            reps = 2 ** (depth - level)
            f_lvl = feats[:, nn - 1:2 * nn - 1]                # (T, nn)
            var = leaves.reshape(T, nn, reps).var(axis=2)      # (T, nn)
            w = float(reps) if kind == "cover" else 1.0
            valid = f_lvl >= 0
            np.add.at(imp, f_lvl[valid], w * var[valid])
    s = imp.sum()
    return imp / s if s > 0 else imp


@dataclasses.dataclass
class GBDTPipeline:
    """Binner + model bundle: raw float/NaN matrices in, predictions out."""

    binner: Binner
    model: GBDTModel

    def predict(self, X: np.ndarray, strategy: Optional[str] = None, *,
                plan: Optional[ExecutionPlan] = None) -> jax.Array:
        data = self.binner.transform(np.asarray(X, dtype=np.float64))
        return self.model.predict(data, strategy=strategy, plan=plan)

    def to_state(self) -> Dict:
        return {
            "model": self.model.to_state(),
            "binner": {
                "max_bins": self.binner.max_bins,
                "categorical": sorted(self.binner.categorical_fields),
                "edges": self.binner._edges,
                "is_cat": self.binner._is_cat,
                "n_value_bins": self.binner._n_value_bins,
            },
        }

    @classmethod
    def from_state(cls, state: Dict) -> "GBDTPipeline":
        b = Binner(int(state["binner"]["max_bins"]),
                   [int(c) for c in np.asarray(
                       state["binner"]["categorical"]).ravel()])
        b._edges = np.asarray(state["binner"]["edges"])
        b._is_cat = np.asarray(state["binner"]["is_cat"])
        b._n_value_bins = np.asarray(state["binner"]["n_value_bins"])
        return cls(binner=b, model=GBDTModel.from_state(state["model"]))

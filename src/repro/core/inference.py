"""Batch inference extensions (paper §III-D) and the serving engine.

* ``predict_margin_cached`` — the compile-once predict engine: an
  lru-cached jitted step keyed on (plan, depth, K, missing bin) with
  power-of-two row- and tree-count padding buckets, so varying request
  batch sizes and checkpoint-resumed ensembles reuse ONE compiled
  executable per bucket instead of retracing per request.  Padding never
  changes results: padded rows are sliced off and padded trees are
  zero-leaf pass-throughs.
* ``sharded_predict`` — "the case of too many trees ... can be addressed
  by distributing the trees to multiple Booster chips (in a simple
  round-robin manner)": trees shard over the "model" mesh axis, records
  over the data axes; each shard runs its resident trees over its record
  block and one psum combines the (n,) ensemble sum — or the (n, K)
  per-class margins — tree-parallel x record-parallel, exactly the
  paper's multi-chip scheme.
* ``feature_importance`` — gain / cover / split-count importances from the
  fixed-shape tree arrays (production-model introspection).
* ``GBDTPipeline`` — binner + model bundle: predicts raw (unbinned,
  NaN-carrying) feature matrices through the device-resident binned
  transform + the cached engine, and round-trips through the checkpoint
  layer.
"""
from __future__ import annotations

import dataclasses
import threading
import warnings
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.api.plan import ExecutionPlan
from repro.core.binning import Binner, BinnedDataset, PackedCodes
from repro.core.gbdt import GBDTModel
from repro.kernels import ops
from repro.kernels.ref import TreeArrays
from repro.launch.mesh import data_axes


# --------------------------------------------------------------------------
# the compile-once predict engine (shape-bucketed jit cache)
# --------------------------------------------------------------------------
ROW_BUCKET_FLOOR = 128      # smallest row-padding bucket (pow2 above this)


def bucket_pow2(x: int, floor: int = 1) -> int:
    """The next power of two >= max(x, floor) — the row pad bucket."""
    return max(floor, 1 << max(0, int(x) - 1).bit_length())


def bucket_trees(T: int) -> int:
    """Tree-count pad bucket: the next multiple of 1/16th of T's power
    of two.  Unlike the row bucket, padded TREES cost real walk work on
    every request (a pass-through tree still walks), so a full pow2
    bucket would tax a fixed 513-tree ensemble ~2x forever; this
    granule caps the padding overhead at T/8 (12.5%) while a
    checkpoint-resumed, still-growing ensemble retraces at most 16
    times per doubling instead of every round."""
    g = max(1, bucket_pow2(T) // 16)
    return -(-int(T) // g) * g


def _inference_plan_key(plan: ExecutionPlan) -> ExecutionPlan:
    """Collapse a plan to the fields ensemble inference actually reads
    (traversal strategy, interpret mode, tree tile) so plans differing
    only in training-side knobs share one cached step."""
    return ExecutionPlan(traversal_strategy=plan.traversal_strategy,
                         interpret=plan.interpret,
                         trees_per_block=plan.trees_per_block).resolved()


def _build_predict_step(plan: ExecutionPlan, depth: int, n_classes: int,
                        missing_bin: int, trace_count):
    """One jitted predict step per (plan, depth, K, missing-bin) key.

    The jit's own shape cache then holds one executable per (row bucket,
    tree bucket, field count) — ``trace_count[0]`` counts exactly those
    compilations, which is what the serving loop asserts on.  The output
    accumulator arrives pre-filled with the base margin and is donated
    where the backend supports aliasing (TPU/GPU), so the margin add
    updates it in place.
    """
    def impl(out, codes, trees):
        trace_count[0] += 1                # trace-time side effect only
        m = ops.predict_ensemble(trees, codes, missing_bin=missing_bin,
                                 depth=depth, plan=plan,
                                 n_classes=n_classes)
        return out + m

    donate = (0,) if jax.default_backend() in ("tpu", "gpu") else ()
    return jax.jit(impl, donate_argnums=donate)


class PredictCache:
    """A namespace of compiled predict steps (the serving jit cache).

    Each instance holds its own ``(plan, depth, K, missing-bin) -> jitted
    step`` table plus hit/miss/trace counters, so multi-tenant serving can
    key compiled executables *per model name*: two resident models never
    evict each other's steps, a hot-swapped model version inherits its
    predecessor's executables (zero retraces when the shape buckets
    match — trees are traced arguments, not compile-time constants), and
    ``ModelRegistry.unpublish`` drops exactly one model's compilations.

    The module-level default instance backs :func:`predict_margin_cached`
    when no ``cache=`` is passed (the single-model path), with
    :func:`predict_cache_stats` / :func:`predict_cache_clear` as its
    process-wide observability handles.  Thread-safe: serving worker
    threads and off-hot-path warmup may use one instance concurrently.
    """

    def __init__(self):
        self._steps = {}
        self._lock = threading.Lock()
        self._hits = 0
        self._misses = 0
        self._traces = [0]          # shared with the jit closures

    def step(self, plan: ExecutionPlan, depth: int, n_classes: int,
             missing_bin: int):
        key = (plan, depth, n_classes, missing_bin)
        with self._lock:
            fn = self._steps.get(key)
            if fn is not None:
                self._hits += 1
                return fn
            self._misses += 1
        fn = _build_predict_step(plan, depth, n_classes, missing_bin,
                                 self._traces)
        with self._lock:
            # two threads may race to build the same key; keep the first
            return self._steps.setdefault(key, fn)

    def stats(self) -> Dict[str, int]:
        """``entries`` distinct (plan, depth, K) steps, ``traces`` total
        XLA compilations across all shape buckets (the serving loop's
        retrace counter)."""
        with self._lock:
            return {"entries": len(self._steps), "hits": self._hits,
                    "misses": self._misses, "traces": self._traces[0]}

    def clear(self) -> None:
        with self._lock:
            self._steps.clear()
            self._hits = self._misses = 0
            self._traces[0] = 0


_DEFAULT_CACHE = PredictCache()


def _padded_trees(model: GBDTModel, n_total: int) -> TreeArrays:
    """``model.trees`` zero-padded to exactly ``n_total`` trees, cached on
    the model instance so repeated requests reuse the device arrays."""
    cache = model.__dict__.setdefault("_pad_tree_cache", {})
    trees = cache.get(n_total)
    if trees is None:
        cache[n_total] = trees = pad_trees(model, n_total).trees
    return trees


def predict_margin_cached(model: GBDTModel, codes, *,
                          plan: Optional[ExecutionPlan] = None,
                          n_rows: Optional[int] = None,
                          cache: Optional[PredictCache] = None) -> jax.Array:
    """Ensemble margins through the compile-once engine.

    ``codes`` (or a :class:`BinnedDataset`) is padded up to a power-of-two
    row bucket (>= ``ROW_BUCKET_FLOOR``) and the ensemble up to its
    :func:`bucket_trees` bucket, so a serving stream of varying batch
    sizes (and a checkpoint-resumed, still-growing tree count) compiles
    once per bucket and never again.  Bucketing is invisible in the
    results: padded rows are sliced off before returning and padded
    trees output exactly 0.  ``n_rows`` marks the real row count when
    the caller already padded.  ``cache`` selects the step namespace
    (multi-tenant serving keys one :class:`PredictCache` per model name);
    ``None`` uses the process-wide default.
    """
    cache = cache if cache is not None else _DEFAULT_CACHE
    plan = _inference_plan_key(
        (plan if plan is not None else ExecutionPlan()).resolved())
    codes = codes.codes if isinstance(codes, BinnedDataset) else codes
    if isinstance(codes, PackedCodes):
        codes = codes.unpack()     # row buckets key on the uint8 layout
    codes = jnp.asarray(codes)
    n = int(codes.shape[0]) if n_rows is None else int(n_rows)
    row_bucket = bucket_pow2(int(codes.shape[0]), ROW_BUCKET_FLOOR)
    if int(codes.shape[0]) != row_bucket:
        codes = jnp.pad(codes, ((0, row_bucket - codes.shape[0]), (0, 0)))
    K = model.n_classes
    trees = _padded_trees(model, bucket_trees(model.n_trees))
    step = cache.step(plan, model.max_depth, K, model.missing_bin)
    base = jnp.asarray(model.base_margin, jnp.float32)
    out0 = (jnp.full((row_bucket,), base, jnp.float32) if K == 1
            else jnp.zeros((row_bucket, K), jnp.float32) + base)
    return step(out0, codes, trees)[:n]


def predict_cache_stats(cache: Optional[PredictCache] = None
                        ) -> Dict[str, int]:
    """Observability for a predict cache (the process-wide default when
    ``cache`` is None) — see :meth:`PredictCache.stats`."""
    return (cache if cache is not None else _DEFAULT_CACHE).stats()


def predict_cache_clear(cache: Optional[PredictCache] = None) -> None:
    (cache if cache is not None else _DEFAULT_CACHE).clear()


def sharded_predict(mesh: Mesh, model: GBDTModel, codes, *,
                    plan: Optional[ExecutionPlan] = None) -> jax.Array:
    """Tree-parallel x record-parallel ensemble inference on ``mesh``.

    Requires n_trees % mesh"model" == 0, and for multi-class ensembles a
    per-shard tree count that is a multiple of K so the round-major
    class routing survives contiguous sharding (pad the ensemble with
    zero-value trees via ``pad_trees(model, mesh_model * K)`` otherwise).
    Returns margins (n,), or (n, K) when ``model.n_classes > 1`` — each
    shard walks its resident trees and one psum combines the per-class
    columns.  ``plan`` selects the local traversal substrate (its own
    ``mesh`` field is ignored here — this IS the mesh dispatch).
    """
    da = data_axes(mesh)
    m = mesh.shape["model"]
    T = model.n_trees
    K = getattr(model, "n_classes", 1)
    if T % m:
        raise ValueError(f"{T} trees do not divide the model axis ({m}); "
                         "use pad_trees() first")
    if K > 1 and (T // m) % K:
        raise ValueError(
            f"{T} trees over {m} shards leave {T // m} trees per shard, "
            f"not a multiple of n_classes={K}; use pad_trees(model, "
            f"{m * K}) so round-major class routing survives sharding")
    if plan is None:
        plan = ExecutionPlan(traversal_strategy="reference")
    plan = plan.replace(mesh=None).resolved()

    def local(codes_l, *tree_leaves):
        trees_l = TreeArrays(*tree_leaves)       # (T/m, ...) local trees
        out = ops.predict_ensemble(trees_l, codes_l,
                                   missing_bin=model.missing_bin,
                                   depth=model.max_depth, plan=plan,
                                   n_classes=K)
        # paper §III-D: combine the per-chip tree outputs
        return jax.lax.psum(out, "model")

    # replicated per-shard zeros inside predict_ensemble are unvarying;
    # skip the static varying-axes check (the psum makes the output
    # well-defined)
    fn = shard_map(
        local, mesh=mesh,
        in_specs=(P(da, None),) + tuple(P("model") for _ in range(5)),
        out_specs=P(da, None) if K > 1 else P(da), check_vma=False)
    out = fn(codes, *model.trees)
    if K > 1:
        return out + jnp.asarray(model.base_margin, jnp.float32)
    return out + model.base_margin


def pad_trees(model: GBDTModel, multiple: int) -> GBDTModel:
    """Append zero-output pass-through trees so n_trees divides a mesh axis."""
    T = model.n_trees
    pad = -T % multiple
    if pad == 0:
        return model
    t = model.trees

    def pad0(a):
        return jnp.concatenate(
            [a, jnp.zeros((pad,) + a.shape[1:], a.dtype)], axis=0)

    padded = TreeArrays(
        feature=jnp.concatenate(
            [t.feature, jnp.full((pad,) + t.feature.shape[1:], -1,
                                 t.feature.dtype)]),
        threshold=pad0(t.threshold), is_cat=pad0(t.is_cat),
        default_left=pad0(t.default_left), leaf_value=pad0(t.leaf_value))
    return dataclasses.replace(model, trees=padded)


def feature_importance(model: GBDTModel, kind: str = "gain"
                       ) -> np.ndarray:
    """Per-field importance over the ensemble.

    kind: "split" (split counts), "gain" (sum of leaf-weight variance
    proxy per split — exact gains are not stored in the compact arrays,
    so subtree leaf-value spread stands in), or "cover" (uniform count
    weighting by subtree width).
    """
    feats = np.asarray(model.trees.feature)        # (T, n_int)
    leaves = np.asarray(model.trees.leaf_value, np.float64)  # (T, n_leaf)
    F = model.n_fields
    imp = np.zeros((F,), np.float64)
    T = feats.shape[0]
    depth = model.max_depth
    if kind == "split":
        valid = feats >= 0
        np.add.at(imp, feats[valid], 1.0)
    else:
        # vectorized per level: the heap positions at ``level`` cover the
        # bottom row in contiguous runs of reps = 2**(depth - level) slots,
        # so one reshape turns the subtree-leaf variance into a segment op
        for level in range(depth):
            nn = 2 ** level
            reps = 2 ** (depth - level)
            f_lvl = feats[:, nn - 1:2 * nn - 1]                # (T, nn)
            var = leaves.reshape(T, nn, reps).var(axis=2)      # (T, nn)
            w = float(reps) if kind == "cover" else 1.0
            valid = f_lvl >= 0
            np.add.at(imp, f_lvl[valid], w * var[valid])
    s = imp.sum()
    return imp / s if s > 0 else imp


@dataclasses.dataclass
class GBDTPipeline:
    """Binner + model bundle: raw float/NaN matrices in, predictions out.

    ``predict``/``predict_margin`` are the serving path: the raw batch is
    row-padded to its power-of-two bucket on the host, binned ON DEVICE
    (``Binner.transform_codes_device`` — no per-request numpy round-trip
    and no redundant column-major copy), and dispatched through the
    compile-once :func:`predict_margin_cached` engine.
    """

    binner: Binner
    model: GBDTModel

    def predict_margin(self, X: np.ndarray, *,
                       plan: Optional[ExecutionPlan] = None,
                       mode: str = "cached",
                       cache: Optional[PredictCache] = None) -> jax.Array:
        """Raw margins for a raw feature matrix.

        ``mode="cached"`` (the serving default) row-pads to the
        power-of-two bucket and dispatches through the compile-once
        engine; ``mode="direct"`` bins and walks the exact request shape
        (one-off calls that should not populate a jit cache).  ``cache``
        selects the step namespace for the cached mode.
        """
        if mode not in ("cached", "direct"):
            raise ValueError(f"unknown predict mode {mode!r}; choose "
                             "'cached' or 'direct'")
        X = np.asarray(X, dtype=np.float32)
        if mode == "direct":
            codes = self.binner.transform_codes_device(X)
            return self.model.predict_margin(codes, plan=plan)
        n = X.shape[0]
        row_bucket = bucket_pow2(n, ROW_BUCKET_FLOOR)
        if row_bucket != n:
            # zero-filled (not NaN) pad rows: they bin to real codes and
            # walk the trees, but are sliced off before returning
            X = np.pad(X, ((0, row_bucket - n), (0, 0)))
        codes = self.binner.transform_codes_device(X)
        return predict_margin_cached(self.model, codes, plan=plan,
                                     n_rows=n, cache=cache)

    def predict(self, X: np.ndarray, strategy: Optional[str] = None, *,
                plan: Optional[ExecutionPlan] = None,
                mode: str = "cached",
                cache: Optional[PredictCache] = None) -> jax.Array:
        base = plan if plan is not None else ExecutionPlan()
        if strategy is not None and strategy != "auto":
            warnings.warn(
                "legacy strategy-string kwargs are deprecated; pass "
                "plan=ExecutionPlan(traversal_strategy=...) instead",
                DeprecationWarning, stacklevel=2)
            base = base.replace(traversal_strategy=strategy)
        return self.model.loss.transform(
            self.predict_margin(X, plan=base, mode=mode, cache=cache))

    def to_state(self) -> Dict:
        return {
            "model": self.model.to_state(),
            "binner": {
                "max_bins": self.binner.max_bins,
                "categorical": sorted(self.binner.categorical_fields),
                "edges": self.binner._edges,
                "is_cat": self.binner._is_cat,
                "n_value_bins": self.binner._n_value_bins,
            },
        }

    @classmethod
    def from_state(cls, state: Dict) -> "GBDTPipeline":
        b = Binner(int(state["binner"]["max_bins"]),
                   [int(c) for c in np.asarray(
                       state["binner"]["categorical"]).ravel()])
        b._edges = np.asarray(state["binner"]["edges"])
        b._is_cat = np.asarray(state["binner"]["is_cat"])
        b._n_value_bins = np.asarray(state["binner"]["n_value_bins"])
        return cls(binner=b, model=GBDTModel.from_state(state["model"]))

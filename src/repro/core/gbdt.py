"""Gradient-boosted decision trees — the end-to-end trainer (steps ①–⑥).

The outer loop follows Table I of the paper: grow trees one at a time
(step ⑥), each tree level-by-level (steps ①–④), then pass every record
through the finished tree to refresh its gradient statistics and the total
loss (step ⑤).  The loop is host-driven; each step body is a jitted JAX
function, so the same trainer runs single-device (this container) or under
a pjit mesh (``repro.distributed``).
"""
from __future__ import annotations

import dataclasses
import functools
import time
import warnings
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.plan import ExecutionPlan
from repro.core import binning as binning_mod
from repro.core import losses as losses_mod
from repro.core import tree as tree_mod
from repro.core.binning import BinnedDataset
from repro.kernels import ops
from repro.kernels.ref import TreeArrays
from repro.resilience import metrics as _metrics
from repro.resilience.errors import (NumericalDivergenceError,
                                     TrainingInterrupted)
from repro.resilience.recovery import RecoveryPolicy, classify
from repro.resilience.retry import RetryingSource
from repro.resilience.shutdown import GracefulShutdown


@dataclasses.dataclass(frozen=True)
class GBDTConfig:
    """Training hyper-parameters (XGBoost-compatible naming where possible)."""

    n_trees: int = 100
    max_depth: int = 6               # the paper trains 500 x depth-6 trees
    learning_rate: float = 0.1      # shrinkage
    lambda_: float = 1.0             # L2 weight regularization
    gamma: float = 0.0               # per-split complexity penalty
    min_child_weight: float = 1.0
    objective: str = "reg:squarederror"
    subsample: float = 1.0           # stochastic GB (Friedman 2002)
    colsample_bytree: float = 1.0
    goss_top_rate: float = 0.0       # GOSS: kept fraction by |gradient|
    goss_other_rate: float = 0.0     # GOSS: sampled fraction of the rest
    grow_policy: str = "depthwise"   # "depthwise" | "lossguide"
    max_leaves: Optional[int] = None  # lossguide only
    fused_rounds: bool = False       # one jitted step per boosting round:
    #                                  grow + leaf settle + margin update +
    #                                  loss accumulate, margins donated,
    #                                  history fetched every log_every rounds
    log_every: int = 10              # host-fetch / verbose cadence (rounds)
    # deprecated per-step strategy strings — one release path; set an
    # ExecutionPlan (train(plan=...) / fit(plan=...)) instead
    hist_strategy: str = "auto"      # see repro.api.plan.HIST_STRATEGIES
    partition_strategy: str = "auto"
    traversal_strategy: str = "auto"
    host_offload_split: bool = False  # the paper's step-② offload
    early_stopping_rounds: Optional[int] = None
    n_classes: Optional[int] = None  # multi:softmax only; K trees per round
    seed: int = 0

    def __post_init__(self):
        if (self.hist_strategy != "auto"
                or self.partition_strategy != "auto"
                or self.traversal_strategy != "auto"
                or self.host_offload_split):
            warnings.warn(
                "legacy strategy-string kwargs are deprecated; "
                "GBDTConfig's hist_strategy / partition_strategy / "
                "traversal_strategy / host_offload_split fields move to "
                "ExecutionPlan — pass plan=ExecutionPlan(...) to "
                "train()/fit() instead", DeprecationWarning, stacklevel=3)
        if self.max_depth < 1 or self.max_depth > 10:
            raise ValueError("max_depth must be in [1, 10]")
        if self.grow_policy not in ("depthwise", "lossguide"):
            raise ValueError(f"unknown grow_policy {self.grow_policy!r}")
        if self.log_every < 1:
            raise ValueError("log_every must be >= 1")
        if self.fused_rounds and self.grow_policy != "depthwise":
            raise ValueError("fused_rounds requires the depthwise "
                             "grow_policy (lossguide growth is host-driven)")
        if self.goss_top_rate or self.goss_other_rate:
            if not (0.0 <= self.goss_top_rate < 1.0
                    and 0.0 < self.goss_other_rate <= 1.0
                    and self.goss_top_rate + self.goss_other_rate <= 1.0):
                raise ValueError(
                    "GOSS rates need 0 <= top_rate < 1, 0 < other_rate <= 1 "
                    f"and top+other <= 1; got top={self.goss_top_rate}, "
                    f"other={self.goss_other_rate}")
        if self.objective in losses_mod.MULTICLASS_OBJECTIVES:
            if self.n_classes is None or self.n_classes < 2:
                raise ValueError(
                    f"objective {self.objective!r} requires n_classes >= 2")
            if self.grow_policy != "depthwise":
                raise ValueError("multi-class training supports only the "
                                 "depthwise grow_policy")
        elif self.n_classes not in (None, 1):
            raise ValueError(
                f"n_classes={self.n_classes} only applies to multi-class "
                f"objectives, not {self.objective!r}")


@dataclasses.dataclass
class GBDTModel:
    """A trained ensemble: stacked fixed-shape trees + prediction metadata.

    Multi-class ensembles (``n_classes > 1``) stack trees round-major —
    the tree at index ``r * K + k`` belongs to boosting round r, class k —
    and ``base_margin`` is a (K,) per-class vector; margins gain a class
    axis: ``predict_margin`` returns (n, K).
    """

    trees: TreeArrays            # stacked (T, ...) arrays
    base_margin: float           # scalar, or (K,) array when n_classes > 1
    objective: str
    missing_bin: int
    n_fields: int
    max_depth: int
    n_classes: int = 1

    @property
    def n_trees(self) -> int:
        return int(self.trees.feature.shape[0])

    @property
    def n_rounds(self) -> int:
        """Boosting rounds (== n_trees for scalar objectives)."""
        return self.n_trees // max(self.n_classes, 1)

    @property
    def loss(self) -> losses_mod.Loss:
        return losses_mod.get_loss(
            self.objective, self.n_classes if self.n_classes > 1 else None)

    def predict_margin(self, codes, strategy: Optional[str] = None, *,
                       plan: Optional[ExecutionPlan] = None,
                       cached: Optional[bool] = None,
                       mode: Optional[str] = None,
                       cache=None) -> jax.Array:
        """Raw ensemble margins for binned ``codes``.

        ``mode`` is the ONE dispatch knob for the predict surface:

        * ``"direct"`` (default) — dispatch on the exact request shape;
          what training-internal callers want.
        * ``"cached"`` — route through the compile-once predict engine
          (:func:`repro.core.inference.predict_margin_cached`): rows and
          tree count are padded to power-of-two buckets so repeated calls
          with varying batch sizes reuse one compiled step per bucket —
          the serving path.  ``cache`` (a
          :class:`~repro.core.inference.PredictCache`) selects the step
          namespace; ``None`` uses the process-wide default.

        The boolean ``cached=`` flag and the positional ``strategy``
        string are deprecated spellings of the same choices (see
        ``docs/api.md`` for the migration table).
        """
        codes = codes.codes if isinstance(codes, BinnedDataset) else codes
        plan = self._resolve_plan(plan, strategy)
        mode = self._resolve_mode(mode, cached)
        if mode == "cached" and plan.mesh is None:
            from repro.core.inference import predict_margin_cached
            return predict_margin_cached(self, codes, plan=plan,
                                         cache=cache)
        out = ops.predict_ensemble(self.trees, codes,
                                   missing_bin=self.missing_bin,
                                   depth=self.max_depth, plan=plan,
                                   n_classes=self.n_classes)
        if self.n_classes > 1:
            return out + jnp.asarray(self.base_margin, jnp.float32)
        return out + self.base_margin

    def predict(self, codes, strategy: Optional[str] = None, *,
                plan: Optional[ExecutionPlan] = None,
                cached: Optional[bool] = None,
                mode: Optional[str] = None, cache=None) -> jax.Array:
        """Transformed predictions — same surface as :meth:`predict_margin`."""
        return self.loss.transform(
            self.predict_margin(codes, strategy, plan=plan, cached=cached,
                                mode=mode, cache=cache))

    @staticmethod
    def _resolve_mode(mode: Optional[str],
                      cached: Optional[bool]) -> str:
        if cached is not None:
            warnings.warn(
                'cached= is deprecated; use mode="cached" or '
                'mode="direct" instead', DeprecationWarning, stacklevel=3)
            if mode is None:
                mode = "cached" if cached else "direct"
        mode = mode if mode is not None else "direct"
        if mode not in ("cached", "direct"):
            raise ValueError(f"unknown predict mode {mode!r}; choose "
                             "'cached' or 'direct'")
        return mode

    @staticmethod
    def _resolve_plan(plan: Optional[ExecutionPlan],
                      strategy: Optional[str]) -> ExecutionPlan:
        """Model-level lifting of the pre-plan positional ``strategy``
        string (deprecated — one release path, then plans only)."""
        base = plan if plan is not None else ExecutionPlan()
        if strategy is not None and strategy != "auto":
            warnings.warn(
                "legacy strategy-string kwargs are deprecated; pass "
                "plan=ExecutionPlan(traversal_strategy=...) instead",
                DeprecationWarning, stacklevel=4)
            base = base.replace(traversal_strategy=strategy)
        return base.resolved()

    # -- (de)serialization for checkpointing ------------------------------
    def meta(self) -> Dict:
        """JSON-safe model metadata — the ONE encoding shared by state
        dicts, bundles and step checkpoints (see ``model_from_meta``)."""
        return {
            "base_margin": pack_base_margin(self.base_margin,
                                            self.n_classes),
            "objective": self.objective,
            "missing_bin": int(self.missing_bin),
            "n_fields": int(self.n_fields),
            "max_depth": int(self.max_depth),
            "n_classes": int(self.n_classes),
        }

    def to_state(self) -> Dict:
        return {
            "trees": {k: np.asarray(v) for k, v in self.trees._asdict().items()},
            "meta": self.meta(),
        }

    @classmethod
    def from_state(cls, state: Dict) -> "GBDTModel":
        trees = TreeArrays(**{k: jnp.asarray(v)
                              for k, v in state["trees"].items()})
        return model_from_meta(trees, state["meta"])


def pack_base_margin(base_margin, n_classes: int):
    """JSON-safe base margin: per-class float list for K > 1, bare float
    otherwise."""
    if n_classes > 1:
        return [float(b) for b in np.asarray(base_margin)]
    return float(base_margin)


def unpack_base_margin(value, n_classes: int):
    return (np.asarray(value, np.float32) if n_classes > 1
            else float(value))


def model_from_meta(trees: TreeArrays, m: Dict) -> GBDTModel:
    """Rebuild a model from its JSON meta (``GBDTModel.meta``); states
    written before multi-class support carry no n_classes key (K = 1)."""
    K = int(m.get("n_classes", 1))
    # checkpoint restore round-trips scalars through numpy — coerce
    return GBDTModel(trees=trees,
                     base_margin=unpack_base_margin(m["base_margin"], K),
                     objective=str(m["objective"]),
                     missing_bin=int(m["missing_bin"]),
                     n_fields=int(m["n_fields"]),
                     max_depth=int(m["max_depth"]),
                     n_classes=K)


def _stack_trees(trees: List[TreeArrays]) -> TreeArrays:
    return TreeArrays(*[jnp.stack([getattr(t, f) for t in trees])
                        for f in TreeArrays._fields])


def _stack_forests(forests: List[TreeArrays]) -> TreeArrays:
    """Stack per-round (K, ...) forests into round-major (R*K, ...) trees."""
    stacked = _stack_trees(forests)                  # (R, K, ...)
    return TreeArrays(*[a.reshape((-1,) + a.shape[2:]) for a in stacked])


def _unstack_forests(trees: TreeArrays, n_rounds: int,
                     n_classes: int) -> List[TreeArrays]:
    """Invert ``_stack_forests``: (R*K, ...) -> R forests of (K, ...)."""
    resh = [a.reshape((n_rounds, n_classes) + a.shape[1:]) for a in trees]
    return [TreeArrays(*[a[r] for a in resh]) for r in range(n_rounds)]


@dataclasses.dataclass
class TrainResult:
    model: GBDTModel
    history: Dict[str, List[float]]
    step_times: Dict[str, float]     # accumulated seconds per paper step
    stats: Dict = dataclasses.field(default_factory=dict)  # trainer extras
    # streaming fits populate stats with the chunking evidence:
    # n_rows, chunk_rows, n_chunks, passes_per_round


def goss_weights(g, key, top_rate: float, other_rate: float) -> jax.Array:
    """Gradient-based One-Side Sampling weights (LightGBM-style GOSS).

    Keeps the top ``top_rate`` fraction of records by gradient magnitude
    at weight 1, uniformly samples ``other_rate``·n of the rest at weight
    ``(1 - top_rate) / other_rate`` (amplified so the small-gradient
    population keeps its expected contribution to BOTH g and h — the
    hessian reweighting), and drops everything else at weight 0.  ``g`` is
    (n,) or (n, K); multi-class records rank by summed per-class |g|.
    """
    score = jnp.abs(g) if g.ndim == 1 else jnp.sum(jnp.abs(g), axis=-1)
    n = score.shape[0]
    n_top = min(int(np.ceil(top_rate * n)), n)
    n_other = min(int(np.ceil(other_rate * n)), n - n_top)
    order = jnp.argsort(-score)
    w = jnp.zeros((n,), jnp.float32).at[order[:n_top]].set(1.0)
    if n_other > 0:
        rest = order[n_top:]
        pick = jax.random.choice(key, rest.shape[0], (n_other,),
                                 replace=False)
        w = w.at[rest[pick]].set((1.0 - top_rate) / other_rate)
    return w


def _round_stats(config: GBDTConfig, tkey, g, h, n: int, F: int,
                 K: Optional[int]):
    """Per-round stochastic filters on the gradient statistics: GOSS,
    row subsampling, and the per-tree field mask.  Shared verbatim by the
    in-memory and streaming trainers (identical RNG folds), so the two
    paths draw identical samples for identical seeds."""
    if config.goss_top_rate or config.goss_other_rate:
        w = goss_weights(g, jax.random.fold_in(tkey, 2),
                         config.goss_top_rate, config.goss_other_rate)
        if K is not None:
            w = w[:, None]
        g, h = g * w, h * w
    if config.subsample < 1.0:
        mask = (jax.random.uniform(jax.random.fold_in(tkey, 0), (n,))
                < config.subsample).astype(jnp.float32)
        if K is not None:          # same record draw for every class
            mask = mask[:, None]
        g, h = g * mask, h * mask
    if config.colsample_bytree < 1.0:
        field_mask = (jax.random.uniform(jax.random.fold_in(tkey, 1),
                                         (F,)) < config.colsample_bytree)
        field_mask = field_mask.at[jnp.argmax(field_mask)].set(True)
    else:
        field_mask = jnp.ones((F,), bool)
    return g, h, field_mask


def _validate_multiclass_labels(K: int, y, eval_y=None) -> None:
    """An out-of-range class in either split would otherwise clamp inside
    the softmax loss (silent NaN loss / broken early stopping)."""
    batches = [("training", y)]
    if eval_y is not None:
        batches.append(("eval_set", jnp.asarray(eval_y, jnp.float32)))
    for what, yy in batches:
        if not yy.shape[0]:
            continue
        y_min, y_max = float(jnp.min(yy)), float(jnp.max(yy))
        if (y_max >= K or y_min < 0
                or not bool(jnp.all(yy == jnp.round(yy)))):
            raise ValueError(
                f"multi-class {what} labels must be integers in "
                f"[0, {K}); observed range [{y_min}, {y_max}]")


# --------------------------------------------------------------------------
# fused boosting rounds: one jitted step per round, margins donated
# --------------------------------------------------------------------------
def _fused_step_key(config: GBDTConfig) -> GBDTConfig:
    """Strip the fields that do not shape the compiled round (loop
    controls like seed/n_trees/early stopping, and the legacy strategy
    strings already lifted into the plan) so e.g. a seed sweep or CV
    loop reuses ONE compiled step instead of retracing per config."""
    return dataclasses.replace(
        config, n_trees=1, seed=0, early_stopping_rounds=None, log_every=1,
        max_leaves=None, hist_strategy="auto", partition_strategy="auto",
        traversal_strategy="auto", host_offload_split=False)


@functools.lru_cache(maxsize=64)
def _fused_round_step(config: GBDTConfig, plan: ExecutionPlan, n: int,
                      F: int, n_bins: int, n_eval: Optional[int]):
    """Compile one boosting round as a single jitted step.

    The step fuses the whole round — gradient statistics, per-round
    stochastic filters, tree growth (steps ①–④), leaf shrinkage, step-⑤
    margin refresh and the device-side loss reduction — so the host
    dispatches once per round and never synchronizes on intermediate
    values.  Margins (train and eval) are donated where the backend
    supports donation, so the round updates them in place.  Cached per
    (``_fused_step_key(config)``, plan, shapes): repeated fits reuse the
    compiled step.
    """
    loss = losses_mod.get_loss(config.objective, config.n_classes)
    K = loss.n_outputs
    with_eval = n_eval is not None

    def body(margins, y, tkey, codes, codes_cm, is_cat_field):
        g, h = loss.grad_hess(margins, y)
        g, h, field_mask = _round_stats(config, tkey, g, h, n, F, K)
        common = dict(depth=config.max_depth, n_bins=n_bins,
                      missing_bin=n_bins - 1, is_cat_field=is_cat_field,
                      field_mask=field_mask, lambda_=config.lambda_,
                      gamma=config.gamma,
                      min_child_weight=config.min_child_weight, plan=plan)
        if K is not None:
            tree = tree_mod.fit_forest(codes, codes_cm, g.T, h.T, **common)
        else:
            tree = tree_mod.fit_tree(codes, codes_cm, g, h, **common)
        tree = tree._replace(
            leaf_value=tree.leaf_value * config.learning_rate)
        data = BinnedDataset(codes, codes_cm, is_cat_field, n_bins,
                             None, None)
        delta = (_predict_forest(tree, data, plan) if K is not None
                 else _predict_one_tree(tree, data, plan))
        margins = margins + delta
        return margins, tree, jnp.mean(loss.value(margins, y))

    if not with_eval:
        step = body
        donate = (0,)
    else:
        def step(margins, ev_margins, y, y_ev, tkey, codes, codes_cm,
                 ev_codes, ev_codes_cm, is_cat_field):
            margins, tree, train_loss = body(margins, y, tkey, codes,
                                             codes_cm, is_cat_field)
            ev_data = BinnedDataset(ev_codes, ev_codes_cm, is_cat_field,
                                    n_bins, None, None)
            ev_delta = (_predict_forest(tree, ev_data, plan)
                        if K is not None
                        else _predict_one_tree(tree, ev_data, plan))
            ev_margins = ev_margins + ev_delta
            ev_loss = jnp.mean(loss.value(ev_margins, y_ev))
            return margins, ev_margins, tree, train_loss, ev_loss
        donate = (0, 1)
    # donation is a no-op (plus a warning) on the CPU backend — only ask
    # for it where XLA actually aliases the buffers
    if jax.default_backend() not in ("tpu", "gpu"):
        donate = ()
    return jax.jit(step, donate_argnums=donate)


def train(config: GBDTConfig, data: BinnedDataset, y,
          eval_set: Optional[Tuple[BinnedDataset, jax.Array]] = None,
          init_model: Optional[GBDTModel] = None,
          callback: Optional[Callable[[int, GBDTModel], None]] = None,
          verbose: bool = False,
          plan: Optional[ExecutionPlan] = None,
          recovery: Optional[RecoveryPolicy] = None,
          shutdown: Optional[GracefulShutdown] = None) -> TrainResult:
    """Fit a GBDT ensemble.  Deterministic per-tree RNG (fault-replayable).

    ``plan`` selects the kernel strategies for every step; when omitted it
    is lifted from the config's legacy per-step strategy strings.

    ``recovery`` arms the numerical divergence sentinels: a non-finite
    loss/margin caught every ``config.log_every`` rounds rolls the fused
    fit back to the last finite round (learning-rate backoff when the
    same round diverges twice, bounded by
    ``recovery.max_divergence_rollbacks``); without a policy the sentinel
    raises :class:`NumericalDivergenceError` fail-fast.  ``shutdown``
    (a :class:`repro.resilience.GracefulShutdown`) makes the fit
    preemption-safe: a delivered signal finishes the in-flight round,
    commits it, and raises :class:`TrainingInterrupted` carrying the
    partial :class:`TrainResult`.
    """
    if plan is None:
        plan = ExecutionPlan.from_config(config)
    plan = plan.resolved()
    if plan.mesh is not None:
        # a training mesh routes the whole fit through the data-parallel
        # engine (records sharded over plan.data_axes, one histogram psum
        # per level) — see repro.distributed.trainer
        from repro.distributed.trainer import train_distributed
        return train_distributed(config, data, y, eval_set=eval_set,
                                 init_model=init_model, callback=callback,
                                 verbose=verbose, plan=plan,
                                 recovery=recovery, shutdown=shutdown)
    loss = losses_mod.get_loss(config.objective, config.n_classes)
    K = loss.n_outputs                 # None for scalar objectives
    y = jnp.asarray(y, jnp.float32)
    if K is not None:
        _validate_multiclass_labels(
            K, y, eval_set[1] if eval_set is not None else None)
    n, F = data.codes.shape
    depth = config.max_depth

    trees: List[TreeArrays] = []       # one entry per round; multi-class
    history: Dict[str, List[float]] = {"train_loss": []}   # entries: (K,...)
    if eval_set is not None:
        history["eval_loss"] = []
    step_times = {"binning_split": 0.0, "partition": 0.0, "traversal": 0.0,
                  "other": 0.0}

    if init_model is not None:
        if K is not None:
            trees = _unstack_forests(init_model.trees, init_model.n_rounds,
                                     K)
        else:
            trees = [TreeArrays(*[a[i] for a in init_model.trees])
                     for i in range(init_model.n_trees)]
        base_margin = init_model.base_margin
        margins = _replay_margins(init_model, data, plan)
        eval_margins = (_replay_margins(init_model, eval_set[0], plan)
                        if eval_set is not None else None)
    elif K is not None:
        base_margin = np.asarray(loss.base_margin(y), np.float32)  # (K,)
        margins = jnp.broadcast_to(jnp.asarray(base_margin), (n, K))
        eval_margins = (jnp.broadcast_to(jnp.asarray(base_margin),
                                         (eval_set[1].shape[0], K))
                        if eval_set is not None else None)
    else:
        base_margin = float(loss.base_margin(y))
        margins = jnp.full((n,), base_margin, jnp.float32)
        eval_margins = (jnp.full((eval_set[1].shape[0],), base_margin)
                        if eval_set is not None else None)

    key = jax.random.PRNGKey(config.seed)
    best_eval, best_round = np.inf, -1

    if config.fused_rounds:
        return _train_fused(config, plan, data, y, eval_set, trees, margins,
                            eval_margins, base_margin, history, step_times,
                            key, callback, verbose, n, F,
                            recovery=recovery, shutdown=shutdown)

    start = len(trees)
    for t_idx in range(start, start + config.n_trees):
        tkey = jax.random.fold_in(key, t_idx)  # deterministic replay stream
        t0 = time.perf_counter()
        g, h = loss.grad_hess(margins, y)
        g, h, field_mask = _round_stats(config, tkey, g, h, n, F, K)

        common = dict(depth=depth, n_bins=data.n_bins,
                      missing_bin=data.missing_bin,
                      is_cat_field=data.is_categorical,
                      field_mask=field_mask, lambda_=config.lambda_,
                      gamma=config.gamma,
                      min_child_weight=config.min_child_weight, plan=plan)
        if K is not None:
            # one class-batched pass grows all K per-class trees
            tree = tree_mod.fit_forest(data.codes, data.codes_cm,
                                       g.T, h.T, **common)
        elif config.grow_policy == "depthwise":
            tree = tree_mod.fit_tree(data.codes, data.codes_cm, g, h,
                                     **common)
        else:
            tree = tree_mod.fit_tree_lossguide(
                data.codes, data.codes_cm, g, h,
                max_leaves=config.max_leaves, **common)
        # shrinkage is folded into the stored leaf values so a tree is
        # self-contained (predict == sum of tree outputs, XGBoost-style)
        tree = tree._replace(
            leaf_value=tree.leaf_value * config.learning_rate)
        tree = jax.tree.map(jax.block_until_ready, tree)
        t1 = time.perf_counter()
        step_times["binning_split"] += t1 - t0

        # step ⑤ — one-tree traversal refreshes margins (and thus g, h)
        if K is not None:
            delta = _predict_forest(tree, data, plan)          # (n, K)
        else:
            delta = _predict_one_tree(tree, data, plan)
        margins = margins + delta
        margins.block_until_ready()
        t2 = time.perf_counter()
        step_times["traversal"] += t2 - t1

        trees.append(tree)
        train_loss = float(jnp.mean(loss.value(margins, y)))
        history["train_loss"].append(train_loss)

        if eval_set is not None:
            if K is not None:
                ev_delta = _predict_forest(tree, eval_set[0], plan)
            else:
                ev_delta = _predict_one_tree(tree, eval_set[0], plan)
            eval_margins = eval_margins + ev_delta
            ev = float(jnp.mean(loss.value(eval_margins,
                                           jnp.asarray(eval_set[1],
                                                       jnp.float32))))
            history["eval_loss"].append(ev)
            if ev < best_eval - 1e-12:
                best_eval, best_round = ev, t_idx
            if (config.early_stopping_rounds is not None
                    and t_idx - best_round >= config.early_stopping_rounds):
                if verbose:
                    print(f"[gbdt] early stop at tree {t_idx} "
                          f"(best {best_round}: {best_eval:.6f})")
                break
        step_times["other"] += time.perf_counter() - t2

        if verbose and (t_idx % config.log_every == 0
                        or t_idx == start + config.n_trees - 1):
            print(f"[gbdt] tree {t_idx:4d}  train_loss={train_loss:.6f}")
        # divergence sentinel: the host loop already syncs the loss each
        # round, so the finiteness check is free; the rollback machinery
        # lives in the fused/distributed engines — here the sentinel is
        # fail-fast-but-typed
        if recovery is not None and not np.isfinite(train_loss):
            raise NumericalDivergenceError(
                f"non-finite training loss at round {t_idx}",
                round_index=t_idx, what="loss")
        if callback is not None:
            callback(t_idx, _as_model(trees, base_margin, config,
                                      data.missing_bin, F))
        if shutdown is not None and shutdown.requested:
            partial = TrainResult(
                model=_as_model(trees, base_margin, config,
                                data.missing_bin, F),
                history=history, step_times=step_times,
                stats={"n_rows": n, "interrupted": True})
            raise TrainingInterrupted(
                f"shutdown ({shutdown.signal_name}) after round {t_idx}",
                rounds_done=len(trees), signal_name=shutdown.signal_name,
                result=partial)

    return TrainResult(model=_as_model(trees, base_margin, config,
                                       data.missing_bin, F),
                       history=history, step_times=step_times,
                       stats={"n_rows": n})


def _train_fused(config, plan, data, y, eval_set, trees, margins,
                 eval_margins, base_margin, history, step_times, key,
                 callback, verbose, n, F, recovery=None,
                 shutdown=None) -> TrainResult:
    """The device-resident boosting loop: one jitted dispatch per round.

    The host never synchronizes on per-round values unless it has to —
    losses stay device scalars, fetched every ``config.log_every`` rounds
    for verbose logging and once in bulk at the end.  Early stopping is
    the one per-round consumer: it pulls the eval scalar each round
    (still a single dispatch per round).  Per-step attribution is not
    possible inside a fused round, so wall time lands in a dedicated
    ``fused_rounds`` slot of ``step_times``.

    Divergence sentinel: every ``config.log_every`` rounds one device-side
    ``isfinite`` reduction over (loss, margins) is synced to the host.  A
    trip with a ``recovery`` policy rolls the fit back to the last finite
    sentinel snapshot and replays — at the ORIGINAL learning rate first
    (a transient glitch replays bit-equal), backing the rate off by
    ``recovery.divergence_backoff`` only when the same window diverges
    twice (``learning_rate`` is part of the step cache key, so the
    backoff recompiles the round).  Without a policy the sentinel raises
    :class:`NumericalDivergenceError` fail-fast.
    """
    live = config                      # LR backoff replaces this copy only
    n_eval = None if eval_set is None else int(eval_set[1].shape[0])
    step = _fused_round_step(_fused_step_key(live), plan, n, F,
                             data.n_bins, n_eval)
    y_ev = (jnp.asarray(eval_set[1], jnp.float32)
            if eval_set is not None else None)
    train_dev: List[jax.Array] = []
    eval_dev: List[jax.Array] = []
    best_eval, best_round = np.inf, -1
    rstats = {"divergence_rollbacks": 0}
    t_loop = time.perf_counter()
    start = len(trees)
    end = start + config.n_trees

    def _flush_history():
        # one bulk fetch materializes the whole loss trajectory
        history["train_loss"].extend(float(v)
                                     for v in jax.device_get(train_dev))
        if eval_set is not None:
            history["eval_loss"].extend(float(v)
                                        for v in jax.device_get(eval_dev))
        step_times["fused_rounds"] = time.perf_counter() - t_loop

    def _snap(t_next):
        """Host copy of the resumable loop state (taken only at finite
        sentinel checks, so a rollback always lands on finite state)."""
        return {"t": t_next, "trees": len(trees), "dev": len(train_dev),
                "margins": np.asarray(margins),
                "eval": (None if eval_margins is None
                         else np.asarray(eval_margins)),
                "best": (best_eval, best_round)}

    snap = _snap(start)
    diverged_at = -1                   # sentinel window of the last trip
    t_idx = start
    stop_early = False
    while t_idx < end and not stop_early:
        tkey = jax.random.fold_in(key, t_idx)   # same stream as host loop
        if eval_set is None:
            margins, tree, tl = step(margins, y, tkey, data.codes,
                                     data.codes_cm, data.is_categorical)
        else:
            margins, eval_margins, tree, tl, ev = step(
                margins, eval_margins, y, y_ev, tkey, data.codes,
                data.codes_cm, eval_set[0].codes, eval_set[0].codes_cm,
                data.is_categorical)
            eval_dev.append(ev)
        trees.append(tree)
        train_dev.append(tl)
        if eval_set is not None and config.early_stopping_rounds is not None:
            ev_f = float(ev)                    # the one per-round sync
            if ev_f < best_eval - 1e-12:
                best_eval, best_round = ev_f, t_idx
            if t_idx - best_round >= config.early_stopping_rounds:
                if verbose:
                    print(f"[gbdt] early stop at tree {t_idx} "
                          f"(best {best_round}: {best_eval:.6f})")
                stop_early = True
        if verbose and (t_idx % config.log_every == 0 or t_idx == end - 1):
            print(f"[gbdt] tree {t_idx:4d}  train_loss={float(tl):.6f}")

        # ---- divergence sentinel (one fused device reduction + sync)
        if t_idx % config.log_every == 0 or t_idx == end - 1 or stop_early:
            finite = bool(jnp.isfinite(tl) & jnp.all(jnp.isfinite(margins)))
            if not finite:
                if (recovery is None or rstats["divergence_rollbacks"]
                        >= recovery.max_divergence_rollbacks):
                    raise NumericalDivergenceError(
                        f"non-finite loss/margins at round {t_idx}",
                        round_index=t_idx, what="loss/margins")
                rstats["divergence_rollbacks"] += 1
                _metrics.record("recoveries")
                del trees[snap["trees"]:]
                del train_dev[snap["dev"]:]
                del eval_dev[snap["dev"]:]
                margins = jnp.asarray(snap["margins"])
                eval_margins = (None if snap["eval"] is None
                                else jnp.asarray(snap["eval"]))
                best_eval, best_round = snap["best"]
                if diverged_at == snap["t"]:
                    # the same window diverged on its replay: genuine
                    # divergence, not a glitch — shrink the steps
                    live = dataclasses.replace(
                        live, learning_rate=(live.learning_rate
                                             * recovery.divergence_backoff))
                    step = _fused_round_step(_fused_step_key(live), plan,
                                             n, F, data.n_bins, n_eval)
                    if verbose:
                        print(f"[gbdt] round {snap['t']} diverged twice; "
                              f"learning_rate -> {live.learning_rate:g}")
                elif verbose:
                    print(f"[gbdt] divergence at round {t_idx}; rolling "
                          f"back to round {snap['t']}")
                diverged_at = snap["t"]
                t_idx = snap["t"]
                stop_early = False
                continue
            snap = _snap(t_idx + 1)
        if callback is not None:
            callback(t_idx, _as_model(trees, base_margin, config,
                                      data.missing_bin, F))
        if shutdown is not None and shutdown.requested:
            _flush_history()
            partial = TrainResult(
                model=_as_model(trees, base_margin, config,
                                data.missing_bin, F),
                history=history, step_times=step_times,
                stats={"n_rows": n, "fused_rounds": True,
                       "interrupted": True, **rstats})
            raise TrainingInterrupted(
                f"shutdown ({shutdown.signal_name}) after round {t_idx}",
                rounds_done=len(trees), signal_name=shutdown.signal_name,
                result=partial)
        t_idx += 1
    _flush_history()
    jax.block_until_ready(margins)
    return TrainResult(model=_as_model(trees, base_margin, config,
                                       data.missing_bin, F),
                       history=history, step_times=step_times,
                       stats={"n_rows": n, "fused_rounds": True, **rstats})


def _as_model(trees, base_margin, config, missing_bin, F) -> GBDTModel:
    K = config.n_classes or 1
    stacked = _stack_forests(trees) if K > 1 else _stack_trees(trees)
    return GBDTModel(trees=stacked, base_margin=base_margin,
                     objective=config.objective,
                     missing_bin=missing_bin, n_fields=F,
                     max_depth=config.max_depth, n_classes=K)


def _predict_one_tree(tree: TreeArrays, data: BinnedDataset,
                      plan: ExecutionPlan) -> jax.Array:
    """Step-⑤ traversal, using the paper's renumbered-column fetch when it
    saves bandwidth: a depth-D tree touches ≤ 2^D − 1 columns, so for wide
    datasets only those columns are gathered from the column-major copy."""
    n_int = tree.feature.shape[0]
    F = data.n_fields
    if F > n_int:
        # per-node column fetch: node i's field becomes renumbered column i
        # (unpacks only the <= N_int gathered fields when codes_cm is
        # nibble-packed)
        cols = tree_mod._gather_fields(
            data.codes_cm, jnp.maximum(tree.feature, 0))          # (N_int, n)
        renum = jnp.where(tree.feature >= 0,
                          jnp.arange(n_int, dtype=jnp.int32), -1)
        tree_c = tree._replace(feature=renum)
        return ops.traverse_tree(tree_c, cols.T,
                                 missing_bin=data.missing_bin, plan=plan)
    return ops.traverse_tree(tree, data.codes, missing_bin=data.missing_bin,
                             plan=plan)


def _predict_forest(forest: TreeArrays, data: BinnedDataset,
                    plan: ExecutionPlan) -> jax.Array:
    """Step-⑤ traversal of one round's K per-class trees -> (n, K) deltas."""
    delta = jax.vmap(lambda t: _predict_one_tree(t, data, plan))(forest)
    return delta.T


def _replay_margins(model: GBDTModel, data: BinnedDataset,
                    plan: ExecutionPlan) -> jax.Array:
    """Seed margins for a continued fit by accumulating per-round deltas in
    round order — the SAME order the interrupted fit used — so checkpoint
    resume and warm start replay bit-exactly.  (A single batched
    ``predict_margin`` reduces the tree axis pairwise, which can differ
    from sequential accumulation in the last ulp and would perturb every
    downstream leaf value.)"""
    n = data.codes.shape[0]
    K = model.n_classes
    if K > 1:
        m = jnp.broadcast_to(
            jnp.asarray(model.base_margin, jnp.float32), (n, K))
        for r in range(model.n_rounds):
            forest = TreeArrays(*[a[r * K:(r + 1) * K]
                                  for a in model.trees])
            m = m + _predict_forest(forest, data, plan)
        return m
    m = jnp.full((n,), model.base_margin, jnp.float32)
    for t in range(model.n_trees):
        tree = TreeArrays(*[a[t] for a in model.trees])
        m = m + _predict_one_tree(tree, data, plan)
    return m


# --------------------------------------------------------------------------
# out-of-core training: chunk-streamed histograms, GOSS, sketch binning
# --------------------------------------------------------------------------
def _streamed_margins(model: GBDTModel, chunks, n: int,
                      plan: ExecutionPlan) -> jax.Array:
    """Warm-start margins without materializing the matrix: one chunked
    inference pass, accumulating per-round deltas in round order (the same
    element-wise addition order the interrupted fit used) so checkpoint
    resume replays bit-exactly — see :func:`_replay_margins`."""
    K = model.n_classes
    out = np.zeros((n, K) if K > 1 else (n,), np.float32)
    for lo, hi, codes in chunks():
        rows = codes.n if hasattr(codes, "n") else codes.shape[0]
        if K > 1:
            m = jnp.broadcast_to(
                jnp.asarray(model.base_margin, jnp.float32), (rows, K))
            for r in range(model.n_rounds):
                forest = TreeArrays(*[a[r * K:(r + 1) * K]
                                      for a in model.trees])
                delta = jax.vmap(lambda t: ops.traverse_tree(
                    t, codes, missing_bin=model.missing_bin,
                    plan=plan))(forest)
                m = m + delta.T
        else:
            m = jnp.full((rows,), model.base_margin, jnp.float32)
            for t_i in range(model.n_trees):
                tree = TreeArrays(*[a[t_i] for a in model.trees])
                m = m + ops.traverse_tree(tree, codes,
                                          missing_bin=model.missing_bin,
                                          plan=plan)
        out[lo:hi] = np.asarray(m)[: hi - lo]
    return jnp.asarray(out)


def train_streaming(config: GBDTConfig, source, binner, y, *,
                    eval_set: Optional[Tuple[BinnedDataset, jax.Array]] = None,
                    init_model: Optional[GBDTModel] = None,
                    callback: Optional[Callable[[int, GBDTModel], None]] = None,
                    verbose: bool = False,
                    plan: Optional[ExecutionPlan] = None,
                    chunk_rows: Optional[int] = None,
                    recovery: Optional[RecoveryPolicy] = None,
                    shutdown: Optional[GracefulShutdown] = None
                    ) -> TrainResult:
    """Out-of-core twin of :func:`train`: the binned matrix is NEVER
    materialized — each tree level re-streams device-sized chunks from
    ``source``, accumulating step-① histograms chunk by chunk and keeping
    step-③ node-id vectors chunk-local (``tree.fit_forest_chunked``).
    Host-resident state is per-record scalars only (margins, g/h, node
    ids); device-resident state is one chunk plus the level histogram.

    source:      a :class:`repro.data.DataSource` of raw float chunks;
                 successive passes must yield identical chunks.
    binner:      a fitted ``Binner``/``StreamingBinner`` (chunks are binned
                 on the fly each pass).
    y:           (n,) labels, gathered from the source by the caller.
    eval_set:    optional in-memory ``(BinnedDataset, y_val)`` pair.
    chunk_rows:  records per streamed chunk; defaults to the plan's
                 ``chunk_bytes`` budget (``ExecutionPlan.chunk_rows``).
    recovery:    a :class:`repro.resilience.RecoveryPolicy` enabling
                 self-healing rounds: a transient source failure replays
                 the round (from the newest ``checkpoint_dir`` checkpoint
                 when one exists, else from the in-memory end-of-previous
                 -round state), and a device OOM halves the chunk size
                 and retries — chunked histogram accumulation is
                 chunk-size-invariant, so degradation never changes the
                 model.  Rounds commit state atomically (margins, trees,
                 history all mutate only after the round's compute
                 succeeds), and the per-round RNG is keyed by
                 ``(seed, round)``, so replayed rounds reproduce the
                 fault-free fit.  ``None`` (default) = fail fast.
    shutdown:    a :class:`repro.resilience.GracefulShutdown`; a delivered
                 signal finishes the in-flight round, commits it (plus a
                 final checkpoint when ``recovery.checkpoint_dir`` is
                 set), and raises :class:`TrainingInterrupted` carrying
                 the partial result — ``fit`` resumes from it.

    Per-round data passes: ``max_depth + 1`` (one per level — the previous
    level's partition is applied lazily in the histogram pass — plus one
    final partition pass).  Step ⑤ is free: margins update from the final
    leaf-slot ids, no traversal of the stream.

    GOSS (``config.goss_top_rate`` / ``goss_other_rate``) drops the
    zero-weight record stream from the histogram *stat* volume each round
    while node ids stay maintained for every record, so margins (and the
    next round's gradients) remain exact.

    ``config.fused_rounds`` is ignored here: every round is a host-driven
    chunk pipeline by construction.  ``plan.hist_subtraction`` applies —
    levels > 0 accumulate only smaller-child statistics per chunk and
    derive the sibling histograms once per level.
    """
    if plan is None:
        plan = ExecutionPlan.from_config(config)
    plan = plan.resolved()
    kernel_plan = plan.without_chunking()
    if config.grow_policy != "depthwise":
        raise ValueError("streaming training supports only the depthwise "
                         "grow_policy")
    loss = losses_mod.get_loss(config.objective, config.n_classes)
    K = loss.n_outputs
    y = jnp.asarray(y, jnp.float32)
    if K is not None:
        _validate_multiclass_labels(
            K, y, eval_set[1] if eval_set is not None else None)
    n = int(y.shape[0])
    F = int(source.n_fields)
    depth = config.max_depth
    # resolve the packed-codes layout BEFORE sizing chunks: 4-bit packing
    # halves the per-row code bytes, so the same chunk_bytes budget fits
    # ~2x the records per streamed chunk (paper §III-B)
    if plan.packed_codes is None:
        plan = plan.replace(
            packed_codes=binner.max_bins <= binning_mod.PACK_MAX_BINS)
        kernel_plan = plan.without_chunking()
    elif plan.packed_codes and binner.max_bins > binning_mod.PACK_MAX_BINS:
        raise ValueError(
            f"plan requests 4-bit packed codes but the binner has "
            f"max_bins={binner.max_bins} > {binning_mod.PACK_MAX_BINS}")
    packed = bool(plan.packed_codes)
    if chunk_rows is None:
        chunk_rows = plan.chunk_rows(F, K or 1)
    # never pad past the data: a small dataset under a large byte budget
    # would otherwise stream (and histogram) mostly padding every pass
    chunk_rows = max(1, min(int(chunk_rows), n))
    # mutable so OOM degradation can shrink the streamed chunks mid-fit;
    # each pass reads the cell once at open, so a resize takes effect on
    # the retried round's first pass
    chunk_state = {"rows": chunk_rows}
    missing_bin = binner.max_bins - 1
    is_cat_field = jnp.asarray(binner._is_cat)
    n_chunks = [0]

    def binned_chunks():
        """One full pass: bin + pad (+ 4-bit pack) each raw chunk on the
        host (prefetch thread overlaps binning/transfer with device
        compute), yield ``(lo, hi, codes)`` with a fixed (chunk_rows, F)
        logical device shape — ``codes`` is a :class:`PackedCodes` when
        the plan packs, so each chunk DMAs half the code bytes."""
        from repro.data.pipeline import PrefetchIterator
        rows_now = chunk_state["rows"]

        def gen():
            for X_chunk, _ in source.chunks(rows_now):
                codes = binner.transform_codes(X_chunk)
                n_real = codes.shape[0]
                if n_real > rows_now:
                    raise ValueError(
                        f"source yielded a {n_real}-row chunk for a "
                        f"{rows_now}-row request")
                if n_real < rows_now:
                    codes = np.pad(codes,
                                   ((0, rows_now - n_real), (0, 0)))
                if packed:
                    codes = binning_mod.pack_nibbles_np(codes)
                yield {"rows": np.int32(n_real), "codes": codes}

        lo = 0
        count = 0
        with PrefetchIterator(gen(), depth=2) as batches:
            for batch in batches:
                n_real = int(batch["rows"])
                codes = (binning_mod.PackedCodes(batch["codes"], F)
                         if packed else batch["codes"])
                yield lo, lo + n_real, codes
                lo += n_real
                count += 1
        if lo != n:
            raise ValueError(
                f"source pass yielded {lo} rows but len(y) == {n}; "
                "DataSource passes must be identical and label-complete")
        n_chunks[0] = count

    trees: List[TreeArrays] = []
    history: Dict[str, List[float]] = {"train_loss": []}
    if eval_set is not None:
        history["eval_loss"] = []
    step_times = {"binning_split": 0.0, "partition": 0.0, "traversal": 0.0,
                  "other": 0.0}

    if init_model is not None:
        if K is not None:
            trees = _unstack_forests(init_model.trees, init_model.n_rounds,
                                     K)
        else:
            trees = [TreeArrays(*[a[i] for a in init_model.trees])
                     for i in range(init_model.n_trees)]
        base_margin = init_model.base_margin
        margins = _streamed_margins(init_model, binned_chunks, n,
                                    kernel_plan)
        eval_margins = (init_model.predict_margin(eval_set[0].codes,
                                                  plan=kernel_plan)
                        if eval_set is not None else None)
    elif K is not None:
        base_margin = np.asarray(loss.base_margin(y), np.float32)
        margins = jnp.broadcast_to(jnp.asarray(base_margin), (n, K))
        eval_margins = (jnp.broadcast_to(jnp.asarray(base_margin),
                                         (eval_set[1].shape[0], K))
                        if eval_set is not None else None)
    else:
        base_margin = float(loss.base_margin(y))
        margins = jnp.full((n,), base_margin, jnp.float32)
        eval_margins = (jnp.full((eval_set[1].shape[0],), base_margin)
                        if eval_set is not None else None)

    key = jax.random.PRNGKey(config.seed)
    best_eval, best_round = np.inf, -1

    start = len(trees)
    end = start + config.n_trees
    rstats = {"recoveries": 0, "oom_halvings": 0, "replayed_rounds": 0}
    pending_restore = False

    def _save_round_checkpoint(rounds_done: int) -> None:
        # lazy import: repro.api depends on this module
        from repro.api import serialize
        from repro.core.inference import GBDTPipeline
        model = _as_model(trees, base_margin, config, missing_bin, F)
        serialize.save_checkpoint(recovery.checkpoint_dir,
                                  GBDTPipeline(binner=binner, model=model),
                                  rounds_done)

    def _restore_state():
        """Trainer state from the newest valid checkpoint: trees unstacked
        from the bundled model, margins recomputed with one streamed
        inference pass (so no per-record state needs checkpointing)."""
        from repro.api import serialize
        pipe, _step = serialize.load_checkpoint(recovery.checkpoint_dir)
        model = pipe.model
        if K is not None:
            rtrees = _unstack_forests(model.trees, model.n_rounds, K)
        else:
            rtrees = [TreeArrays(*[a[i] for a in model.trees])
                      for i in range(model.n_trees)]
        rmargins = _streamed_margins(model, binned_chunks, n, kernel_plan)
        rev = (model.predict_margin(eval_set[0].codes, plan=kernel_plan)
               if eval_set is not None else None)
        return rtrees, rmargins, rev, len(rtrees)

    def _stats():
        return {"n_rows": n, "chunk_rows": int(chunk_state["rows"]),
                "n_chunks": int(n_chunks[0]),
                "passes_per_round": depth + 1, **rstats}

    t_idx = t_done = start
    try:
        while t_idx < end:
            try:
                if pending_restore:
                    trees, margins, eval_margins, t_idx = _restore_state()
                    rstats["replayed_rounds"] += max(0, t_done - t_idx)
                    del history["train_loss"][t_idx - start:]
                    if eval_set is not None:
                        del history["eval_loss"][t_idx - start:]
                        evs = history["eval_loss"]
                        best_eval = min(evs) if evs else np.inf
                        best_round = (start + int(np.argmin(evs))) if evs \
                            else -1
                    pending_restore = False

                tkey = jax.random.fold_in(key, t_idx)
                t0 = time.perf_counter()
                g, h = loss.grad_hess(margins, y)
                g, h, field_mask = _round_stats(config, tkey, g, h, n, F, K)
                g2 = np.asarray(g.T if K is not None else g[None],
                                np.float32)
                h2 = np.asarray(h.T if K is not None else h[None],
                                np.float32)

                forest, leaf_ids = tree_mod.fit_forest_chunked(
                    binned_chunks, g2, h2, depth=depth,
                    n_bins=binner.max_bins, missing_bin=missing_bin,
                    is_cat_field=is_cat_field, field_mask=field_mask,
                    lambda_=config.lambda_, gamma=config.gamma,
                    min_child_weight=config.min_child_weight,
                    plan=kernel_plan)
                forest = forest._replace(
                    leaf_value=forest.leaf_value * config.learning_rate)
                forest = jax.tree.map(jax.block_until_ready, forest)
                t1 = time.perf_counter()

                # step ⑤ for free: the chunk-local node ids END as leaf
                # slots, so the margin refresh is a leaf-value lookup,
                # not a data pass
                delta = jax.vmap(lambda v, i: v[i])(
                    forest.leaf_value, jnp.asarray(leaf_ids))       # (K, n)
                tree = forest if K is not None else TreeArrays(
                    *[a[0] for a in forest])
                new_margins = margins + (delta.T if K is not None
                                         else delta[0])
                new_margins.block_until_ready()
                t2 = time.perf_counter()

                if eval_set is not None:
                    if K is not None:
                        ev_delta = _predict_forest(tree, eval_set[0],
                                                   kernel_plan)
                    else:
                        ev_delta = _predict_one_tree(tree, eval_set[0],
                                                     kernel_plan)
                    new_eval_margins = eval_margins + ev_delta
                    ev = float(jnp.mean(loss.value(
                        new_eval_margins,
                        jnp.asarray(eval_set[1], jnp.float32))))
                else:
                    new_eval_margins, ev = None, None
            except Exception as exc:  # noqa: BLE001 — classified below
                action = classify(exc) if recovery is not None else "fatal"
                if action == "oom":
                    rows = chunk_state["rows"]
                    new_rows = max(recovery.min_chunk_rows, rows // 2)
                    if (new_rows >= rows or rstats["oom_halvings"]
                            >= recovery.max_oom_halvings):
                        raise
                    rstats["oom_halvings"] += 1
                    _metrics.record("recoveries")
                    chunk_state["rows"] = new_rows
                    if verbose:
                        print(f"[gbdt] device OOM at tree {t_idx}: "
                              f"chunk_rows {rows} -> {new_rows}; "
                              "retrying round")
                    continue
                if action == "transient":
                    if rstats["recoveries"] >= recovery.max_recoveries:
                        raise
                    rstats["recoveries"] += 1
                    _metrics.record("recoveries")
                    if recovery.retry_delay_s:
                        time.sleep(recovery.retry_delay_s)
                    if recovery.checkpoint_dir is not None:
                        from repro.api import serialize
                        pending_restore = serialize.has_checkpoint(
                            recovery.checkpoint_dir)
                    if verbose:
                        how = ("restoring newest checkpoint"
                               if pending_restore
                               else "replaying round in memory")
                        print(f"[gbdt] transient failure at tree {t_idx} "
                              f"({type(exc).__name__}: {exc}); {how}")
                    continue
                raise

            # ---- commit: the round succeeded, mutate state atomically
            step_times["binning_split"] += t1 - t0
            step_times["traversal"] += t2 - t1
            margins = new_margins
            trees.append(tree)
            train_loss = float(jnp.mean(loss.value(margins, y)))
            history["train_loss"].append(train_loss)
            stop_early = False

            if eval_set is not None:
                eval_margins = new_eval_margins
                history["eval_loss"].append(ev)
                if ev < best_eval - 1e-12:
                    best_eval, best_round = ev, t_idx
                if (config.early_stopping_rounds is not None
                        and t_idx - best_round
                        >= config.early_stopping_rounds):
                    if verbose:
                        print(f"[gbdt] early stop at tree {t_idx} "
                              f"(best {best_round}: {best_eval:.6f})")
                    stop_early = True
            step_times["other"] += time.perf_counter() - t2

            if verbose and (t_idx % config.log_every == 0
                            or t_idx == end - 1):
                print(f"[gbdt] tree {t_idx:4d}  "
                      f"train_loss={train_loss:.6f}  "
                      f"({n_chunks[0]} chunks x {chunk_state['rows']} rows)")
            t_done = t_idx + 1
            if (recovery is not None and recovery.checkpoint_dir is not None
                    and (t_done - start) % recovery.checkpoint_every == 0):
                _save_round_checkpoint(t_done)
            if callback is not None:
                callback(t_idx, _as_model(trees, base_margin, config,
                                          missing_bin, F))
            t_idx = t_done
            if shutdown is not None and shutdown.requested:
                # the in-flight round is committed; persist the exact
                # resumable state, then exit with a typed status
                if (recovery is not None
                        and recovery.checkpoint_dir is not None
                        and (t_done - start) % recovery.checkpoint_every):
                    _save_round_checkpoint(t_done)
                partial = TrainResult(
                    model=_as_model(trees, base_margin, config,
                                    missing_bin, F),
                    history=history, step_times=step_times,
                    stats={**_stats(), "interrupted": True})
                raise TrainingInterrupted(
                    f"shutdown ({shutdown.signal_name}) after round "
                    f"{t_done - 1}", rounds_done=len(trees),
                    signal_name=shutdown.signal_name,
                    checkpoint_dir=(recovery.checkpoint_dir
                                    if recovery is not None else None),
                    result=partial)
            if stop_early:
                break

        return TrainResult(
            model=_as_model(trees, base_margin, config, missing_bin, F),
            history=history, step_times=step_times, stats=_stats())
    finally:
        # parity with PrefetchIterator: a fit never leaks the retry
        # wrapper's watchdog thread or its open shard handles, no matter
        # how it exits
        if isinstance(source, RetryingSource):
            source.close()

"""Step ② — evaluating histogram bins to pick split points.

Paper §II-A/III-B: this step is short (O(bins), not O(records)), uses
"hardware-unfriendly" formulae that vary across implementations, and is
therefore *offloaded to the host* by Booster.  We keep both paths:

  * ``find_best_splits``     — fused jnp reduction (default; a TPU handles
                               the argmax fine and avoids a device→host trip)
  * ``find_best_splits_host`` — numpy twin, invoked through
                               ``jax.pure_callback`` so the step literally
                               runs on the host CPU even under jit on TPU,
                               reproducing the paper's offload.

Split semantics (paper Fig 3 + missing-value handling):
  numeric field f, bin t:  "code <= t" goes left;
  categorical field f, category c: "code == c" goes left (one-vs-rest — the
      collapsed form of the paper's one-hot features);
  the missing bin is tried on BOTH sides ("GB considers placing records with
      missing fields in both the left and the right sub-trees") — the better
      direction is stored as ``default_left``.

gain = 1/2 [ GL²/(HL+λ) + GR²/(HR+λ) − Gp²/(Hp+λ) ] − γ   (XGBoost eq. 7)
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_NEG = -jnp.inf


class SplitDecision(NamedTuple):
    gain: jax.Array          # (NN,) float32; <= 0 means "do not split"
    feature: jax.Array       # (NN,) int32 global field id
    threshold: jax.Array     # (NN,) int32 bin code (numeric: <=, cat: ==)
    is_cat: jax.Array        # (NN,) int32
    default_left: jax.Array  # (NN,) int32 missing direction
    node_g: jax.Array        # (NN,) float32 parent G (for leaf weights)
    node_h: jax.Array        # (NN,) float32 parent H
    left_h: jax.Array        # (NN,) float32 hessian mass routed LEFT by the
    #                          chosen split (incl. the missing bin when
    #                          default_left) — the "counts channel" the
    #                          subtraction growers use to pick the smaller
    #                          child without a device→host trip (h ≡ 1 for
    #                          squared error, so this IS the record count)


def leaf_weight(G, H, lambda_):
    return -G / (H + lambda_)


@functools.partial(jax.jit, static_argnames=())
def find_best_splits(hist, is_cat_field, field_mask, lambda_, gamma,
                     min_child_weight) -> SplitDecision:
    """hist: (NN, F, NB, 2); last bin of every field is the missing bin.

    field_mask: (F,) bool — colsample / field-availability mask.
    Vectorized over nodes, fields and candidate bins; per-candidate the
    better missing-direction is chosen, then argmax over bins then fields.
    """
    NN, F, NB, _ = hist.shape
    G = hist[..., 0].sum(-1)                               # (NN, F)
    H = hist[..., 1].sum(-1)
    # Every record carries every field exactly once (the density property
    # behind group-by-field), so per-field totals are identical: field 0
    # supplies the parent statistics.
    Gp, Hp = G[:, 0], H[:, 0]                              # (NN,)
    Gm = hist[:, :, NB - 1, 0]                             # (NN, F) missing
    Hm = hist[:, :, NB - 1, 1]
    v = hist[:, :, : NB - 1, :]                            # value bins
    parent_score = (Gp ** 2 / (Hp + lambda_))[:, None, None]

    def gain_of(GL, HL):
        GR = Gp[:, None, None] - GL
        HR = Hp[:, None, None] - HL
        ok = (HL >= min_child_weight) & (HR >= min_child_weight)
        gain = 0.5 * (GL ** 2 / (HL + lambda_) + GR ** 2 / (HR + lambda_)
                      - parent_score) - gamma
        return jnp.where(ok, gain, _NEG)

    cumG = jnp.cumsum(v[..., 0], axis=-1)                  # (NN, F, NB-1)
    cumH = jnp.cumsum(v[..., 1], axis=-1)
    num_dr = gain_of(cumG, cumH)                           # missing -> right
    num_dl = gain_of(cumG + Gm[..., None], cumH + Hm[..., None])
    cat_dr = gain_of(v[..., 0], v[..., 1])
    cat_dl = gain_of(v[..., 0] + Gm[..., None], v[..., 1] + Hm[..., None])

    cat_f = is_cat_field[None, :, None]
    cand_dr = jnp.where(cat_f, cat_dr, num_dr)
    cand_dl = jnp.where(cat_f, cat_dl, num_dl)
    go_dl = cand_dl > cand_dr
    cand = jnp.maximum(cand_dl, cand_dr)                   # (NN, F, NB-1)
    cand = jnp.where(field_mask[None, :, None], cand, _NEG)

    # hessian routed left per candidate (counts channel): cumulative for
    # numeric, single-bin for categorical, + the missing mass when the
    # chosen direction sends missing records left
    HL = jnp.where(cat_f, v[..., 1], cumH)                 # (NN, F, NB-1)
    HL = HL + jnp.where(go_dl, Hm[..., None], 0.0)

    t_best = jnp.argmax(cand, axis=-1)                     # (NN, F)
    gain_f = jnp.take_along_axis(cand, t_best[..., None], -1)[..., 0]
    dl_f = jnp.take_along_axis(go_dl, t_best[..., None], -1)[..., 0]
    hl_f = jnp.take_along_axis(HL, t_best[..., None], -1)[..., 0]
    f_best = jnp.argmax(gain_f, axis=-1)                   # (NN,)
    gain = jnp.take_along_axis(gain_f, f_best[:, None], 1)[:, 0]
    thr = jnp.take_along_axis(t_best, f_best[:, None], 1)[:, 0]
    dl = jnp.take_along_axis(dl_f, f_best[:, None], 1)[:, 0]
    hl = jnp.take_along_axis(hl_f, f_best[:, None], 1)[:, 0]
    gain = jnp.where(jnp.isfinite(gain), gain, jnp.float32(-1.0))
    return SplitDecision(
        gain=gain.astype(jnp.float32),
        feature=f_best.astype(jnp.int32),
        threshold=thr.astype(jnp.int32),
        is_cat=is_cat_field[f_best].astype(jnp.int32),
        default_left=dl.astype(jnp.int32),
        node_g=Gp.astype(jnp.float32),
        node_h=Hp.astype(jnp.float32),
        left_h=hl.astype(jnp.float32),
    )


# --------------------------------------------------------------------------
# host-offloaded twin (paper's step-② offload, via pure_callback)
# --------------------------------------------------------------------------
def _np_best_splits(hist, is_cat_field, field_mask, lambda_, gamma,
                    min_child_weight):
    NN, F, NB, _ = hist.shape
    G = hist[..., 0].sum(-1)
    H = hist[..., 1].sum(-1)
    Gp, Hp = G[:, 0], H[:, 0]
    Gm, Hm = hist[:, :, NB - 1, 0], hist[:, :, NB - 1, 1]
    v = hist[:, :, : NB - 1, :]
    parent = (Gp ** 2 / (Hp + lambda_))[:, None, None]

    def gain_of(GL, HL):
        GR, HR = Gp[:, None, None] - GL, Hp[:, None, None] - HL
        ok = (HL >= min_child_weight) & (HR >= min_child_weight)
        with np.errstate(divide="ignore", invalid="ignore"):
            gn = 0.5 * (GL ** 2 / (HL + lambda_) + GR ** 2 / (HR + lambda_)
                        - parent) - gamma
        return np.where(ok, gn, -np.inf)

    cumG, cumH = np.cumsum(v[..., 0], -1), np.cumsum(v[..., 1], -1)
    num_dr, num_dl = gain_of(cumG, cumH), gain_of(cumG + Gm[..., None],
                                                  cumH + Hm[..., None])
    cat_dr, cat_dl = gain_of(v[..., 0], v[..., 1]), gain_of(
        v[..., 0] + Gm[..., None], v[..., 1] + Hm[..., None])
    catf = is_cat_field[None, :, None]
    cand_dr = np.where(catf, cat_dr, num_dr)
    cand_dl = np.where(catf, cat_dl, num_dl)
    go_dl = cand_dl > cand_dr
    cand = np.where(field_mask[None, :, None],
                    np.maximum(cand_dl, cand_dr), -np.inf)
    HL = np.where(catf, v[..., 1], cumH) + np.where(go_dl, Hm[..., None],
                                                    0.0)
    t_best = np.argmax(cand, -1)
    gain_f = np.take_along_axis(cand, t_best[..., None], -1)[..., 0]
    dl_f = np.take_along_axis(go_dl, t_best[..., None], -1)[..., 0]
    hl_f = np.take_along_axis(HL, t_best[..., None], -1)[..., 0]
    f_best = np.argmax(gain_f, -1)
    gain = np.take_along_axis(gain_f, f_best[:, None], 1)[:, 0]
    thr = np.take_along_axis(t_best, f_best[:, None], 1)[:, 0]
    dl = np.take_along_axis(dl_f, f_best[:, None], 1)[:, 0]
    hl = np.take_along_axis(hl_f, f_best[:, None], 1)[:, 0]
    gain = np.where(np.isfinite(gain), gain, -1.0)
    return (gain.astype(np.float32), f_best.astype(np.int32),
            thr.astype(np.int32), is_cat_field[f_best].astype(np.int32),
            dl.astype(np.int32), Gp.astype(np.float32), Hp.astype(np.float32),
            hl.astype(np.float32))


def find_best_splits_host(hist, is_cat_field, field_mask, lambda_, gamma,
                          min_child_weight) -> SplitDecision:
    """Step ② on the host CPU via pure_callback (paper's offload path)."""
    NN = hist.shape[0]
    shapes = (
        jax.ShapeDtypeStruct((NN,), jnp.float32),
        jax.ShapeDtypeStruct((NN,), jnp.int32),
        jax.ShapeDtypeStruct((NN,), jnp.int32),
        jax.ShapeDtypeStruct((NN,), jnp.int32),
        jax.ShapeDtypeStruct((NN,), jnp.int32),
        jax.ShapeDtypeStruct((NN,), jnp.float32),
        jax.ShapeDtypeStruct((NN,), jnp.float32),
        jax.ShapeDtypeStruct((NN,), jnp.float32),
    )

    def cb(h, c, m, lam, gam, mcw):
        return _np_best_splits(np.asarray(h), np.asarray(c), np.asarray(m),
                               float(lam), float(gam), float(mcw))

    out = jax.pure_callback(
        cb, shapes, hist, is_cat_field, field_mask,
        jnp.asarray(lambda_, jnp.float32), jnp.asarray(gamma, jnp.float32),
        jnp.asarray(min_child_weight, jnp.float32))
    return SplitDecision(*out)

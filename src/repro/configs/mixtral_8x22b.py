"""mixtral-8x22b — sparse MoE with sliding-window attention.

[arXiv:2401.04088; hf]  56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, 8 experts top-2, SWA (window 4096 per assignment note).
SWA is sub-quadratic -> long_500k RUNS (KV cache bounded by the window).
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab=32768, head_dim=128,
    sliding_window=4096, rope_theta=1e6,
    n_experts=8, top_k=2,
    param_dtype="bfloat16", fsdp=True,
    sub_quadratic=True,
    source="arXiv:2401.04088; 8 experts/layer top-2; SWA per assignment",
)

SMOKE = ArchConfig(
    name="mixtral-8x22b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, sliding_window=32, n_experts=4, top_k=2,
    moe_capacity_factor=8.0,
    param_dtype="float32", compute_dtype="float32", sub_quadratic=True,
)

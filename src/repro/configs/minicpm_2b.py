"""minicpm-2b — dense llama-like with the WSD LR schedule.

[arXiv:2404.06395; hf]  40L d_model=2304 36H (kv=36: MHA) d_ff=5760
vocab=122753; head_dim=64.  WSD (warmup-stable-decay) schedule is a
trainer feature (see repro.models.optim.wsd_schedule).  Full attention ->
long_500k skipped.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, head_dim=64,
    lr_schedule="wsd",
    source="arXiv:2404.06395 (MiniCPM); llama-like, MHA (kv=36)",
)

SMOKE = ArchConfig(
    name="minicpm-2b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=256,
    head_dim=16, lr_schedule="wsd",
    param_dtype="float32", compute_dtype="float32",
)

"""deepseek-67b — deep dense llama-arch (95 layers).

[arXiv:2401.02954; hf]  95L d_model=8192 64H (GQA kv=8) d_ff=22016
vocab=102400; head_dim=128.  Full attention -> long_500k skipped.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b", family="dense",
    n_layers=95, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22016,
    vocab=102400, head_dim=128,
    param_dtype="bfloat16", fsdp=True,
    source="arXiv:2401.02954 (DeepSeek LLM 67B); llama arch, deepest cell",
)

SMOKE = ArchConfig(
    name="deepseek-67b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, param_dtype="float32", compute_dtype="float32",
)

"""command-r-35b — dense GQA, no biases, 256k vocab.

[hf:CohereForAI/c4ai-command-r-v01; unverified]  40L d_model=8192 64H
(GQA kv=8) d_ff=22528 vocab=256000; head_dim=128, no attention/MLP bias.
Full attention -> long_500k skipped.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="command-r-35b", family="dense",
    n_layers=40, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=22528,
    vocab=256000, head_dim=128,
    rope_theta=8e6, attn_bias=False,
    param_dtype="bfloat16", fsdp=True,
    source="hf:CohereForAI/c4ai-command-r-v01; sequential-block variant "
           "of Cohere's parallel block (noted in DESIGN.md)",
)

SMOKE = ArchConfig(
    name="command-r-35b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, param_dtype="float32", compute_dtype="float32",
)

from repro.configs.registry import (ARCH_IDS, SHAPES, ArchConfig, ShapeConfig,
                                    all_cells, cell_is_runnable, get_arch,
                                    get_smoke)

"""qwen3-14b — dense GQA with qk-norm.

[hf:Qwen/Qwen3-14B; hf]  40L d_model=5120 40H (GQA kv=8) d_ff=17408
vocab=151936; per-head RMSNorm on q and k (qk_norm), no attn bias.
Full attention -> long_500k skipped.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-14b", family="dense",
    n_layers=40, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=17408,
    vocab=151936, head_dim=128,
    qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-14B; qk_norm per-head RMSNorm",
)

SMOKE = ArchConfig(
    name="qwen3-14b-smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, qk_norm=True,
    param_dtype="float32", compute_dtype="float32",
)

"""mamba2-370m — attention-free SSM (SSD / state-space duality).

[arXiv:2405.21060; unverified]  48L d_model=1024 d_ff=0 vocab=50280
ssm_state=128; expand=2 -> d_inner=2048, 32 heads of head_dim 64.
O(S) scan -> long_500k RUNS (decode state is O(1) per token).
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, head_dim=0,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    sub_quadratic=True,
    source="arXiv:2405.21060 (Mamba-2); mixer-only blocks (d_ff=0)",
)

SMOKE = ArchConfig(
    name="mamba2-370m-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0, d_ff=0, vocab=256,
    head_dim=0, ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4,
    ssm_chunk=8, param_dtype="float32", compute_dtype="float32",
    sub_quadratic=True,
)

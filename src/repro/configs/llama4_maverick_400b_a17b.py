"""llama4-maverick-400b-a17b — 128-expert top-1 MoE with early fusion.

[hf:meta-llama/Llama-4-*; unverified]  48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048; MoE 128e top-1 on alternating layers (interleave=2,
matching the a17b active-parameter budget) + shared expert; early-fusion
multimodality is a token-stub.  Full attention in the assigned config ->
long_500k skipped.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=8192,
    vocab=202048, head_dim=128, rope_theta=5e5,
    n_experts=128, top_k=1, moe_every=2, moe_offset=1, shared_expert=True,
    param_dtype="bfloat16", fsdp=True,
    source="hf Llama-4 family; MoE every other layer + shared expert "
           "(a17b active budget); qk_norm off per Maverick",
)

SMOKE = ArchConfig(
    name="llama4-maverick-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, n_experts=4, top_k=1, moe_every=2, moe_offset=1,
    moe_capacity_factor=8.0,
    shared_expert=True, param_dtype="float32", compute_dtype="float32",
)

"""qwen2-vl-72b — dense VLM backbone with M-RoPE.

[arXiv:2409.12191; hf]  80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064.  Vision frontend is a STUB: input_specs() supplies
precomputed patch embeddings merged into the token stream; M-RoPE uses
3-section (temporal, h, w) position ids.  Full attention -> long_500k skip.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, head_dim=128,
    mrope=True, rope_theta=1e6, attn_bias=True,
    param_dtype="bfloat16", fsdp=True,
    source="hf:Qwen/Qwen2-VL-72B-Instruct; qkv bias per Qwen2; "
           "M-RoPE sections (16,24,24) over head_dim/2=64",
)

SMOKE = ArchConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, mrope=True, attn_bias=True,
    param_dtype="float32", compute_dtype="float32",
)

"""whisper-large-v3 — enc-dec audio transformer backbone.

[arXiv:2212.04356; unverified]  32L d_model=1280 20H (kv=20) d_ff=5120
vocab=51866.  Conv/mel frontend is a STUB: input_specs() supplies 1500
precomputed frame embeddings (B, 1500, d_model).  Full attention (enc
non-causal, dec causal + cross) -> long_500k skipped.
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3", family="encdec",
    n_layers=32, encoder_layers=32, d_model=1280, n_heads=20, n_kv_heads=20,
    d_ff=5120, vocab=51866, head_dim=64,
    act="gelu", rope=False, attn_bias=True,
    frontend="audio", frontend_len=1500,
    sub_quadratic=False,
    source="arXiv:2212.04356 (Whisper); head_dim=1280/20=64; GELU MLP; "
           "sinusoidal positions stand in for Whisper's learned embeddings",
)

SMOKE = ArchConfig(
    name="whisper-large-v3-smoke", family="encdec",
    n_layers=2, encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab=256, head_dim=16,
    act="gelu", rope=False, attn_bias=True,
    frontend="audio", frontend_len=12,
    param_dtype="float32", compute_dtype="float32",
)

"""Architecture registry: assigned configs, smoke variants, input shapes.

Every architecture from the assignment is a first-class ``--arch <id>``
config.  ``smoke()`` returns a reduced same-family variant for CPU tests;
the full config is only ever lowered abstractly (dry-run).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 128
    # attention flavor
    qk_norm: bool = False
    attn_bias: bool = False
    sliding_window: Optional[int] = None
    rope: bool = True
    rope_theta: float = 1e4
    mrope: bool = False
    attn_chunk: int = 0          # >0: flash-style chunked attention
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # MoE where i % moe_every == moe_offset
    moe_offset: int = 0
    moe_d_ff: int = 0            # 0 -> d_ff
    shared_expert: bool = False
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.0  # >0: Switch-style load-balance aux loss
    moe_ff_fsdp: bool = False    # TP-MoE: shard expert ff over data x model
                                 # (keeps the contracted d dim unsharded)
    # SSM / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    attn_period: int = 0         # hybrid: attention where i % period == offset
    attn_period_offset: int = 0
    # enc-dec / multimodal frontends (stubs provide embeddings)
    encoder_layers: int = 0
    frontend: Optional[str] = None   # "audio" | "vision"
    frontend_len: int = 0
    # numerics / distribution
    act: str = "silu"
    norm_eps: float = 1e-6
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    fsdp: bool = False           # shard params over data axes too (ZeRO-3)
    remat: bool = True
    remat_policy: str = "full"   # full | dots (save matmul outputs: fewer
                                 # bwd FSDP re-gathers, more activation HBM)
    scan_unroll: bool = False    # unroll layer groups (dry-run cost truth:
                                 # XLA cost_analysis counts while bodies once)
    lr_schedule: str = "cosine"  # minicpm: "wsd"
    sub_quadratic: bool = False  # long_500k eligibility
    source: str = ""

    @property
    def vocab_padded(self) -> int:
        """Embedding-table rows padded to 256 so the vocab dim divides any
        production mesh axis (MaxText-style); logits beyond ``vocab`` are
        masked to -inf in the loss."""
        return -(-self.vocab // 256) * 256

    @property
    def d_inner(self) -> int:    # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kinds(self) -> List[Tuple[str, str]]:
        """Per-layer (mixer, ffn) kinds for the decoder stack."""
        kinds = []
        for i in range(self.n_layers):
            if self.family == "ssm":
                mixer = "mamba"
            elif self.attn_period:
                mixer = ("attn" if i % self.attn_period
                         == self.attn_period_offset else "mamba")
            else:
                mixer = "attn"
            if self.family == "ssm":
                ffn = "none"     # mamba2 stacks are mixer-only
            elif self.n_experts and i % self.moe_every == self.moe_offset:
                ffn = "moe"
            else:
                ffn = "mlp"
            kinds.append((mixer, ffn))
        return kinds

    def scan_period(self) -> int:
        """Smallest repeating pattern period (for scan-over-layers)."""
        kinds = self.layer_kinds()
        for p in range(1, len(kinds) + 1):
            if len(kinds) % p == 0 and all(
                    kinds[i] == kinds[i % p] for i in range(len(kinds))):
                return p
        return len(kinds)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

_ARCH_MODULES = {
    "whisper-large-v3": "whisper_large_v3",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "mamba2-370m": "mamba2_370m",
    "mixtral-8x22b": "mixtral_8x22b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-14b": "qwen3_14b",
    "command-r-35b": "command_r_35b",
    "deepseek-67b": "deepseek_67b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
}

ARCH_IDS = list(_ARCH_MODULES)


def get_arch(arch_id: str) -> ArchConfig:
    if arch_id not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; available: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.CONFIG


def get_smoke(arch_id: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch_id]}")
    return mod.SMOKE


def cell_is_runnable(cfg: ArchConfig, shape: ShapeConfig
                     ) -> Tuple[bool, str]:
    """Dry-run cell applicability (skips recorded in EXPERIMENTS.md)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("full O(S²) attention at 524k context — skipped per "
                       "assignment (run only for SSM/hybrid/linear-attn)")
    return True, ""


def all_cells():
    """The 40 assigned (arch x shape) cells, with runnability flags."""
    out = []
    for aid in ARCH_IDS:
        cfg = get_arch(aid)
        for shape in SHAPES.values():
            ok, why = cell_is_runnable(cfg, shape)
            out.append((aid, shape.name, ok, why))
    return out

"""jamba-v0.1-52b — hybrid Mamba+attention (1:7) with 16-expert MoE.

[arXiv:2403.19887; hf]  32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536; attention at layer i%8==4 (1 attn : 7 mamba), MoE 16e top-2
every other layer; mamba d_state=16, expand=2.  Hybrid/SSM -> long_500k
RUNS (4 full-attention layers hold the 524k KV; mamba layers are O(1)).
"""
from repro.configs.registry import ArchConfig

CONFIG = ArchConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab=65536, head_dim=128,
    rope=False,  # Jamba uses no positional encoding (mamba provides order)
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    ssm_state=16, ssm_expand=2, ssm_head_dim=64, ssm_conv=4,
    attn_period=8, attn_period_offset=4,
    param_dtype="bfloat16", fsdp=True,
    sub_quadratic=True,
    source="arXiv:2403.19887 (Jamba); mamba-1 mixer approximated by the "
           "shared mamba-2 SSD mixer (noted in DESIGN.md)",
)

SMOKE = ArchConfig(
    name="jamba-v0.1-smoke", family="hybrid",
    n_layers=8, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=256,
    head_dim=16, rope=False, n_experts=4, top_k=2, moe_every=2, moe_offset=1,
    moe_capacity_factor=8.0,
    ssm_state=16, ssm_expand=2, ssm_head_dim=16, ssm_conv=4, ssm_chunk=8,
    attn_period=8, attn_period_offset=4,
    param_dtype="float32", compute_dtype="float32", sub_quadratic=True,
)

"""Out-of-core scaling — chunked (streamed) vs monolithic training.

Reports rows/sec of boosting over a synthetic DataSource when the resident
binned chunk is capped at ~1/8 of the dataset (the acceptance budget)
versus the in-memory monolithic fit of the same data.  At ``scale=100``
the source reaches the acceptance configuration — 1M x 64 records streamed
without ever materializing the matrix; the monolithic baseline is measured
on a capped subset (rows/sec is size-normalized, so the comparison holds).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import csv_row
from repro.api import BoosterRegressor, ExecutionPlan
from repro.data.synthetic import SyntheticSource

BYTES_PER_ROW_OVERHEAD = 12          # f32 g/h + i32 node id per record


def _fit_seconds(est, **fit_kw) -> float:
    t0 = time.perf_counter()
    est.fit(**fit_kw)
    return time.perf_counter() - t0


def run(scale: float = 1.0, n_fields: int = 64, n_trees: int = 5,
        max_depth: int = 5, monolithic_cap: int = 200_000):
    n = max(4_000, int(10_000 * scale))
    src = SyntheticSource(n, n_fields, seed=0)
    est_kw = dict(n_trees=n_trees, max_depth=max_depth, learning_rate=0.3,
                  max_bins=64)
    rows = []

    # streamed fit: resident chunk capped at 1/8 of the dataset
    chunk_rows = max(256, n // 8)
    chunk_bytes = chunk_rows * (2 * n_fields + BYTES_PER_ROW_OVERHEAD)
    stream = BoosterRegressor(**est_kw)
    t_stream = _fit_seconds(stream, data=src,
                            plan=ExecutionPlan(chunk_bytes=chunk_bytes))
    stats = stream.stats_
    rps_stream = n * n_trees / t_stream
    rows.append(csv_row(
        f"stream_fit_n{n}", t_stream * 1e6,
        f"rows_per_sec={rps_stream:.0f};chunk_rows={stats['chunk_rows']};"
        f"n_chunks={stats['n_chunks']};"
        f"passes_per_round={stats['passes_per_round']}"))

    # monolithic baseline (same binning family, matrix fully resident)
    nb = min(n, monolithic_cap)
    Xb = np.concatenate([x for x, _ in
                         SyntheticSource(nb, n_fields, seed=0).chunks(nb)])
    yb = np.concatenate([y for _, y in
                         SyntheticSource(nb, n_fields, seed=0).chunks(nb)])
    mono = BoosterRegressor(**est_kw)
    t_mono = _fit_seconds(mono, X=Xb, y=yb)
    rps_mono = nb * n_trees / t_mono
    rows.append(csv_row(
        f"monolithic_fit_n{nb}", t_mono * 1e6,
        f"rows_per_sec={rps_mono:.0f}"))
    rows.append(csv_row(
        "stream_vs_monolithic", 0.0,
        f"throughput_ratio={rps_stream / rps_mono:.3f};"
        f"resident_fraction={stats['chunk_rows'] / n:.3f}"))

    # subtraction on top of streaming: siblings derived once per level
    # from the previous level's accumulated histogram (chunk passes are
    # unchanged — every chunk is streamed anyway for the lazy partition)
    sub = BoosterRegressor(**est_kw)
    t_sub = _fit_seconds(sub, data=src,
                         plan=ExecutionPlan(chunk_bytes=chunk_bytes,
                                            hist_subtraction=True))
    rows.append(csv_row(
        f"stream_fit_sub_n{n}", t_sub * 1e6,
        f"rows_per_sec={n * n_trees / t_sub:.0f};hist_subtraction=1"))

    # GOSS on top of streaming: the per-round stat volume drops
    goss = BoosterRegressor(goss_top_rate=0.1, goss_other_rate=0.1, **est_kw)
    t_goss = _fit_seconds(goss, data=src,
                          plan=ExecutionPlan(chunk_bytes=chunk_bytes))
    rows.append(csv_row(
        f"stream_goss_fit_n{n}", t_goss * 1e6,
        f"rows_per_sec={n * n_trees / t_goss:.0f};top=0.1;other=0.1"))

    # resilience-wrapped streaming (PR 9): the same fit through a
    # fault-free RetryingSource under a RecoveryPolicy — measures the
    # overhead of the self-healing machinery when nothing fails (the
    # regression gate keeps it inside tolerance of stream_fit)
    from repro.api import RecoveryPolicy, RetryPolicy, RetryingSource
    guarded = BoosterRegressor(**est_kw)
    t_guard = _fit_seconds(
        guarded, data=RetryingSource(src, RetryPolicy()),
        plan=ExecutionPlan(chunk_bytes=chunk_bytes),
        recovery=RecoveryPolicy())
    rows.append(csv_row(
        f"stream_fit_resilient_n{n}", t_guard * 1e6,
        f"rows_per_sec={n * n_trees / t_guard:.0f};"
        f"overhead_vs_plain={t_guard / t_stream:.3f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

"""Fig 6 analog — training-time breakdown over the algorithm's steps.

Measures our JAX implementation's steady-state per-step wall time
(step ① histogram, ② split-find, ③ partition, ⑤ traversal) on the five
dataset analogs and reports fractions; the paper's claim is that ①/③/⑤
dominate (~90–98% at full scale) and ② is small enough to offload.  All
jitted functions are warmed before timing (compile time excluded).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, hist_plan
from repro.core import bin_dataset
from repro.core.splits import find_best_splits
from repro.core.tree import fit_tree
from repro.data import paper_dataset
from repro.kernels import ops


def _one_tree_pass(data, g, h, depth, plan, timers=None):
    """One tree's steps ①②③ level loop; optionally accumulate timers."""
    n, F = data.codes.shape
    iscat = data.is_categorical
    fmask = jnp.ones((F,), bool)
    node_ids = jnp.zeros((n,), jnp.int32)
    for level in range(depth):
        nn = 2 ** level
        t0 = time.perf_counter()
        hist = ops.build_histogram(data.codes, g, h, node_ids, n_nodes=nn,
                                   n_bins=data.n_bins, plan=plan)
        hist.block_until_ready()
        t1 = time.perf_counter()
        best = find_best_splits(hist, iscat, fmask, 1.0, 0.0, 1.0)
        jax.block_until_ready(best.gain)
        t2 = time.perf_counter()
        codes_lvl = data.codes_cm[jnp.maximum(best.feature, 0)]
        node_ids = ops.partition_level(
            node_ids, codes_lvl.T, jnp.arange(nn, dtype=jnp.int32),
            best.threshold, best.is_cat, best.default_left,
            missing_bin=data.missing_bin, plan=plan)
        node_ids.block_until_ready()
        t3 = time.perf_counter()
        if timers is not None:
            timers["hist"] += t1 - t0
            timers["split"] += t2 - t1
            timers["part"] += t3 - t2


def run(scale: float = 1.0, max_bins: int = 128, depth: int = 6,
        strategy: str = "scatter"):
    rows = []
    plan = hist_plan(strategy, partition_strategy="reference",
                     traversal_strategy="reference")
    for name in ("iot", "higgs", "allstate", "mq2008", "flight"):
        X, y, cats, spec = paper_dataset(name, scale=scale)
        data = bin_dataset(X, max_bins=max_bins, categorical_fields=cats)
        n, F = data.codes.shape
        g = jnp.asarray(np.random.default_rng(0).normal(size=n), jnp.float32)
        h = jnp.ones((n,), jnp.float32)

        _one_tree_pass(data, g, h, depth, plan)              # warm compiles
        timers = {"hist": 0.0, "split": 0.0, "part": 0.0}
        _one_tree_pass(data, g, h, depth, plan, timers)      # measured

        tree = fit_tree(data.codes, data.codes_cm, g, h, depth=depth,
                        n_bins=data.n_bins, missing_bin=data.missing_bin,
                        is_cat_field=data.is_categorical,
                        field_mask=jnp.ones((F,), bool), lambda_=1.0,
                        gamma=0.0, min_child_weight=1.0, plan=plan)
        trav = lambda: ops.traverse_tree(  # noqa: E731
            tree, data.codes, missing_bin=data.missing_bin, plan=plan)
        trav().block_until_ready()                           # warm
        t0 = time.perf_counter()
        trav().block_until_ready()
        t_trav = time.perf_counter() - t0

        total = sum(timers.values()) + t_trav
        accel = (timers["hist"] + timers["part"] + t_trav) / total
        rows.append(csv_row(
            f"breakdown_{name}", total * 1e6,
            f"hist={timers['hist']/total:.2f};"
            f"split={timers['split']/total:.2f};"
            f"part={timers['part']/total:.2f};trav={t_trav/total:.2f};"
            f"accelerated_share={accel:.3f};records={n}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

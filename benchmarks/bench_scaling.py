"""Fig 12 analog — sensitivity to dataset size.

The paper scales each dataset 10x and finds Booster's speedup grows
(geomean 11.4 -> 27.9) while the GPU's stays ~2x.  We evaluate the same
machine model at 1x and 10x, and measure the software strategies' scaling
on this host (throughput per record should stay ~flat for the vectorized
strategies — i.e. time grows linearly, no superlinear artifacts).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import BOOSTER, IDEAL_CPU, IDEAL_GPU, csv_row, time_call
from benchmarks.bench_training import modeled_training_time
from repro.api import ExecutionPlan
from repro.data import paper_dataset
from repro.kernels import ops


def run(base_scale: float = 0.5, max_bins: int = 128):
    rows = []
    for name in ("iot", "higgs", "flight"):
        sus = {}
        _, _, _, spec0 = paper_dataset(name, n_override=8)
        for s_name, mult in (("1x", 1), ("10x", 10)):
            n = spec0.n_records * 1000 * mult   # full Table-III scale
            F = spec0.n_numeric + spec0.n_categorical
            spec = spec0
            frac = 0.55 if spec.n_categorical else 1.0
            # IoT's many shallow trees raise step-①'s share (paper §IV)
            depth = 3 if name == "iot" else 6
            t_cpu = modeled_training_time(IDEAL_CPU, n, F,
                                          depth=depth, frac_active=frac)
            t_gpu = modeled_training_time(IDEAL_GPU, n, F,
                                          depth=depth, frac_active=frac)
            t_boo = modeled_training_time(BOOSTER, n, F,
                                          depth=depth, frac_active=frac)
            sus[s_name] = (t_cpu / t_gpu, t_cpu / t_boo)
        rows.append(csv_row(
            f"scaling_modeled_{name}", 0.0,
            f"gpu_1x={sus['1x'][0]:.2f};gpu_10x={sus['10x'][0]:.2f};"
            f"booster_1x={sus['1x'][1]:.2f};"
            f"booster_10x={sus['10x'][1]:.2f}"))

    # measured: per-record throughput of the software strategies vs n
    rng = np.random.default_rng(0)
    for n in (20_000, 200_000):
        F, NB = 16, 64
        codes = jnp.asarray(rng.integers(0, NB, (n, F)), jnp.uint8)
        g = jnp.asarray(rng.normal(size=n), jnp.float32)
        h = jnp.ones((n,), jnp.float32)
        nid = jnp.asarray(rng.integers(0, 8, n), jnp.int32)
        t = time_call(lambda: ops.build_histogram(
            codes, g, h, nid, n_nodes=8, n_bins=NB,
            plan=ExecutionPlan.auto(hist_strategy="scatter")))
        rows.append(csv_row(f"scaling_measured_scatter_n{n}", t * 1e6,
                            f"ns_per_update={t/(n*F)*1e9:.2f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

"""Shared benchmark utilities + the paper's analytic machine models.

This container is a single CPU core, so cross-machine speedups cannot be
*measured*; they are *modeled* exactly the way the paper models its Ideal
configurations (§IV: "constrained only by 32- and 64-way parallelism
without any implementation artifacts"), then cross-checked against the
structure of the paper's results.  Wall-clock numbers reported alongside
are real measurements of the JAX software strategies on this host.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax
import numpy as np

from repro.api import ExecutionPlan

# --- the paper's hardware constants (Table V, §III-B) ---------------------
MEM_BW = 400e9              # sustained DRAM bandwidth, all machines
IDEAL_CPU = dict(parallelism=32, clock=2.2e9, name="ideal_32core")
IDEAL_GPU = dict(parallelism=64, clock=2.2e9, name="ideal_gpu")
BOOSTER = dict(parallelism=3200, clock=1.0e9, name="booster")
CYCLES_PER_UPDATE = 8       # §III-B: subtract + SRAM read + 2 FP adds + write
BYTES_PER_FIELD = 1         # uint8 bin code
GH_BYTES = 8                # g + h as f32


def hist_plan(strategy: str, **overrides) -> ExecutionPlan:
    """ExecutionPlan pinned to one histogram strategy (benchmark sweeps
    compare strategies at equal memory traffic, so everything else stays
    at the backend default)."""
    return ExecutionPlan.auto(hist_strategy=strategy, **overrides)


def strategy_plans(strategies) -> Dict[str, ExecutionPlan]:
    """name -> plan for a benchmark sweep over histogram strategies."""
    return {s: hist_plan(s) for s in strategies}


def time_call(fn: Callable, *args, repeat: int = 3, warmup: int = 1,
              **kwargs) -> float:
    """Median wall-time in seconds of a blocking call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kwargs))
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kwargs))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def machine_step1_time(machine: Dict, n_records: int, n_fields: int,
                       serialization: float = 1.0) -> float:
    """Histogram binning (step ①) time under the paper's ideal-machine
    model: update work at `parallelism`-way / clock vs the shared memory
    stream; whichever bounds.  `serialization` models naive bin packing
    (several fields' bins behind one SRAM port)."""
    updates = n_records * n_fields * serialization
    compute = updates * CYCLES_PER_UPDATE / (machine["parallelism"]
                                             * machine["clock"])
    memory = n_records * (n_fields * BYTES_PER_FIELD + GH_BYTES) / MEM_BW
    return max(compute, memory)


def host_step2_time(n_nodes: int, n_fields: int, n_bins: int,
                    ops_per_bin: int = 1000) -> float:
    """Split selection (step ②): offloaded to the host 32-core on EVERY
    machine (§IV adds this time to all systems), so it is the Amdahl
    residual that dominates Booster's residual time (Fig 8) and caps its
    speedup on small datasets.  ``ops_per_bin`` is calibrated so step ②
    lands in the paper's measured 2–10% of *sequential* time (Fig 6) —
    the gain formula with divisions + cache-unfriendly bin walks costs
    far more than the naive 4 flops/bin."""
    work = n_nodes * n_fields * n_bins * ops_per_bin
    return work / (IDEAL_CPU["parallelism"] * IDEAL_CPU["clock"])


def machine_step3_time(machine: Dict, n_records: int, n_fields: int,
                       column_major: bool) -> float:
    """Single-predicate evaluation: one compare per record; traffic is one
    field column (column-major) or the full record (row-major)."""
    compute = n_records * 2 / (machine["parallelism"] * machine["clock"])
    bytes_ = n_records * (BYTES_PER_FIELD if column_major
                          else n_fields * BYTES_PER_FIELD)
    return max(compute, bytes_ / MEM_BW)


def machine_step5_time(machine: Dict, n_records: int, n_fields: int,
                       depth: int, used_fields: int,
                       column_major: bool) -> float:
    """One-tree traversal: depth hops per record; traffic is the used
    columns (column-major) or whole records (row-major), plus g/h update."""
    compute = n_records * depth * CYCLES_PER_UPDATE / (
        machine["parallelism"] * machine["clock"])
    fetch = used_fields if column_major else n_fields
    bytes_ = n_records * (fetch * BYTES_PER_FIELD + 2 * GH_BYTES)
    return max(compute, bytes_ / MEM_BW)


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.2f},{derived}"

"""Fig 9 analog — isolating Booster's optimizations.

  (1) group-by-field vs naive packing: the serialization factor naive
      packing induces (several fields' bins behind one SRAM port) computed
      from each dataset's real field/bin layout — >1 only for categorical
      datasets, reproducing Fig 9's structure — plus the VMEM-pressure
      ratio of the two Pallas kernel variants (the TPU analog);
  (2) redundant column-major representation: measured wall-clock of the
      single-field fetch (step ③) from column-major vs row-major storage
      on this host, plus the modeled DRAM-byte saving for steps ③/⑤.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import csv_row, time_call
from repro.core import bin_dataset
from repro.data import paper_dataset

SRAM_BINS = 256  # one 2-KB SRAM = 256 bins of (G, H) f32 pairs (paper §III)


def naive_packing_serialization(n_value_bins) -> float:
    """Average updates serialized per SRAM under capacity-packing.

    Greedy-pack each field's bins into 256-bin SRAMs; a record issues one
    update per field, so an SRAM holding k fields serializes k updates.
    Group-by-field always yields 1.0.
    """
    srams, cur = [], 0
    counts = []
    cnt = 0
    for nb in n_value_bins:
        nb = int(nb) + 1  # + missing bin
        if cur + nb > SRAM_BINS and cur > 0:
            counts.append(cnt)
            cur, cnt = 0, 0
        cur += nb
        cnt += 1
    if cnt:
        counts.append(cnt)
    return float(max(counts)) if counts else 1.0


def vmem_pressure(fblk: int = 8, rblk: int = 256, nb: int = 256,
                  nn2: int = 64):
    """Transient one-hot tile bytes: grouped (per-field) vs packed."""
    grouped = rblk * nb * 4
    packed = rblk * fblk * nb * 4
    return grouped, packed


def run(scale: float = 1.0, max_bins: int = 128):
    rows = []
    g_bytes, p_bytes = vmem_pressure()
    rows.append(csv_row("kernel_vmem_onehot_tile", 0.0,
                        f"grouped_B={g_bytes};packed_B={p_bytes};"
                        f"ratio={p_bytes/g_bytes:.0f}"))
    for name in ("iot", "higgs", "allstate", "mq2008", "flight"):
        X, y, cats, spec = paper_dataset(name, scale=scale)
        data = bin_dataset(X, max_bins=max_bins, categorical_fields=cats)
        n, F = data.codes.shape

        ser = naive_packing_serialization(np.asarray(data.n_value_bins))
        rows.append(csv_row(
            f"group_by_field_{name}", 0.0,
            f"naive_packing_serialization_x={ser:.1f};"
            f"categorical_fields={spec.n_categorical}"))

        # measured: fetch one predicate column, column- vs row-major
        import jax
        f = F // 2
        cm_fn = jax.jit(lambda c: (c[f] <= 3).sum())
        rm_fn = jax.jit(lambda c: (c[:, f] <= 3).sum())
        t_cm = time_call(cm_fn, data.codes_cm)
        t_rm = time_call(rm_fn, data.codes)
        # modeled DRAM bytes for steps ③/⑤ (paper Fig 10b)
        bytes_rm = n * F
        bytes_cm3 = n
        bytes_cm5 = n * min(2 ** 6 - 1, F)
        rows.append(csv_row(
            f"column_major_{name}", t_cm * 1e6,
            f"measured_step3_x={t_rm/t_cm:.2f};"
            f"dram_bytes_step3_saving_x={bytes_rm/bytes_cm3:.1f};"
            f"dram_bytes_step5_saving_x={bytes_rm/max(bytes_cm5,1):.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

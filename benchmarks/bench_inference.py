"""Fig 13 analog — batch inference over a 500-tree ensemble.

Measures the vectorized ensemble traversal (the Booster mapping: one tree
resident per compute unit, records streamed) against a per-tree sequential
baseline, and reproduces the paper's depth effect: the shallow-tree outlier
(IoT) gains least because the baseline's work shrinks with depth while
Booster is bound by the deepest tree.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import BOOSTER, IDEAL_CPU, csv_row, time_call
from repro.kernels import ops
from repro.kernels.ref import TreeArrays


def _ensemble(rng, T, depth, n_cols, n_bins):
    def one():
        n_int, n_leaf = 2 ** depth - 1, 2 ** depth
        feat = rng.integers(0, n_cols, n_int).astype(np.int32)
        return TreeArrays(
            feature=jnp.asarray(feat),
            threshold=jnp.asarray(rng.integers(0, n_bins - 1, n_int),
                                  jnp.int32),
            is_cat=jnp.asarray(np.zeros(n_int), jnp.int32),
            default_left=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
            leaf_value=jnp.asarray(rng.normal(size=n_leaf), jnp.float32))
    trees = [one() for _ in range(T)]
    return TreeArrays(*[jnp.stack([getattr(t, f) for t in trees])
                        for f in TreeArrays._fields])


def modeled_inference_speedup(n, T, avg_depth, max_depth, n_fields):
    """Paper §III-D/§V-H model: the 32-core walks the ACTUAL (average)
    path length, while Booster's fixed-shape tables always walk the
    maximum depth ("its performance depends on the maximum depth across
    all trees") — shallow-tree ensembles (IoT) therefore gain less."""
    cpu = n * T * avg_depth * 8 / (IDEAL_CPU["parallelism"]
                                   * IDEAL_CPU["clock"])
    replicas = 3000 // max(T, 1) or 1
    booster_compute = n * T * max_depth * 8 / (
        min(3000, replicas * T) * BOOSTER["clock"])
    booster_mem = n * n_fields / 400e9
    return cpu / max(booster_compute, booster_mem)


def run(n: int = 20_000, T: int = 100, n_cols: int = 28, n_bins: int = 64):
    rows = []
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, n_bins, (n, n_cols)), jnp.uint8)
    for avg_depth, tag in ((3, "shallow_iot_like"), (6, "deep_typical")):
        trees = _ensemble(rng, T, avg_depth, n_cols, n_bins)
        t_vec = time_call(
            lambda trees=trees, depth=avg_depth: ops.predict_ensemble(
                trees, codes, missing_bin=n_bins - 1, depth=depth,
                strategy="reference"))
        su = modeled_inference_speedup(n, 500, avg_depth, 6, n_cols)
        rows.append(csv_row(
            f"inference_{tag}", t_vec * 1e6,
            f"records_per_s={n/t_vec:.0f};trees={T};"
            f"modeled_booster_x={su:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

"""Fig 13 analog — batch inference over the ensemble, three engines deep.

Measures the tree-batched inference engine against the legacy per-tree
scan baseline (the §III-D story: one shared record stream past all
resident trees vs T re-reads of the stream):

  * ``predict_scan``           — legacy per-tree ``lax.scan`` (the old
                                 default reference path, unjitted)
  * ``predict_batched``        — the all-trees-at-once level walk (jitted)
  * ``predict_batched_pallas`` — the tree-blocked Pallas kernel
                                 (``trees_per_block`` tables per grid
                                 step; interpret mode off-TPU)
  * ``serve_p99``              — a warm micro-serving loop with varying
                                 request sizes through the compile-once
                                 predict cache; derived reports p50/p99
                                 latency and sustained rows/sec
  * ``serve_qps_mixed``        — the serving daemon end-to-end: two
                                 tenants in one ``ModelRegistry``, ragged
                                 requests coalescing under deadline slack;
                                 sustained rows/sec + worst-tenant p99
  * ``serve_hotswap_p99``      — the daemon with a mid-run ``publish()``
                                 hot-swap; p99 must stay bounded and the
                                 swap must cost zero drops / zero retraces

plus the paper's depth-effect lanes (shallow IoT-like vs deep typical)
and its modeled Booster speedup.
"""
from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import BOOSTER, IDEAL_CPU, csv_row, time_call
from repro.api import ExecutionPlan
from repro.kernels import ops
from repro.kernels.ref import TreeArrays


def _ensemble(rng, T, depth, n_cols, n_bins):
    def one():
        n_int, n_leaf = 2 ** depth - 1, 2 ** depth
        feat = rng.integers(0, n_cols, n_int).astype(np.int32)
        return TreeArrays(
            feature=jnp.asarray(feat),
            threshold=jnp.asarray(rng.integers(0, n_bins - 1, n_int),
                                  jnp.int32),
            is_cat=jnp.asarray(np.zeros(n_int), jnp.int32),
            default_left=jnp.asarray(rng.integers(0, 2, n_int), jnp.int32),
            leaf_value=jnp.asarray(rng.normal(size=n_leaf), jnp.float32))
    trees = [one() for _ in range(T)]
    return TreeArrays(*[jnp.stack([getattr(t, f) for t in trees])
                        for f in TreeArrays._fields])


def modeled_inference_speedup(n, T, avg_depth, max_depth, n_fields):
    """Paper §III-D/§V-H model: the 32-core walks the ACTUAL (average)
    path length, while Booster's fixed-shape tables always walk the
    maximum depth ("its performance depends on the maximum depth across
    all trees") — shallow-tree ensembles (IoT) therefore gain less."""
    cpu = n * T * avg_depth * 8 / (IDEAL_CPU["parallelism"]
                                   * IDEAL_CPU["clock"])
    replicas = 3000 // max(T, 1) or 1
    booster_compute = n * T * max_depth * 8 / (
        min(3000, replicas * T) * BOOSTER["clock"])
    booster_mem = n * n_fields / 400e9
    return cpu / max(booster_compute, booster_mem)


def _engine_lanes(rng, codes, n, n_cols, n_bins, T, depth, rows):
    """scan vs batched vs tree-blocked Pallas at one (T, depth) point."""
    trees = _ensemble(rng, T, depth, n_cols, n_bins)
    lanes = (
        ("predict_scan", ExecutionPlan.auto(traversal_strategy="scan")),
        ("predict_batched",
         ExecutionPlan.auto(traversal_strategy="reference")),
        ("predict_batched_pallas",
         ExecutionPlan.auto(traversal_strategy="pallas")),
    )
    rps = {}
    for name, plan in lanes:
        t = time_call(lambda plan=plan: ops.predict_ensemble(
            trees, codes, missing_bin=n_bins - 1, depth=depth, plan=plan))
        rps[name] = n / t
        rows.append(csv_row(
            name, t * 1e6,
            f"rows_per_sec={n/t:.0f};trees={T};depth={depth}"))
    rows.append(csv_row(
        "predict_batched_vs_scan", 0.0,
        f"speedup_x={rps['predict_batched']/rps['predict_scan']:.2f};"
        f"trees={T};depth={depth}"))
    return rows


def _serve_lane(rng, n_cols, n_bins, T, depth, base_batch, rows):
    """Warm serving loop with ragged request sizes through the predict
    cache: p50/p99 request latency + sustained rows/sec + retraces."""
    from repro.core.gbdt import GBDTModel
    from repro.core.inference import (predict_cache_stats,
                                      predict_margin_cached)

    trees = _ensemble(rng, T, depth, n_cols, n_bins)
    model = GBDTModel(trees=trees, base_margin=0.0,
                      objective="reg:squarederror", missing_bin=n_bins - 1,
                      n_fields=n_cols, max_depth=depth)
    plan = ExecutionPlan.auto()
    sizes = [base_batch, base_batch // 2, (3 * base_batch) // 4,
             base_batch // 3]
    sizes = [max(1, s) for s in sizes]
    # warm every bucket, then measure: a warm server must not retrace
    for s in sizes:
        jax.block_until_ready(predict_margin_cached(
            model, jnp.asarray(rng.integers(0, n_bins, (s, n_cols)),
                               jnp.uint8), plan=plan))
    t0_traces = predict_cache_stats()["traces"]
    lat, total = [], 0
    for i in range(12):
        s = sizes[i % len(sizes)]
        batch = jnp.asarray(rng.integers(0, n_bins, (s, n_cols)),
                            jnp.uint8)
        t0 = time.perf_counter()
        jax.block_until_ready(predict_margin_cached(model, batch,
                                                    plan=plan))
        lat.append(time.perf_counter() - t0)
        total += s
    retraces = predict_cache_stats()["traces"] - t0_traces
    p50, p99 = np.percentile(np.asarray(lat) * 1e3, [50, 99])
    rows.append(csv_row(
        "serve_p99", p99 * 1e3,
        f"rows_per_sec={total/sum(lat):.0f};p50_ms={p50:.2f};"
        f"p99_ms={p99:.2f};retraces_warm={retraces};trees={T}"))
    return rows


def _daemon_pipeline(seed, T, depth, n_cols, n_bins):
    """Synthetic binner+ensemble bundle for the daemon lanes (raw-matrix
    requests need a binner in front of the forest)."""
    from repro.core.binning import Binner
    from repro.core.gbdt import GBDTModel
    from repro.core.inference import GBDTPipeline

    rng = np.random.default_rng(seed)
    binner = Binner(n_bins).fit(
        rng.normal(size=(512, n_cols)).astype(np.float32))
    model = GBDTModel(trees=_ensemble(rng, T, depth, n_cols, n_bins),
                      base_margin=0.0, objective="reg:squarederror",
                      missing_bin=n_bins - 1, n_fields=n_cols,
                      max_depth=depth)
    return GBDTPipeline(binner=binner, model=model)


def _daemon_lanes(rng, n_cols, n_bins, T, depth, base_batch, rows,
                  n_requests: int = 12):
    """The serving daemon end-to-end (Server + ModelRegistry over the
    predict cache): mixed two-tenant QPS, and hot-swap tail latency."""
    from repro.api import ModelRegistry, Server

    plan = ExecutionPlan.auto()
    sizes = [max(1, s) for s in (base_batch, base_batch // 2,
                                 (3 * base_batch) // 4, base_batch // 3)]
    mb = max(sizes)

    def request(i):
        X = rng.normal(size=(sizes[i % len(sizes)], n_cols)) \
               .astype(np.float32)
        X[rng.random(X.shape) < 0.02] = np.nan
        return X

    # -- serve_qps_mixed: two tenants, ragged coalescing traffic ----------
    reg = ModelRegistry(plan)
    names = ("a", "b")
    for i, name in enumerate(names):
        reg.publish(name, _daemon_pipeline(10 + i, T, depth, n_cols,
                                           n_bins))
    with Server(reg, max_batch=mb, default_slack_ms=2.0) as srv:
        for name in names:
            srv.warmup(name)
        warm = {name: srv.stats()[name]["traces"] for name in names}
        t0 = time.perf_counter()
        pending = [srv.submit(names[i % 2], request(i))
                   for i in range(n_requests)]
        for req in pending:
            req.result(timeout=600)
        wall = time.perf_counter() - t0
        stats = srv.stats()
    total = sum(r.n_rows for r in pending)
    p99 = max(stats[name]["p99_ms"] for name in names)
    retraces = sum(stats[name]["traces"] - warm[name] for name in names)
    rows.append(csv_row(
        "serve_qps_mixed", wall / n_requests * 1e6,
        f"rows_per_sec={total/wall:.0f};p99_ms={p99:.2f};models=2;"
        f"requests={n_requests};retraces_warm={retraces};trees={T}"))

    # -- serve_hotswap_p99: publish a new version mid-load ----------------
    reg = ModelRegistry(plan)
    reg.publish("m", _daemon_pipeline(20, T, depth, n_cols, n_bins))
    with Server(reg, max_batch=mb, default_slack_ms=2.0) as srv:
        srv.warmup("m")
        warm_m = srv.stats()["m"]["traces"]
        t0 = time.perf_counter()
        pending = []
        for i in range(n_requests):
            if i == n_requests // 2:   # same T/depth -> same shape buckets
                reg.publish("m", _daemon_pipeline(21, T, depth, n_cols,
                                                  n_bins))
            pending.append(srv.submit("m", request(i)))
        for req in pending:
            req.result(timeout=600)
        wall = time.perf_counter() - t0
        stats = srv.stats()["m"]
    total = sum(r.n_rows for r in pending)
    rows.append(csv_row(
        "serve_hotswap_p99", stats["p99_ms"] * 1e3,
        f"rows_per_sec={total/wall:.0f};p99_ms={stats['p99_ms']:.2f};"
        f"dropped={stats['dropped']};"
        f"retraces_warm={stats['traces'] - warm_m};version=2;trees={T}"))
    return rows


def run(n: int = 20_000, T: int = 200, n_cols: int = 28, n_bins: int = 64,
        depth: int = 6):
    rows = []
    rng = np.random.default_rng(0)
    codes = jnp.asarray(rng.integers(0, n_bins, (n, n_cols)), jnp.uint8)

    # engine comparison at the acceptance point (T=200 x depth-6 default)
    _engine_lanes(rng, codes, n, n_cols, n_bins, T, depth, rows)
    _serve_lane(rng, n_cols, n_bins, T, depth, base_batch=max(256, n // 8),
                rows=rows)
    _daemon_lanes(rng, n_cols, n_bins, T, depth,
                  base_batch=max(256, n // 8), rows=rows)

    # the paper's depth effect, now on the batched engine
    for avg_depth, tag in ((3, "shallow_iot_like"), (6, "deep_typical")):
        trees = _ensemble(rng, min(T, 100), avg_depth, n_cols, n_bins)
        t_vec = time_call(
            lambda trees=trees, depth=avg_depth: ops.predict_ensemble(
                trees, codes, missing_bin=n_bins - 1, depth=depth,
                plan=ExecutionPlan.auto(traversal_strategy="reference")))
        su = modeled_inference_speedup(n, 500, avg_depth, 6, n_cols)
        rows.append(csv_row(
            f"inference_{tag}", t_vec * 1e6,
            f"records_per_s={n/t_vec:.0f};trees={min(T, 100)};"
            f"modeled_booster_x={su:.1f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

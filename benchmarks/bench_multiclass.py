"""Multi-class boosting — class-batched step ① vs per-class passes.

The class-batched histogram build (one launch, K-wide stats operand)
reads the record/code stream ONCE per level regardless of K; the naive
alternative runs K independent scalar passes (K× the code traffic).
This bench measures both at growing K on one paper-shaped dataset, plus
the end-to-end per-round cost of ``multi:softmax`` training.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, hist_plan, time_call
from repro.core import GBDTConfig, bin_dataset, train
from repro.data import make_tabular
from repro.kernels import ops


def run(scale: float = 1.0, max_bins: int = 64, strategy: str = "onehot"):
    rows = []
    n = max(2000, int(8000 * scale))
    X, y, _ = make_tabular(n, 24, 0, task="multiclass", n_classes=8, seed=0)
    data = bin_dataset(X, max_bins=max_bins)
    plan = hist_plan(strategy)
    rng = np.random.default_rng(0)
    nid1 = jnp.asarray(rng.integers(0, 8, n), jnp.int32)

    for K in (2, 4, 8):
        g = jnp.asarray(rng.normal(size=(K, n)), jnp.float32)
        h = jnp.asarray(rng.uniform(0.1, 1.0, (K, n)), jnp.float32)
        nid = jnp.broadcast_to(nid1, (K, n))

        t_batched = time_call(lambda: ops.build_histogram(
            data.codes, g, h, nid, n_nodes=8, n_bins=data.n_bins,
            plan=plan))
        t_perclass = time_call(lambda: jax.block_until_ready([
            ops.build_histogram(data.codes, g[k], h[k], nid[k],
                                n_nodes=8, n_bins=data.n_bins, plan=plan)
            for k in range(K)]))
        rows.append(csv_row(
            f"hist_class_batched_K{K}", t_batched * 1e6,
            f"per_class_x={t_perclass / t_batched:.2f};"
            f"strategy={strategy};records={n}"))

    res = train(GBDTConfig(n_trees=3, max_depth=5, objective="multi:softmax",
                           n_classes=8, hist_strategy=strategy),
                data, y)
    per_round = sum(res.step_times.values()) / 3
    rows.append(csv_row("multiclass_train_round", per_round * 1e6,
                        f"K=8;depth=5;records={n};"
                        f"final_loss={res.history['train_loss'][-1]:.4f}"))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figures covered:
  Fig 6  step breakdown            bench_breakdown
  Fig 7  machine/strategy speedups bench_training
  Fig 9  optimization isolation    bench_opts
  Fig 12 dataset-size sensitivity  bench_scaling
  Fig 13 batch inference           bench_inference
  (out-of-core)                    bench_streaming
The roofline table (EXPERIMENTS.md §Roofline) is produced by the dry-run
artifacts via ``python -m repro.launch.report``.

``--smoke`` is the CI lane: tiny scales, every bench family exercised,
and ``--json BENCH_ci.json`` captures the rows (plus wall time and
failure state per bench) as the machine-readable perf-trajectory
artifact that CI uploads on every push.
"""
from __future__ import annotations

import argparse
import json
import platform
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3,
                    help="dataset scale vs the (already scaled-down) specs")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke lane: minimal scales, all benches")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as a JSON artifact")
    args = ap.parse_args()
    scale = 0.05 if args.smoke else args.scale

    from benchmarks import (bench_breakdown, bench_inference,
                            bench_multiclass, bench_opts, bench_scaling,
                            bench_streaming, bench_training)
    from repro.resilience import metrics as rmetrics
    benches = {
        "breakdown": lambda: bench_breakdown.run(scale=scale),
        "training": lambda: bench_training.run(scale=scale),
        "opts": lambda: bench_opts.run(scale=scale),
        "scaling": lambda: bench_scaling.run(base_scale=scale),
        "inference": lambda: bench_inference.run(
            n=max(2000, int(20000 * scale))),
        "multiclass": lambda: bench_multiclass.run(scale=scale),
        "streaming": lambda: bench_streaming.run(
            scale=scale, n_fields=16 if args.smoke else 64,
            n_trees=3 if args.smoke else 5),
    }
    selected = (args.only.split(",") if args.only else list(benches))
    report = {"smoke": args.smoke, "scale": scale,
              "python": platform.python_version(), "benches": {}}
    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        t0 = time.time()
        entry = {"rows": [], "seconds": None, "error": None}
        report["benches"][name] = entry
        before = rmetrics.snapshot()
        try:
            if name not in benches:
                raise KeyError(
                    f"unknown bench {name!r}; available: "
                    f"{','.join(benches)}")
            for row in benches[name]():
                print(row)
                sys.stdout.flush()
                cells = row.split(",", 2)
                try:
                    us = float(cells[1]) if len(cells) > 1 else 0.0
                except ValueError:
                    us = 0.0    # malformed timing cell must not kill the lane
                entry["rows"].append({
                    "name": cells[0],
                    "us_per_call": us,
                    "derived": cells[2] if len(cells) > 2 else ""})
        except Exception as e:  # noqa: BLE001 — keep the artifact complete
            # record the failure in the JSON (with context: how far the
            # lane got, and a short traceback), keep running the rest
            print(f"{name}_FAILED,0,{e!r}")
            entry["error"] = repr(e)
            entry["failed_after_rows"] = len(entry["rows"])
            entry["traceback"] = traceback.format_exc(limit=6)
            failures.append(name)
        entry["seconds"] = round(time.time() - t0, 2)
        # "slow" vs "silently degraded": a lane that demoted a Pallas
        # kernel or spent rounds recovering says so in the artifact
        fired = rmetrics.delta(before)
        entry["resilience"] = {"degradations": fired.get("degradations", 0),
                               "recoveries": fired.get("recoveries", 0)}
        print(f"# {name} done in {entry['seconds']:.1f}s", file=sys.stderr)

    if args.json is not None:
        with open(args.json, "w") as f:
            json.dump(report, f, indent=2)
        print(f"# wrote {args.json}", file=sys.stderr)
    if failures:
        print(f"# FAILED benches ({len(failures)}/{len(selected)}): "
              f"{', '.join(failures)}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()

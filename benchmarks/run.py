"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV.  Figures covered:
  Fig 6  step breakdown            bench_breakdown
  Fig 7  machine/strategy speedups bench_training
  Fig 9  optimization isolation    bench_opts
  Fig 12 dataset-size sensitivity  bench_scaling
  Fig 13 batch inference           bench_inference
The roofline table (EXPERIMENTS.md §Roofline) is produced by the dry-run
artifacts via ``python -m repro.launch.report``.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.3,
                    help="dataset scale vs the (already scaled-down) specs")
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    args = ap.parse_args()

    from benchmarks import (bench_breakdown, bench_inference,
                            bench_multiclass, bench_opts, bench_scaling,
                            bench_training)
    benches = {
        "breakdown": lambda: bench_breakdown.run(scale=args.scale),
        "training": lambda: bench_training.run(scale=args.scale),
        "opts": lambda: bench_opts.run(scale=args.scale),
        "scaling": lambda: bench_scaling.run(base_scale=args.scale),
        "inference": lambda: bench_inference.run(
            n=max(2000, int(20000 * args.scale))),
        "multiclass": lambda: bench_multiclass.run(scale=args.scale),
    }
    selected = (args.only.split(",") if args.only else list(benches))
    print("name,us_per_call,derived")
    for name in selected:
        t0 = time.time()
        try:
            for row in benches[name]():
                print(row)
                sys.stdout.flush()
        except Exception as e:  # noqa: BLE001
            print(f"{name}_FAILED,0,{e!r}")
            raise
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()

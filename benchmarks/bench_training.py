"""Fig 7 analog — training speedups across machines and strategies.

Two complementary reproductions:
  (a) *measured*: wall-time of the software histogram strategies on this
      host (scatter = multicore-style RMW, privatized replicas = GPU
      shared-memory style, sort+segment-sum = GPU-alternative, blocked
      one-hot einsum = the Booster kernel's XLA twin);
  (b) *modeled*: the paper's ideal-machine model (see benchmarks.common)
      evaluated per dataset: Ideal-32-core, Ideal-GPU (2x parallelism),
      Inter-record (histogram replicas eat on-chip capacity), Booster
      (3200-way, memory-bound).  Expected structure: GPU ≈ 1.6–1.9x,
      Booster ~5–30x, larger datasets -> larger speedups.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (BOOSTER, IDEAL_CPU, IDEAL_GPU, csv_row,
                               host_step2_time, machine_step1_time,
                               machine_step3_time, machine_step5_time,
                               strategy_plans, time_call)
from repro.api import ExecutionPlan
from repro.core import GBDTConfig, bin_dataset, train
from repro.data import make_tabular, paper_dataset
from repro.kernels import ops

STRATS = ("scatter", "scatter_private", "sort", "onehot")


def modeled_training_time(machine, n, F, depth=6, n_trees=1,
                          column_major=None, frac_active=1.0,
                          n_bins=256):
    """Per-tree time under the paper's machine model.  ``column_major``
    defaults to Booster-only (its redundant representation).  Step ② runs
    on the host for EVERY machine (§IV adds it to all systems) — it is the
    Amdahl residual that caps speedups on small datasets (Mq2008)."""
    if column_major is None:
        column_major = machine["name"] == "booster"
    t = 0.0
    for level in range(depth):
        active = n * (frac_active ** level)
        t += machine_step1_time(machine, active, F)
        t += machine_step3_time(machine, active, F, column_major)
        t += host_step2_time(2 ** level, F, n_bins)
    t += machine_step5_time(machine, n, F, depth, min(2 ** depth - 1, F),
                            column_major)
    return t * n_trees


def run_e2e(scale: float = 1.0, depth: int = 6, n_trees: int = 5):
    """End-to-end depth-6 training rows/sec: the pre-PR path (direct
    histograms, host-driven loop) vs hist-subtraction + fused rounds —
    the acceptance comparison for the device-resident trainer (subtraction
    halves step-① work at levels > 0, fused rounds drop the per-round
    host syncs)."""
    n = max(4000, int(40000 * scale))
    X, y, cats = make_tabular(n, 20, 4, n_cats=10, task="regression",
                              seed=0)
    data = bin_dataset(X, max_bins=64, categorical_fields=cats)
    rows = []
    rps = {}
    lanes = {
        "direct": (ExecutionPlan(hist_strategy="scatter").resolved(), False),
        "subfused": (ExecutionPlan(hist_strategy="scatter",
                                   hist_subtraction=True).resolved(), True),
    }
    for name, (plan, fused) in lanes.items():
        cfg = GBDTConfig(n_trees=n_trees, max_depth=depth,
                         learning_rate=0.3, fused_rounds=fused)
        t = time_call(lambda cfg=cfg, plan=plan: train(cfg, data, y,
                                                       plan=plan),
                      repeat=2)
        rps[name] = n * n_trees / t
        rows.append(csv_row(f"train_e2e_d{depth}_{name}", t * 1e6,
                            f"rows_per_sec={rps[name]:.0f};n={n};"
                            f"n_trees={n_trees}"))
    rows.append(csv_row(f"train_e2e_d{depth}_speedup", 0.0,
                        f"x={rps['subfused'] / rps['direct']:.2f}"))
    return rows


def run_packed_hist(scale: float = 1.0):
    """Packed vs unpacked Pallas histogram rows/sec (ISSUE 7).  Both
    lanes run the same ``pallas_grouped`` kernel on the same 16-bin
    data; the packed lane feeds 4-bit nibble codes and unpacks them
    in-VMEM, halving the HBM traffic the kernel is bound by — the
    acceptance criterion is packed beating unpacked."""
    from repro.core.binning import PackedCodes, pack_nibbles_np

    n = max(20000, int(200000 * scale))
    n_cols, n_bins = 28, 16
    rng = np.random.default_rng(0)
    codes_np = rng.integers(0, n_bins, (n, n_cols), dtype=np.uint8)
    codes = jnp.asarray(codes_np)
    packed = PackedCodes(jnp.asarray(pack_nibbles_np(codes_np)), n_cols)
    g = jnp.asarray(rng.normal(size=n), jnp.float32)
    h = jnp.ones((n,), jnp.float32)
    nid = jnp.asarray(rng.integers(0, 8, n), jnp.int32)
    plan = ExecutionPlan(hist_strategy="pallas_grouped").resolved()

    rows, rps = [], {}
    for tag, data in (("unpacked", codes), ("packed", packed)):
        t = time_call(lambda data=data: ops.build_histogram(
            data, g, h, nid, n_nodes=8, n_bins=n_bins, plan=plan))
        rps[tag] = n / t
        rows.append(csv_row(f"hist_pallas_{tag}", t * 1e6,
                            f"rows_per_sec={rps[tag]:.0f};n={n};"
                            f"fields={n_cols};bins={n_bins}"))
    rows.append(csv_row("hist_pallas_packed_speedup", 0.0,
                        f"x={rps['packed'] / rps['unpacked']:.2f}"))
    return rows


_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# timed in a subprocess: the 8-way mesh needs XLA_FLAGS=
# --xla_force_host_platform_device_count set before jax initializes,
# which the parent bench process is too late for
_DIST_CHILD = r"""
import json, time
import jax
from repro.core import GBDTConfig, bin_dataset
from repro.data import make_tabular
from repro.distributed.trainer import data_parallel_mesh, train_distributed
from repro.resilience import RecoveryPolicy

n, n_trees, depth = {n}, {n_trees}, {depth}
X, y, cats = make_tabular(n, 20, 0, task="regression", seed=0)
data = bin_dataset(X, max_bins=64)
cfg = GBDTConfig(n_trees=n_trees, max_depth=depth, learning_rate=0.3)

def timed(**kw):
    # min-of-2: the recovery-overhead lane compares two subprocess-local
    # timings, so squeeze scheduler noise out of both sides
    return min(_one(**kw) for _ in range(2))

def _one(**kw):
    t0 = time.perf_counter()
    train_distributed(cfg, data, y, **kw)
    return time.perf_counter() - t0

out = {{}}
for tag, devs in (("1shard", jax.devices()[:1]), ("8shard", jax.devices())):
    mesh = data_parallel_mesh(devs)
    train_distributed(cfg, data, y, mesh=mesh)   # warm: step cached by mesh
    out[tag] = timed(mesh=mesh)
# fault-free fit with the recovery machinery armed (divergence sentinels
# + checkpointable round loop): measures the wrapper's overhead when
# nothing fails.  Interleave plain/recovery reps on the warm 8-way mesh
# so the overhead ratio compares adjacent timings, not distant ones
rec = RecoveryPolicy()
# longer fits for the overhead pairs: the wrapper cost is per-round, so
# more rounds raise the signal while per-fit timing jitter stays flat
cfg = GBDTConfig(n_trees=n_trees * 3, max_depth=depth, learning_rate=0.3)
train_distributed(cfg, data, y, mesh=mesh)                # warm
train_distributed(cfg, data, y, mesh=mesh, recovery=rec)  # warm
plain, guarded = [], []
for _ in range(5):
    plain.append(_one(mesh=mesh))
    guarded.append(_one(mesh=mesh, recovery=rec))
out["recovery"] = min(guarded)
# per-pair ratios: adjacent timings share whatever load the host was
# under, so the ratio cancels drift the raw times cannot
ratios = sorted(g / p for g, p in zip(guarded, plain))
out["overhead"] = ratios[len(ratios) // 2]
out["recovery_trees"] = n_trees * 3
print(json.dumps(out))
"""


def run_distributed(scale: float = 1.0, depth: int = 5, n_trees: int = 4):
    """End-to-end ``train_distributed`` rows/sec on a 1-shard vs an
    8-virtual-device ``("data",)`` mesh.  On a CPU host the 8 "devices"
    share the same cores, so the scaling row measures the psum +
    shard_map overhead rather than real speedup — the two rows/sec lanes
    are what the perf gate tracks."""
    n = max(4000, int(40000 * scale))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    code = _DIST_CHILD.format(n=n, n_trees=n_trees, depth=depth)
    out = subprocess.run([sys.executable, "-c", code], env=env, cwd=_ROOT,
                         capture_output=True, text=True, timeout=900)
    if out.returncode != 0:
        raise RuntimeError("distributed bench subprocess failed:\n"
                           + out.stderr[-2000:])
    timed = json.loads(out.stdout.strip().splitlines()[-1])
    rows, rps = [], {}
    for tag in ("1shard", "8shard"):
        t = timed[tag]
        rps[tag] = n * n_trees / t
        rows.append(csv_row(f"train_dist_{tag}", t * 1e6,
                            f"rows_per_sec={rps[tag]:.0f};n={n};"
                            f"n_trees={n_trees}"))
    rows.append(csv_row("train_dist_scaling", 0.0,
                        f"x={rps['8shard'] / rps['1shard']:.2f}"))
    # fault-free recovery-armed fit on the same mesh: the self-healing
    # wrapper (divergence sentinels, checkpointable rounds) must stay
    # within 5% of the plain engine.  The gate is the median of paired
    # plain/guarded ratios from interleaved reps — robust to host drift
    t_rec = timed["recovery"]
    overhead = timed["overhead"]
    rows.append(csv_row("train_dist_recovery", t_rec * 1e6,
                        f"rows_per_sec={n * timed['recovery_trees'] / t_rec:.0f};"
                        f"overhead_vs_plain={overhead:.3f}"))
    if overhead > 1.05:
        raise RuntimeError(
            f"recovery-armed distributed fit is {overhead:.3f}x the plain "
            f"fit (gate: 1.05) — the fault-free path must stay cheap")
    return rows


def run(scale: float = 1.0, max_bins: int = 128):
    rows = []
    geo = {m["name"]: [] for m in (IDEAL_GPU, BOOSTER)}
    for name in ("iot", "higgs", "allstate", "mq2008", "flight"):
        X, y, cats, spec = paper_dataset(name, scale=scale)
        data = bin_dataset(X, max_bins=max_bins, categorical_fields=cats)
        n, F = data.codes.shape
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=n), jnp.float32)
        h = jnp.ones((n,), jnp.float32)
        nid = jnp.asarray(rng.integers(0, 8, n), jnp.int32)

        # (a) measured software strategies
        times = {}
        for s, plan in strategy_plans(STRATS).items():
            times[s] = time_call(
                lambda plan=plan: ops.build_histogram(
                    data.codes, g, h, nid, n_nodes=8, n_bins=data.n_bins,
                    plan=plan))
        base = times["scatter"]
        rows.append(csv_row(
            f"hist_strategies_{name}", base * 1e6,
            ";".join(f"{s}_x={base/times[s]:.2f}" for s in STRATS)))

        # (b) the paper's ideal-machine model at the FULL Table-III sizes
        # (analytic — no memory cost); categorical datasets behave
        # "smaller" (lopsided splits shrink per-level work, §IV)
        n_full = spec.n_records * 1000      # specs are 1000x scaled down
        frac = 0.55 if spec.n_categorical else 1.0
        # IoT's many shallow trees raise step-①'s share (paper §IV)
        depth = 3 if name == "iot" else 6
        t_cpu = modeled_training_time(IDEAL_CPU, n_full, F,
                                      depth=depth, frac_active=frac)
        t_gpu = modeled_training_time(IDEAL_GPU, n_full, F,
                                      depth=depth, frac_active=frac)
        t_boo = modeled_training_time(BOOSTER, n_full, F,
                                      depth=depth, frac_active=frac)
        su_gpu, su_boo = t_cpu / t_gpu, t_cpu / t_boo
        geo["ideal_gpu"].append(su_gpu)
        geo["booster"].append(su_boo)
        rows.append(csv_row(
            f"modeled_speedup_{name}", t_cpu * 1e6,
            f"ideal_gpu_x={su_gpu:.2f};booster_x={su_boo:.2f};"
            f"records={n_full};fields={F}"))
    for k, v in geo.items():
        rows.append(csv_row(f"modeled_geomean_{k}", 0.0,
                            f"x={float(np.exp(np.mean(np.log(v)))):.2f}"))
    # (c) packed vs unpacked Pallas histogram kernel (4-bit nibble codes)
    rows.extend(run_packed_hist(scale=scale))
    # (d) end-to-end depth-6 trainer: direct vs subtraction + fused rounds
    rows.extend(run_e2e(scale=scale))
    # (e) the distributed engine: 1-shard vs 8-virtual-device data mesh
    rows.extend(run_distributed(scale=scale))
    return rows


if __name__ == "__main__":
    print("\n".join(run()))

"""Perf gate: diff a fresh BENCH_ci.json against the committed baseline.

The bench-smoke CI job runs ``benchmarks/run.py --smoke --json
BENCH_ci.json`` and then this checker against ``BENCH_baseline.json``.
The gate compares only the INTERSECTION of lanes: every lane present in
both runs must not regress by more than ``--tolerance`` (default 30%),
and a bench family that errored in CI but has baseline lanes fails.  A
lane that exists only in the CI run (new bench, baseline not yet
regenerated) is ignored; a baseline lane that disappeared from the CI
run without its bench erroring is a printed WARNING, not a failure —
renamed/retired lanes shouldn't block unrelated PRs, and the warning
keeps the drift visible until the baseline is regenerated.

Lanes are throughput-typed on purpose: rows/sec is what the ROADMAP's
"fast as the hardware allows" goal cares about.  Because the committed
baseline is tied to whatever machine produced it while CI runners come
in different speed classes, the gate is **machine-calibrated** by
default: each lane's ci/baseline ratio is divided by the *median* ratio
across all lanes before applying the tolerance.  A uniform speed delta
(different CPU class) cancels out; a genuine code regression — one or a
few lanes dropping while the rest hold — does not.  The calibration
factor is clamped to [1/3, 3]: an across-the-board collapse beyond 3×
still fails rather than being explained away as slow hardware.  Pass
``--absolute`` to skip calibration when comparing runs from the same
machine (e.g. locally, before/after a change).

After an intentional perf change, regenerate the baseline::

    PYTHONPATH=src:. python benchmarks/run.py --smoke --json BENCH_baseline.json

Tolerance can be widened per-run via ``BENCH_TOLERANCE`` (a fraction,
e.g. ``0.5``) without editing CI, for known-noisy shared runners.
"""
from __future__ import annotations

import argparse
import json
import os
import re
import statistics
import sys

_RPS = re.compile(r"rows_per_sec=([0-9.]+)")
_CALIB_CLAMP = 3.0          # max uniform speed delta absorbed as "hardware"


def throughput_lanes(report: dict) -> dict:
    """{(bench, row_name): rows_per_sec} for every throughput-typed row."""
    lanes = {}
    for bench, entry in report.get("benches", {}).items():
        for row in entry.get("rows", []):
            m = _RPS.search(row.get("derived", ""))
            if m:
                lanes[(bench, row["name"])] = float(m.group(1))
    return lanes


def machine_calibration(base_lanes: dict, ci_lanes: dict) -> float:
    """Median ci/baseline ratio over the lanes both runs report, clamped
    to ``[1/_CALIB_CLAMP, _CALIB_CLAMP]`` — the uniform speed factor
    attributed to the machine rather than to the code."""
    ratios = [ci_lanes[k] / v for k, v in base_lanes.items()
              if k in ci_lanes and v > 0]
    if not ratios:
        return 1.0
    return min(max(statistics.median(ratios), 1.0 / _CALIB_CLAMP),
               _CALIB_CLAMP)


def check(ci: dict, baseline: dict, tolerance: float,
          absolute: bool = False) -> tuple:
    """Gate the INTERSECTION of baseline and CI lanes.

    Returns ``(failures, warnings)`` — both lists of human-readable
    strings; the gate passes iff ``failures`` is empty.  A CI-only lane
    (new bench without a baseline entry yet) is never a failure; a
    baseline lane absent from a *successful* CI bench is a warning
    (renamed/retired lane — regenerate the baseline to silence it).
    """
    failures, warnings = [], []
    base_lanes = throughput_lanes(baseline)
    ci_lanes = throughput_lanes(ci)
    base_benches = {b for (b, _) in base_lanes}
    for bench in sorted(base_benches):
        err = ci.get("benches", {}).get(bench, {}).get("error")
        if err:
            failures.append(f"{bench}: errored in CI ({err})")
    calib = 1.0 if absolute else machine_calibration(base_lanes, ci_lanes)
    for (bench, name), base_rps in sorted(base_lanes.items()):
        if ci.get("benches", {}).get(bench, {}).get("error"):
            continue  # already reported above
        got = ci_lanes.get((bench, name))
        if got is None:
            warnings.append(f"{bench}/{name}: baseline lane disappeared "
                            f"from the CI run (baseline {base_rps:.0f} "
                            f"rows/sec) — regenerate BENCH_baseline.json "
                            f"if this rename/retirement is intentional")
            continue
        expected = base_rps * calib
        if got < (1.0 - tolerance) * expected:
            failures.append(
                f"{bench}/{name}: {got:.0f} rows/sec is "
                f"{100 * (1 - got / expected):.0f}% below the "
                f"machine-calibrated baseline {expected:.0f} "
                f"(raw baseline {base_rps:.0f} x calibration {calib:.2f}; "
                f"tolerance {tolerance:.0%})")
    return failures, warnings


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("ci_json")
    ap.add_argument("baseline_json")
    ap.add_argument("--tolerance", type=float,
                    default=float(os.environ.get("BENCH_TOLERANCE", 0.30)),
                    help="allowed fractional rows/sec drop per lane")
    ap.add_argument("--absolute", action="store_true",
                    help="skip machine calibration (same-machine runs)")
    args = ap.parse_args()
    with open(args.ci_json) as f:
        ci = json.load(f)
    with open(args.baseline_json) as f:
        baseline = json.load(f)

    failures, warnings = check(ci, baseline, args.tolerance,
                               absolute=args.absolute)
    n_lanes = len(throughput_lanes(baseline))
    mode = ("absolute" if args.absolute else
            f"calibration {machine_calibration(throughput_lanes(baseline), throughput_lanes(ci)):.2f}")
    for msg in warnings:
        print(f"perf gate WARNING: {msg}")
    if failures:
        print(f"perf gate FAILED ({len(failures)} of {n_lanes} lanes, "
              f"{mode}):")
        for msg in failures:
            print(f"  - {msg}")
        sys.exit(1)
    print(f"perf gate OK: {n_lanes} rows/sec lanes within "
          f"{args.tolerance:.0%} of baseline ({mode})")


if __name__ == "__main__":
    main()
